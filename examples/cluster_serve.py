"""Cluster serving demo: one bursty multi-tenant trace over a heterogeneous
2-GPU fleet, comparing placement policies — with inter-GPU migration on the
MSched-aware packer.

Run: PYTHONPATH=src python examples/cluster_serve.py [--gpus 2] [--migrate]
"""
import argparse

from repro.cluster import mixed, simulate_cluster
from repro.core.hardware import A100_40G, A100_80G
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import MSchedAdmission, SLOSpec, ServedRequestTask, bursty_trace


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gpus", type=int, default=2)
    ap.add_argument("--rate", type=float, default=2.0, help="rps per GPU")
    ap.add_argument("--duration", type=float, default=4.0)
    ap.add_argument("--oversub", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--migrate", action="store_true",
                    help="enable periodic inter-GPU rebalancing")
    args = ap.parse_args()

    trace = bursty_trace(
        args.rate * args.gpus, args.duration, seed=args.seed, cv=4.0,
        tenants=("qwen3-1.7b",), prompt_mean=128, output_mean=64,
        max_output=128,
    )
    probe = ServedRequestTask(999, trace.requests[0], page_size=1 << 20)
    cap = int(3 * probe.footprint_bytes() / args.oversub)

    # heterogeneous fleet: alternating 1x/3x-capacity device classes; a
    # topology carries live link-contention state, so each run gets a fresh one
    def topology():
        return mixed([
            (A100_40G, cap // 2) if i % 2 == 0 else (A100_80G, 3 * cap // 2)
            for i in range(args.gpus)
        ])

    names = ", ".join(
        f"{g.name}={g.hbm_bytes / 2**30:.1f}GiB" for g in topology().gpus
    )
    slo = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)
    print(
        f"trace: {len(trace)} requests @ {trace.offered_rate_rps():.1f} rps "
        f"over {args.gpus} GPUs ({names}), "
        f"{args.oversub:.1f}x oversubscribed at 3-way per-GPU concurrency"
    )
    for placement in ("roundrobin", "leastloaded", "msched"):
        rep = simulate_cluster(
            trace, topology(),
            backend="msched", placement=placement,
            admission_factory=lambda i: MSchedAdmission(headroom=0.9),
            policy_factory=lambda i: RoundRobinPolicy(350_000.0),
            page_size=1 << 20, slo=slo,
            rebalance_period_us=500_000.0 if args.migrate else None,
        )
        moved = (
            f" migrations={len(rep.migrations)}" if args.migrate else ""
        )
        print(
            f"{placement:>12}: finished {rep.stats.n_finished}/"
            f"{rep.stats.n_requests} goodput={rep.stats.goodput_per_s:.2f}/s "
            f"ttft_p99={rep.stats.ttft_p99_us / 1e3:.0f}ms "
            f"placed={[g.placed for g in rep.per_gpu]}{moved}"
        )


if __name__ == "__main__":
    main()
