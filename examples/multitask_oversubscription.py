"""The paper's headline scenario, live: three model instances time-share a
device whose memory budget holds only half their aggregate weights (200%
oversubscription). MSched predicts each task's working set from its command
stream (template predictor), enforces timeline-aligned OPT placement, and
migrates real arrays host<->device on every extended context switch.

    PYTHONPATH=src python examples/multitask_oversubscription.py
"""
import time

import jax

from repro.core.runtime import LiveModelTask, LiveRuntime


def main():
    archs = ["qwen3-1.7b", "llama3.2-3b", "mamba2-1.3b"]
    tasks = [LiveModelTask(i, a, seed=i) for i, a in enumerate(archs)]
    total = sum(t.footprint_bytes() for t in tasks)
    budget = int(total / 2.0)
    print(f"aggregate working set {total/2**20:.1f} MiB, device budget "
          f"{budget/2**20:.1f} MiB (200% oversubscription)")

    rt = LiveRuntime(tasks, budget, steps_per_slice=4)
    t0 = time.time()
    stats = rt.run(total_slices=9)  # 3 slices each, round robin
    dt = time.time() - t0

    print(f"steps per task: {stats.steps}")
    print(f"proactively migrated in : {stats.migrated_in_bytes/2**20:8.1f} MiB")
    print(f"evicted to host         : {stats.migrated_out_bytes/2**20:8.1f} MiB")
    print(f"demand faults (F- path) : {stats.demand_faults}")
    print(f"avg switch coordinator  : {1e3*sum(stats.switch_wall_s)/len(stats.switch_wall_s):.2f} ms"
          f"  (paper Fig. 11: <1 ms control plane @ GPU scale)")
    print(f"wall time: {dt:.1f}s")


if __name__ == "__main__":
    main()
