"""Quickstart: train a tiny LM for 30 steps on synthetic data (CPU, ~1 min).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.data.pipeline import pipeline_for
from repro.launch.steps import make_train_state, make_train_step


def main():
    cfg = get_config("qwen3-1.7b").reduced()
    shape = ShapeSpec("quickstart", seq_len=128, global_batch=8, kind="train")
    pipe = pipeline_for(cfg, shape)

    state = make_train_state(cfg, jax.random.PRNGKey(0))
    step = jax.jit(
        make_train_step(cfg, peak_lr=1e-3, warmup=5, total_steps=30),
        donate_argnums=(0,),
    )

    print(f"arch={cfg.name} (reduced) params="
          f"{sum(p.size for p in jax.tree.leaves(state['params'])):,}")
    for i in range(30):
        batch = {k: jax.numpy.asarray(v) for k, v in pipe.batch(i).items()}
        state, m = step(state, batch)
        if i % 5 == 0 or i == 29:
            print(f"step {i:3d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  gnorm {float(m['grad_norm']):.2f}")
    print("done — loss should have dropped from ~ln(512)=6.24")


if __name__ == "__main__":
    main()
