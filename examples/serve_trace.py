"""Trace-driven serving demo: replay a bursty request trace through the
dynamic simulator under UM (always-admit) vs MSched (working-set-aware
admission) and print the SLO scoreboard.

Run: PYTHONPATH=src python examples/serve_trace.py [--arch qwen3-1.7b]
"""
import argparse

from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    SLOSpec,
    ServedRequestTask,
    bursty_trace,
    serve_trace,
)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--rate", type=float, default=4.0)
    ap.add_argument("--duration", type=float, default=2.0)
    ap.add_argument("--oversub", type=float, default=1.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    trace = bursty_trace(
        args.rate, args.duration, seed=args.seed, cv=3.0,
        tenants=(args.arch,), prompt_mean=128, output_mean=16, max_output=32,
    )
    probe = ServedRequestTask(999, trace.requests[0], page_size=1 << 20)
    cap = int(3 * probe.footprint_bytes() / args.oversub)
    slo = SLOSpec(ttft_us=2_000_000.0, tpot_us=50_000.0)
    print(
        f"trace: {len(trace)} requests @ {trace.offered_rate_rps():.1f} rps, "
        f"tenant={args.arch}, HBM={cap / 2**30:.1f} GiB "
        f"({args.oversub:.1f}x oversubscribed at 3-way concurrency)"
    )
    for backend, admission, quantum in (
        ("um", AlwaysAdmit(), 2_000.0),
        ("msched", MSchedAdmission(headroom=0.9), 350_000.0),
    ):
        rep = serve_trace(
            trace, RTX5080, backend=backend, capacity_bytes=cap,
            admission=admission, policy=RoundRobinPolicy(quantum),
            page_size=1 << 20, slo=slo,
        )
        print(
            f"{backend:>7}: finished {rep.n_finished}/{rep.n_requests} "
            f"goodput={rep.goodput_per_s:.2f}/s "
            f"ttft_p99={rep.ttft_p99_us / 1e3:.0f}ms "
            f"tpot_p50={rep.tpot_p50_us / 1e3:.1f}ms "
            f"p99_latency={rep.latency_p99_us / 1e6:.2f}s "
            f"faults={rep.faults}"
        )


if __name__ == "__main__":
    main()
