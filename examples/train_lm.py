"""End-to-end training driver: a ~100M-parameter LM for a few hundred steps
with the full substrate — sharded state, deterministic data pipeline, WSD
schedule, async checkpointing, fault-tolerant supervisor, and (optional)
restart continuation.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--kill-at 120]

``--kill-at`` injects a node failure mid-run to demonstrate restart-from-
checkpoint: the run resumes from the last checkpoint and finishes, and the
loss curve is identical to an uninterrupted run.
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.configs.base import ShapeSpec
from repro.runtime.train_loop import FailureInjector, TrainSupervisor


def build_cfg():
    # ~100M-param llama-style config (scaled-down llama3.2 family)
    base = get_config("llama3.2-3b")
    return dataclasses.replace(
        base,
        num_layers=8,
        d_model=640,
        num_heads=10,
        num_kv_heads=2,
        head_dim=64,
        d_ff=1792,
        vocab_size=32000,
        remat=False,
        schedule="wsd",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = build_cfg()
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    shape = ShapeSpec("train", seq_len=128, global_batch=4, kind="train")
    sup = TrainSupervisor(cfg, shape, args.ckpt_dir, ckpt_every=40)
    injector = FailureInjector([args.kill_at]) if args.kill_at else None

    t0 = time.time()
    report = sup.run(args.steps, injector=injector)
    dt = time.time() - t0
    print(
        f"steps={report.steps_run} restarts={report.restarts} "
        f"checkpoints={report.checkpoints} stragglers={report.straggler_steps}"
    )
    print(f"loss: {report.losses[0]:.3f} -> {report.losses[-1]:.3f}  ({dt:.0f}s)")
    assert report.losses[-1] < report.losses[0]


if __name__ == "__main__":
    main()
