"""Hillclimb probe: recompile one dry-run cell and print its roofline terms.

Usage: PYTHONPATH=src python scripts/cellprobe.py <arch> <shape> [micro]
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.launch import dryrun  # noqa: E402
from benchmarks.roofline_report import roofline_terms  # noqa: E402

arch, shape = sys.argv[1], sys.argv[2]
if len(sys.argv) > 3:
    os.environ["REPRO_MICROBATCH"] = sys.argv[3]
rec = dryrun.run_cell(arch, shape, multi_pod=False)
if rec["status"] != "ok":
    print(rec.get("error"))
    print(rec.get("traceback", "")[-1500:])
    sys.exit(1)
t = roofline_terms(rec)
out = {
    "arch": arch,
    "shape": shape,
    "compile_s": rec["compile_s"],
    "temp_GiB": round(rec["mem"]["temp_bytes"] / 2**30, 2),
    "dot_TF_dev": round(rec["hlo_costs"]["dot_flops"] / 1e12, 2),
    "coll_GB_dev": round(sum(rec["hlo_costs"]["collective_bytes"].values()) / 1e9, 2),
    "coll_by_kind_GB": {
        k: round(v / 1e9, 1)
        for k, v in rec["hlo_costs"]["collective_bytes"].items()
        if v
    },
    "terms_s": {
        "compute": round(t["t_compute_s"], 4),
        "memory": round(t["t_memory_s"], 4),
        "collective": round(t["t_collective_s"], 4),
    },
    "dominant": t["dominant"],
    "useful_ratio": round(t["useful_ratio"], 3),
    "roofline_fraction": round(t["roofline_fraction"], 4),
}
print(json.dumps(out, indent=1))
