#!/usr/bin/env python3
"""``msctl`` — operator CLI for the crash-safe control plane.

Three subcommands:

``demo``
    Run a small fleet under the control plane (optionally with a
    coordinator crash/recover cycle mid-run), exercise ``submit``/
    ``cancel`` through the lifecycle state machine, and dump the decision
    journal to ``--journal-out`` for the offline subcommands below.

``journal <dump.json>``
    Pretty-print a journal dump (the ``DecisionJournal.to_json`` format):
    one line per decision, sequence-ordered, with the primitive payload.

``status <dump.json> [--task ID]``
    Replay a journal dump offline through the lifecycle state machine
    (the same ``apply_event`` the in-sim replay uses) and print where
    every task ended up — or one task's state with ``--task``. This is
    the recovery path as a command: the dump alone reconstructs the
    fleet's task states.

``metrics [report.json] [--prom] [--demo]``
    Pretty-print a ``metrics-report-v1`` artifact (counters, gauges,
    histogram percentiles, prediction-audit block), or re-emit it as
    Prometheus text exposition with ``--prom``. With ``--demo``, run the
    demo fleet with the metrics registry and the online prediction
    auditor attached, tail the per-rebalance-tick rollups, and print
    fleet prediction health next to the deadline counters.

Usage:
  python scripts/msctl.py demo [--crash] [--journal-out /tmp/journal.json]
  python scripts/msctl.py journal /tmp/journal.json
  python scripts/msctl.py status /tmp/journal.json [--task 3]
  python scripts/msctl.py metrics report.json [--prom]
  python scripts/msctl.py metrics --demo [--out report.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import Counter
from pathlib import Path

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)
from repro.control import (  # noqa: E402
    ControlPlane,
    TaskLifecycle,
    apply_event,
)

# canonical display order for lifecycle summaries (TASK_STATES is a set)
_ORDER = (
    "SUBMITTED", "ADMITTED", "RUNNING", "MIGRATING", "CHECKPOINTED",
    "FAILED", "FINISHED", "CANCELLED", "SHED",
)

# journal kinds with no lifecycle effect — skipped by offline replay, the
# same set ControlPlane._replay skips (markers and queue bookkeeping)
_NON_LIFECYCLE = {"crash", "recover", "hold", "strand", "requeue", "release"}


def _run_demo_fleet(crash: bool, telemetry=None):
    """The shared demo fleet: 2x RTX5080, journal control plane, one
    operator cancel, optional coordinator crash/recover cycle. Returns
    ``(report, control)`` — the caller picks what to print."""
    from repro.cluster import (
        FaultEvent,
        FaultInjector,
        homogeneous,
        simulate_cluster,
    )
    from repro.core.hardware import NVLINK_A100_GBPS, RTX5080
    from repro.core.scheduler import RoundRobinPolicy
    from repro.serving import MSchedAdmission, poisson_trace

    trace = poisson_trace(
        6.0, 1.2, seed=7, tenants=("qwen3-1.7b",), prompt_mean=64,
        output_mean=120, max_output=240, rt_fraction=0.25,
    )
    faults = [
        FaultEvent(300_000.0, "coordinator_crash"),
        FaultEvent(450_000.0, "gpu_fail", gpu="gpu0"),
        FaultEvent(650_000.0, "gpu_recover", gpu="gpu0"),
        FaultEvent(800_000.0, "coordinator_recover"),
    ] if crash else []
    control = ControlPlane(recovery="journal", replay_check=True)
    # operator ops scheduled through the CLI surface: cancel one task
    # mid-run to show the lifecycle edge in the journal
    control.cancel(1, 150_000.0)
    rep = simulate_cluster(
        trace,
        homogeneous(
            2, RTX5080, capacity_bytes=4 << 30,
            nvlink_gbps=NVLINK_A100_GBPS,
        ),
        backend="msched", placement="leastloaded",
        admission_factory=lambda i: MSchedAdmission(headroom=0.9),
        policy_factory=lambda i: RoundRobinPolicy(350_000.0),
        page_size=1 << 20,
        faults=FaultInjector(faults) if faults else FaultInjector.none(),
        control=control, audit=True, drain_factor=20.0,
        telemetry=telemetry,
    )
    return rep, control


def _print_prediction_health(control) -> None:
    health = control.prediction_health()
    if health is None:
        return
    print(
        "prediction: F-={false_negative_pct:.2f}% "
        "F+={false_positive_pct:.2f}% "
        "drift={template_drift_pp:+.2f}pp over {audited_commands} commands "
        "/ {audited_quanta} quanta, "
        "overfetch={overfetch_bytes}B "
        "underfetch-stall={underfetch_stall_us:.0f}us".format(**health)
    )


def cmd_demo(args) -> int:
    rep, control = _run_demo_fleet(args.crash)
    print(
        f"demo run: {rep.stats.n_requests} requests, "
        f"{rep.stats.n_finished} finished, {rep.lost_requests} lost, "
        f"{rep.coordinator_crashes} coordinator crash(es), "
        f"{rep.journal_replays} journal replay(s)"
    )
    counts = Counter(
        control.status(tid) for tid in control.lifecycle.states()
    )
    print("lifecycle:", ", ".join(
        f"{s}={counts[s]}" for s in _ORDER if counts[s]
    ))
    out = Path(args.journal_out)
    out.write_text(json.dumps(control.journal.to_json(), indent=1))
    print(f"journal: {len(control.journal)} records -> {out}")
    return 0


def _load_dump(path: Path) -> list:
    doc = json.loads(path.read_text())
    if not isinstance(doc, list):
        raise SystemExit(f"{path}: not a journal dump (expected a list)")
    return doc


def cmd_journal(args) -> int:
    dump = _load_dump(args.dump)
    if not dump:
        print(f"{args.dump}: empty journal")
        return 0
    for r in dump:
        extra = {
            k: v for k, v in r.items()
            if k not in ("seq", "time_us", "kind", "task_id")
        }
        tid = "-" if r.get("task_id") is None else r["task_id"]
        detail = " ".join(f"{k}={v}" for k, v in sorted(extra.items()))
        print(
            f"{r['seq']:>5}  {r['time_us'] / 1e3:>10.1f}ms  "
            f"{r['kind']:<10} task={tid:<6} {detail}"
        )
    print(f"{len(dump)} records")
    return 0


def cmd_status(args) -> int:
    dump = _load_dump(args.dump)
    lc = TaskLifecycle()
    for r in dump:
        if r["kind"] in _NON_LIFECYCLE:
            continue
        apply_event(lc, r["kind"], r["task_id"], r["time_us"])
    states = lc.states()
    if args.task is not None:
        if args.task not in states:
            print(f"task {args.task}: unknown (never submitted)")
            return 1
        print(f"task {args.task}: {states[args.task]}")
        return 0
    counts = Counter(states.values())
    print(f"{len(states)} tasks from {len(dump)} journal records")
    for s in _ORDER:
        if counts[s]:
            tids = sorted(t for t, st in states.items() if st == s)
            shown = ", ".join(map(str, tids[:12]))
            more = f" (+{len(tids) - 12} more)" if len(tids) > 12 else ""
            print(f"  {s:<13} {counts[s]:>4}  [{shown}{more}]")
    return 0


def _fmt_value(v) -> str:
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)


def cmd_metrics(args) -> int:
    if args.demo:
        from repro.telemetry import Telemetry

        tel = Telemetry(metrics=True, audit=True)
        rep, control = _run_demo_fleet(args.crash, telemetry=tel)
        report = tel.metrics_report()
        tail = report.rollups[-args.tail:] if args.tail else report.rollups
        for row in tail:
            keys = sorted(row["values"])
            shown = ", ".join(
                f"{k}={_fmt_value(row['values'][k])}" for k in keys[:6]
            )
            more = f" (+{len(keys) - 6} keys)" if len(keys) > 6 else ""
            print(f"rollup @ {row['ts_us'] / 1e3:>10.1f}ms  {shown}{more}")
        print(
            f"deadlines: {control.deadline_misses} missed of "
            f"{control.rt_requests} rt requests, "
            f"{control.preemptions} preemption(s)"
        )
        _print_prediction_health(control)
        if args.out is not None:
            report.write(args.out)
            print(f"metrics: wrote {args.out}")
        return 0

    if args.report is None:
        raise SystemExit("metrics: need a report path (or --demo)")
    from repro.telemetry import MetricsReport

    report = MetricsReport.from_json(json.loads(args.report.read_text()))
    if args.prom:
        sys.stdout.write(report.to_prometheus())
        return 0
    doc = report.to_json()
    print(
        f"schema: {doc['schema']}  "
        f"generated @ {report.generated_us / 1e3:.1f}ms"
    )
    by_kind = {"counter": [], "gauge": [], "histogram": []}
    for r in doc["metrics"]:
        by_kind[r["kind"]].append(r)
    for kind in ("counter", "gauge"):
        rows = by_kind[kind]
        if rows:
            print(f"{kind}s ({len(rows)}):")
            for r in sorted(rows, key=lambda r: (r["name"], r["track"])):
                print(
                    f"  {r['name']:<32} track={r['track']:<10} "
                    f"{_fmt_value(r['value'])}"
                )
    hists = by_kind["histogram"]
    if hists:
        print(f"histograms ({len(hists)}):")
        for r in sorted(hists, key=lambda r: (r["name"], r["track"])):
            print(
                f"  {r['name']:<32} track={r['track']:<10} "
                f"n={r['count']} p50={_fmt_value(r['p50'])} "
                f"p99={_fmt_value(r['p99'])} sum={_fmt_value(r['sum'])}"
            )
    if report.rollups:
        print(f"rollups: {len(report.rollups)} banked")
    audit = doc.get("audit")
    if audit:
        fleet = audit["fleet"]
        print(
            "prediction audit: F-={:.2f}% F+={:.2f}% over {} commands "
            "({} templates, {} tasks)".format(
                fleet["false_negative_pct"], fleet["false_positive_pct"],
                fleet["commands"], len(audit["per_template"]),
                len(audit["per_task"]),
            )
        )
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    sub = ap.add_subparsers(dest="cmd", required=True)
    demo = sub.add_parser("demo", help="run a control-plane demo fleet")
    demo.add_argument("--crash", action="store_true",
                      help="inject a coordinator crash/recover cycle")
    demo.add_argument("--journal-out", type=Path,
                      default=Path("/tmp/msctl_journal.json"),
                      help="where to dump the decision journal")
    demo.set_defaults(fn=cmd_demo)
    jr = sub.add_parser("journal", help="pretty-print a journal dump")
    jr.add_argument("dump", type=Path)
    jr.set_defaults(fn=cmd_journal)
    st = sub.add_parser("status", help="offline lifecycle replay of a dump")
    st.add_argument("dump", type=Path)
    st.add_argument("--task", type=int, default=None,
                    help="show one task's state instead of the summary")
    st.set_defaults(fn=cmd_status)
    mt = sub.add_parser(
        "metrics", help="pretty-print a metrics report or tail a demo run"
    )
    mt.add_argument("report", type=Path, nargs="?", default=None,
                    help="a metrics-report-v1 JSON artifact")
    mt.add_argument("--prom", action="store_true",
                    help="emit Prometheus text exposition instead")
    mt.add_argument("--demo", action="store_true",
                    help="run the demo fleet traced and tail live rollups")
    mt.add_argument("--crash", action="store_true",
                    help="(with --demo) inject a coordinator crash cycle")
    mt.add_argument("--tail", type=int, default=8,
                    help="(with --demo) show the last N rollup rows")
    mt.add_argument("--out", type=Path, default=None,
                    help="(with --demo) also write the report JSON here")
    mt.set_defaults(fn=cmd_metrics)
    args = ap.parse_args()
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
