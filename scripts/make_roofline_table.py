"""Render results/dryrun.jsonl into the §Roofline markdown table.

    PYTHONPATH=src:. python scripts/make_roofline_table.py > results/roofline.md
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.roofline_report import load_rows, roofline_terms  # noqa: E402


def main():
    rows = load_rows()
    print("# Roofline table (TPU v5e constants; per-device terms)\n")
    print("| arch | shape | mesh | compute s | memory s | collective s | dominant | useful | roofline frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["status"] == "skipped":
            print(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped: {r['skip_reason']} | — | — |"
            )
            continue
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | | | | | |")
            continue
        t = roofline_terms(r)
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['t_compute_s']:.4f} | {t['t_memory_s']:.4f} "
            f"| {t['t_collective_s']:.4f} | {t['dominant']} "
            f"| {t['useful_ratio']:.2f} | {t['roofline_fraction']:.4f} |"
        )


if __name__ == "__main__":
    main()
