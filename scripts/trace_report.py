#!/usr/bin/env python3
"""Render or validate an exported ``msched-trace-v1`` Chrome trace.

Default mode prints a text report of the trace written by a ``--telemetry``
benchmark run (or ``Telemetry.write_chrome``):

  * the banked run summary (sim time, faults, switches, migrated bytes);
  * top stall sources — the stall-attribution ledger's six categories
    aggregated over tasks, ranked by total µs;
  * a per-link heatmap — peak/mean in-flight bytes and peak sharer count
    from the sampled counter probes;
  * the fault-coalescing ratio — working-set pages moved per planned
    migration (how many demand faults each proactive move replaced).

``--validate`` instead runs :func:`repro.telemetry.validate_trace` (schema
validity, monotone timestamps, balanced begin/end pairs, exact stall-ledger
conservation) and exits non-zero on any error — the CI telemetry smoke.

Usage: python scripts/trace_report.py out.trace [--validate] [--top 10]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict
from pathlib import Path

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)
from repro.telemetry import SCHEMA, STALL_CATEGORIES, validate_trace  # noqa: E402


def load(path: Path) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        # a bare event array is the other legal Chrome trace shape;
        # normalise so every downstream section can .get() on a dict
        doc = {"traceEvents": doc}
    return doc


def is_empty_trace(doc) -> bool:
    """True when the document holds no real (non-metadata) events — a
    zero-event run, not a malformed file."""
    if not isinstance(doc, dict):
        return False
    events = doc.get("traceEvents")
    return isinstance(events, list) and not any(
        isinstance(ev, dict) and ev.get("ph") != "M" for ev in events
    )


def run_validate(doc: dict, path: Path) -> int:
    errors = validate_trace(doc)
    for e in errors:
        print(f"TRACE INVALID: {e}", file=sys.stderr)
    if errors:
        return 1
    if is_empty_trace(doc):
        print(f"trace ok: {path} (empty trace — no events recorded)")
        return 0
    n_events = sum(
        1 for ev in doc.get("traceEvents", []) if ev.get("ph") != "M"
    )
    n_tracks = sum(
        1 for ev in doc.get("traceEvents", []) if ev.get("ph") == "M"
    )
    print(
        f"trace ok: {path} ({doc.get('otherData', {}).get('schema')}, "
        f"{n_events} events on {n_tracks} tracks, "
        f"{len(doc.get('stallLedger', {}))} ledger rows, "
        f"{doc.get('dropped_events', 0)} dropped)"
    )
    return 0


def _track_names(doc: dict) -> dict:
    """pid → track name, from the process_name metadata events."""
    return {
        ev["pid"]: ev.get("args", {}).get("name", f"pid{ev['pid']}")
        for ev in doc.get("traceEvents", [])
        if ev.get("ph") == "M" and ev.get("name") == "process_name"
    }


def stall_section(doc: dict, top: int) -> None:
    ledger = doc.get("stallLedger", {})
    if not ledger:
        print("stall ledger: (empty — no finished tasks in the trace)")
        return
    totals = {cat: 0.0 for cat in STALL_CATEGORIES}
    wall = non_compute = 0.0
    for row in ledger.values():
        for cat in STALL_CATEGORIES:
            totals[cat] += row.get(cat, 0.0)
        wall += row.get("wall_us", 0.0)
        non_compute += row.get("non_compute_us", 0.0)
    print(
        f"stall ledger: {len(ledger)} tasks, "
        f"{wall / 1e6:.3f}s wall, {non_compute / 1e6:.3f}s non-compute "
        f"({100.0 * non_compute / wall if wall else 0.0:.1f}%)"
    )
    print("top stall sources:")
    ranked = sorted(totals.items(), key=lambda kv: -kv[1])[:top]
    for cat, us in ranked:
        share = 100.0 * us / non_compute if non_compute else 0.0
        print(f"  {cat:<20} {us / 1e6:>10.4f}s  {share:5.1f}%")


def link_section(doc: dict) -> None:
    probes = doc.get("probes", {})
    links: dict = defaultdict(dict)
    for key, points in probes.items():
        track, _, name = key.rpartition("/")
        if track.startswith("link:"):
            links[track[len("link:"):]][name] = [v for _t, v in points]
    if not links:
        print("link heatmap: (no link probes — single-GPU or unsampled run)")
        return
    print("link heatmap:")
    print(f"  {'link':<18} {'peak inflight':>14} {'mean inflight':>14} "
          f"{'peak sharers':>13}")
    for link in sorted(links):
        vals = links[link]
        inflight = vals.get("inflight_bytes", [0])
        sharers = vals.get("sharers", [0])
        mean = sum(inflight) / len(inflight) if inflight else 0.0
        print(
            f"  {link:<18} {max(inflight) / 1e6:>12.2f}MB "
            f"{mean / 1e6:>12.2f}MB {max(sharers, default=0):>13}"
        )


def coalescing_section(doc: dict) -> None:
    names = _track_names(doc)
    plans = 0
    pages = 0
    per_track: dict = defaultdict(int)
    for ev in doc.get("traceEvents", []):
        if ev.get("name") == "migration_plan" and ev.get("ph") != "M":
            plans += 1
            pages += int(ev.get("args", {}).get("pages", 0))
            per_track[names.get(ev.get("pid"), "?")] += 1
    ratio = pages / max(1, plans)
    print(
        f"fault coalescing: {plans} planned migrations moved {pages} pages "
        f"-> {ratio:.1f} faults avoided per migration"
    )
    if per_track:
        origin = ", ".join(
            f"{tr}:{n}" for tr, n in sorted(per_track.items())
        )
        print(f"  plan origins: {origin}")


def run_report(doc: dict, path: Path, top: int) -> int:
    if not isinstance(doc, dict):
        print(f"trace report: {path}: not a trace document", file=sys.stderr)
        return 1
    schema = doc.get("otherData", {}).get("schema")
    if schema != SCHEMA:
        print(
            f"warning: schema {schema!r} != expected {SCHEMA!r}",
            file=sys.stderr,
        )
    print(f"trace report: {path}")
    if is_empty_trace(doc):
        print("empty trace — no events recorded")
        return 0
    summary = doc.get("summary", {})
    if summary:
        print("summary:")
        for k in sorted(summary):
            print(f"  {k} = {summary[k]}")
    if doc.get("dropped_events"):
        print(f"warning: {doc['dropped_events']} events dropped at the cap")
    print()
    stall_section(doc, top)
    print()
    link_section(doc)
    print()
    coalescing_section(doc)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", type=Path, help="Chrome trace JSON to read")
    ap.add_argument(
        "--validate", action="store_true",
        help="check schema/monotonicity/pairing/ledger-conservation and "
        "exit non-zero on any error",
    )
    ap.add_argument("--top", type=int, default=10,
                    help="stall categories to show in the report")
    args = ap.parse_args()
    doc = load(args.trace)
    if args.validate:
        return run_validate(doc, args.trace)
    return run_report(doc, args.trace, args.top)


if __name__ == "__main__":
    raise SystemExit(main())
