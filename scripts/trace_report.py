#!/usr/bin/env python3
"""Render or validate an exported ``msched-trace-v1`` Chrome trace.

Default mode prints a text report of the trace written by a ``--telemetry``
benchmark run (or ``Telemetry.write_chrome``):

  * the banked run summary (sim time, faults, switches, migrated bytes);
  * top stall sources — the stall-attribution ledger's six categories
    aggregated over tasks, ranked by total µs;
  * a per-link heatmap — peak/mean in-flight bytes and peak sharer count
    from the sampled counter probes;
  * the fault-coalescing ratio — working-set pages moved per planned
    migration (how many demand faults each proactive move replaced).

``--validate`` instead runs :func:`repro.telemetry.validate_trace` (schema
validity, monotone timestamps, balanced begin/end pairs, exact stall-ledger
conservation) and exits non-zero on any error — the CI telemetry smoke.

``--json`` emits the same report machine-readably: one JSON document with
``summary``, ``stalls`` (ranked sources + totals), ``links`` (per-link
peak/mean in-flight bytes, peak sharers), and ``coalescing`` (pages per
planned migration, per-track plan origins) — the shape the round-trip
test in ``tests/core/test_metrics_audit.py`` pins.

Usage: python scripts/trace_report.py out.trace [--validate|--json] [--top 10]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from collections import defaultdict
from pathlib import Path

sys.path.insert(
    0, os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")
)
from repro.telemetry import SCHEMA, STALL_CATEGORIES, validate_trace  # noqa: E402


def load(path: Path) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if isinstance(doc, list):
        # a bare event array is the other legal Chrome trace shape;
        # normalise so every downstream section can .get() on a dict
        doc = {"traceEvents": doc}
    return doc


def is_empty_trace(doc) -> bool:
    """True when the document holds no real (non-metadata) events — a
    zero-event run, not a malformed file."""
    if not isinstance(doc, dict):
        return False
    events = doc.get("traceEvents")
    return isinstance(events, list) and not any(
        isinstance(ev, dict) and ev.get("ph") != "M" for ev in events
    )


def run_validate(doc: dict, path: Path) -> int:
    errors = validate_trace(doc)
    for e in errors:
        print(f"TRACE INVALID: {e}", file=sys.stderr)
    if errors:
        return 1
    if is_empty_trace(doc):
        print(f"trace ok: {path} (empty trace — no events recorded)")
        return 0
    n_events = sum(
        1 for ev in doc.get("traceEvents", []) if ev.get("ph") != "M"
    )
    n_tracks = sum(
        1 for ev in doc.get("traceEvents", []) if ev.get("ph") == "M"
    )
    print(
        f"trace ok: {path} ({doc.get('otherData', {}).get('schema')}, "
        f"{n_events} events on {n_tracks} tracks, "
        f"{len(doc.get('stallLedger', {}))} ledger rows, "
        f"{doc.get('dropped_events', 0)} dropped)"
    )
    return 0


def _track_names(doc: dict) -> dict:
    """pid → track name, from the process_name metadata events."""
    return {
        ev["pid"]: ev.get("args", {}).get("name", f"pid{ev['pid']}")
        for ev in doc.get("traceEvents", [])
        if ev.get("ph") == "M" and ev.get("name") == "process_name"
    }


def stall_data(doc: dict, top: int = 10) -> dict:
    """The stall section as data: per-category totals ranked by µs."""
    ledger = doc.get("stallLedger", {})
    totals = {cat: 0.0 for cat in STALL_CATEGORIES}
    wall = non_compute = 0.0
    for row in ledger.values():
        for cat in STALL_CATEGORIES:
            totals[cat] += row.get(cat, 0.0)
        wall += row.get("wall_us", 0.0)
        non_compute += row.get("non_compute_us", 0.0)
    ranked = sorted(totals.items(), key=lambda kv: -kv[1])[:top]
    return {
        "tasks": len(ledger),
        "wall_us": wall,
        "non_compute_us": non_compute,
        "top_sources": [
            {
                "category": cat,
                "us": us,
                "share_pct": 100.0 * us / non_compute if non_compute else 0.0,
            }
            for cat, us in ranked
        ],
    }


def link_data(doc: dict) -> list:
    """The link heatmap as data: per-link peak/mean in-flight + sharers."""
    probes = doc.get("probes", {})
    links: dict = defaultdict(dict)
    for key, points in probes.items():
        track, _, name = key.rpartition("/")
        if track.startswith("link:"):
            links[track[len("link:"):]][name] = [v for _t, v in points]
    out = []
    for link in sorted(links):
        vals = links[link]
        inflight = vals.get("inflight_bytes", [0])
        sharers = vals.get("sharers", [0])
        out.append(
            {
                "link": link,
                "peak_inflight_bytes": max(inflight, default=0),
                "mean_inflight_bytes": (
                    sum(inflight) / len(inflight) if inflight else 0.0
                ),
                "peak_sharers": max(sharers, default=0),
            }
        )
    return out


def coalescing_data(doc: dict) -> dict:
    """Fault coalescing as data: pages moved per planned migration."""
    names = _track_names(doc)
    plans = 0
    pages = 0
    per_track: dict = defaultdict(int)
    for ev in doc.get("traceEvents", []):
        if ev.get("name") == "migration_plan" and ev.get("ph") != "M":
            plans += 1
            pages += int(ev.get("args", {}).get("pages", 0))
            per_track[names.get(ev.get("pid"), "?")] += 1
    return {
        "planned_migrations": plans,
        "pages_moved": pages,
        "pages_per_migration": pages / max(1, plans),
        "plan_origins": dict(sorted(per_track.items())),
    }


def json_report(doc: dict, top: int = 10) -> dict:
    """The machine-readable report behind ``--json``."""
    return {
        "schema": doc.get("otherData", {}).get("schema"),
        "empty": is_empty_trace(doc),
        "summary": doc.get("summary", {}),
        "dropped_events": doc.get("dropped_events", 0),
        "stalls": stall_data(doc, top),
        "links": link_data(doc),
        "coalescing": coalescing_data(doc),
    }


def stall_section(doc: dict, top: int) -> None:
    data = stall_data(doc, top)
    if not data["tasks"]:
        print("stall ledger: (empty — no finished tasks in the trace)")
        return
    wall, non_compute = data["wall_us"], data["non_compute_us"]
    print(
        f"stall ledger: {data['tasks']} tasks, "
        f"{wall / 1e6:.3f}s wall, {non_compute / 1e6:.3f}s non-compute "
        f"({100.0 * non_compute / wall if wall else 0.0:.1f}%)"
    )
    print("top stall sources:")
    for row in data["top_sources"]:
        print(
            f"  {row['category']:<20} {row['us'] / 1e6:>10.4f}s  "
            f"{row['share_pct']:5.1f}%"
        )


def link_section(doc: dict) -> None:
    links = link_data(doc)
    if not links:
        print("link heatmap: (no link probes — single-GPU or unsampled run)")
        return
    print("link heatmap:")
    print(f"  {'link':<18} {'peak inflight':>14} {'mean inflight':>14} "
          f"{'peak sharers':>13}")
    for row in links:
        print(
            f"  {row['link']:<18} "
            f"{row['peak_inflight_bytes'] / 1e6:>12.2f}MB "
            f"{row['mean_inflight_bytes'] / 1e6:>12.2f}MB "
            f"{row['peak_sharers']:>13}"
        )


def coalescing_section(doc: dict) -> None:
    data = coalescing_data(doc)
    print(
        f"fault coalescing: {data['planned_migrations']} planned migrations "
        f"moved {data['pages_moved']} pages "
        f"-> {data['pages_per_migration']:.1f} faults avoided per migration"
    )
    if data["plan_origins"]:
        origin = ", ".join(
            f"{tr}:{n}" for tr, n in data["plan_origins"].items()
        )
        print(f"  plan origins: {origin}")


def run_report(doc: dict, path: Path, top: int) -> int:
    if not isinstance(doc, dict):
        print(f"trace report: {path}: not a trace document", file=sys.stderr)
        return 1
    schema = doc.get("otherData", {}).get("schema")
    if schema != SCHEMA:
        print(
            f"warning: schema {schema!r} != expected {SCHEMA!r}",
            file=sys.stderr,
        )
    print(f"trace report: {path}")
    if is_empty_trace(doc):
        print("empty trace — no events recorded")
        return 0
    summary = doc.get("summary", {})
    if summary:
        print("summary:")
        for k in sorted(summary):
            print(f"  {k} = {summary[k]}")
    if doc.get("dropped_events"):
        print(f"warning: {doc['dropped_events']} events dropped at the cap")
    print()
    stall_section(doc, top)
    print()
    link_section(doc)
    print()
    coalescing_section(doc)
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", type=Path, help="Chrome trace JSON to read")
    ap.add_argument(
        "--validate", action="store_true",
        help="check schema/monotonicity/pairing/ledger-conservation and "
        "exit non-zero on any error",
    )
    ap.add_argument(
        "--json", action="store_true",
        help="emit the report as one machine-readable JSON document",
    )
    ap.add_argument("--top", type=int, default=10,
                    help="stall categories to show in the report")
    args = ap.parse_args()
    doc = load(args.trace)
    if args.validate:
        return run_validate(doc, args.trace)
    if args.json:
        if not isinstance(doc, dict):
            print(
                f"trace report: {args.trace}: not a trace document",
                file=sys.stderr,
            )
            return 1
        json.dump(json_report(doc, args.top), sys.stdout, indent=2)
        sys.stdout.write("\n")
        return 0
    return run_report(doc, args.trace, args.top)


if __name__ == "__main__":
    raise SystemExit(main())
