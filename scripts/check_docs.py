#!/usr/bin/env python3
"""Docs drift check: fail when README code blocks reference commands, flags,
or files that no longer exist.

Validates, for every fenced code block in README.md (and any extra markdown
files passed on the command line):

  * ``python -m <module>`` — the module resolves to a real file in the repo
    (external tools like pytest/pip are exempt);
  * ``--flag`` tokens on such lines — the literal flag string appears in the
    module's source (argparse definitions drift silently otherwise);
  * ``python <path>.py`` — the script exists;
  * ``pip install -r <file>`` — the requirements file exists.

Also checks that relative markdown links ``[...](path)`` point at existing
files. Run from anywhere: paths resolve against the repo root (this
script's parent's parent).

Usage: python scripts/check_docs.py [README.md docs/architecture.md ...]
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# modules invoked with -m that are not part of this repo
EXTERNAL_MODULES = {"pytest", "pip"}
# flags handled by tools we do not inspect
GENERIC_FLAGS = {"-m", "-x", "-q", "-r"}

FENCE_RE = re.compile(r"```[a-z]*\n(.*?)```", re.S)
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#]+)(?:#[^)]*)?\)")


def module_path(mod: str) -> Path | None:
    """Resolve a ``python -m`` target to a repo file (benchmarks/examples
    live at the root; library code under src/)."""
    for root in (REPO, REPO / "src"):
        cand = root / Path(*mod.split("."))
        if cand.with_suffix(".py").is_file():
            return cand.with_suffix(".py")
        if (cand / "__init__.py").is_file():
            return cand / "__init__.py"
    return None


def check_code_line(line: str, md: Path, errors: list[str]) -> None:
    tokens = line.split()
    if "python" not in [Path(t).name for t in tokens[:2]] and not any(
        t.startswith("python") for t in tokens
    ):
        return
    flags = [t for t in tokens if t.startswith("--")]
    if "-m" in tokens:
        mod = tokens[tokens.index("-m") + 1]
        base = mod.split(".")[0]
        if base in EXTERNAL_MODULES:
            return
        path = module_path(mod)
        if path is None:
            errors.append(f"{md.name}: no such module `{mod}`: {line.strip()}")
            return
        src = path.read_text()
        if path.name == "__init__.py":
            # a package CLI may define its argparse in sibling modules
            src = "\n".join(
                p.read_text() for p in sorted(path.parent.glob("*.py"))
            )
        for flag in flags:
            name = flag.split("=")[0]
            if name in GENERIC_FLAGS:
                continue
            if name not in src:
                errors.append(
                    f"{md.name}: `{mod}` no longer takes `{name}`: "
                    f"{line.strip()}"
                )
        return
    for tok in tokens:
        if tok.endswith(".py") and not tok.startswith("-"):
            if not (REPO / tok).is_file():
                errors.append(f"{md.name}: no such file `{tok}`: {line.strip()}")
    if "pip" in tokens and "-r" in tokens:
        req = tokens[tokens.index("-r") + 1]
        if not (REPO / req).is_file():
            errors.append(f"{md.name}: no such requirements file `{req}`")


def check_markdown(md: Path, errors: list[str]) -> None:
    text = md.read_text()
    for block in FENCE_RE.findall(text):
        for line in block.splitlines():
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            check_code_line(line, md, errors)
    for target in LINK_RE.findall(text):
        if "://" in target:
            continue
        if not (md.parent / target).exists() and not (REPO / target).exists():
            errors.append(f"{md.name}: broken link `{target}`")


def main() -> int:
    files = [Path(a) for a in sys.argv[1:]] or [
        REPO / "README.md",
        REPO / "docs" / "architecture.md",
        REPO / "docs" / "observability.md",
    ]
    errors: list[str] = []
    for md in files:
        if not md.is_file():
            errors.append(f"missing documentation file: {md}")
            continue
        check_markdown(md, errors)
    for e in errors:
        print(f"DOCS CHECK FAILED: {e}", file=sys.stderr)
    if not errors:
        print(f"docs check ok ({', '.join(f.name for f in files)})")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
