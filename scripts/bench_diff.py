#!/usr/bin/env python3
"""``bench_diff`` — gate the committed benchmark trajectory.

Compares a freshly-generated benchmark report against the committed
``BENCH_*.json`` artifact and fails (exit 1) when the trajectory
regresses:

* **Gate keys** (``meets_target``, ``results_identical``,
  ``recovery_beats_cold_at_every_mtbf``, ``journal_beats_cold_rt_miss``,
  ``chaos_clean``) are compared wherever both documents carry them —
  regardless of config — and any ``True -> False`` flip (or a gate that
  vanished from the fresh report) is a regression. This is the CI mode:
  smoke configs differ from the committed full-sweep configs, so the
  boolean gates are the cross-config contract.

* **Numeric metrics** are compared only inside subtrees whose shared
  *config keys* (seed, oversubscription, n_gpus, page size, MTBF, ...)
  agree between baseline and fresh — i.e. when the fresh run actually
  re-ran the committed configuration. Each metric gets a relative
  tolerance (per-metric table below, 10% default). Wall-clock-derived
  fields (``wall_s``, ``sim_us_per_wall_s``, wall-ratio speedups) are
  machine-dependent and always excluded.

Usage:
  python scripts/bench_diff.py BASELINE FRESH [BASELINE FRESH ...]
  python scripts/bench_diff.py BENCH_serving.json /tmp/fresh_serving.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Tuple

# boolean claims the repo stakes the paper reproduction on: a committed
# True may never silently become False
GATE_KEYS = frozenset(
    {
        "meets_target",
        "results_identical",
        "recovery_beats_cold_at_every_mtbf",
        "journal_beats_cold_rt_miss",
        "chaos_clean",
        "planned_beats_greedy_makespan",
        "planned_landing_error_not_worse",
    }
)

# identity of a benchmark configuration: numeric comparison is meaningful
# only where every shared config key matches
CONFIG_KEYS = frozenset(
    {
        "benchmark",
        "scenario",
        "seed",
        "arch",
        "tenants",
        "oversubscription",
        "ratio",
        "rate_rps",
        "rate_per_gpu",
        "duration_s",
        "n_gpus",
        "page_size",
        "page_kib",
        "capacity_bytes",
        "cap_per_gpu_bytes",
        "capacity_bytes_per_gpu",
        "n_requests",
        "n_schedules",
        "n_fault_events",
        "gpu_mtbf_us",
        "gpu_mttr_us",
        "coord_mtbf_us",
        "coord_mttr_us",
        "mtbf_us",
        "horizon_us",
        "checkpoint_period_us",
        "windows",
        "window_us",
        "rt_fraction",
        "hotspot_fraction",
        "nvlink_gbps",
        "planning",
        "pool",
        "tasks",
        "backend",
        "placement",
        "scale",
    }
)

# wall-clock-derived fields: machine-dependent, never diffed
_WALL_EXACT = frozenset(
    {
        "speedup",
        "speedup_vs_pr1",
        "target_speedup",
        "target_sweep_speedup_vs_pr1",
        "pr1_baseline_sim_us_per_wall_s",
    }
)


def _is_wall_key(key: str) -> bool:
    return "wall" in key or key in _WALL_EXACT


# per-metric relative tolerances; anything numeric not listed gets DEFAULT_REL
TOLERANCES: Dict[str, float] = {
    "goodput_per_s": 0.05,
    "throughput_per_s": 0.05,
    "goodput_ratio": 0.05,
    "goodput_gain_vs_leastloaded": 0.05,
    "ws_move_speedup": 0.05,
    "ttft_p50_us": 0.10,
    "ttft_p99_us": 0.15,
    "tpot_p50_us": 0.10,
    "tpot_p99_us": 0.15,
    "latency_p50_us": 0.10,
    "latency_p99_us": 0.15,
    "rt_miss_rate": 0.10,
    # deterministic simulator outputs: same config must reproduce exactly
    "sim_us": 0.0,
    "faults": 0.0,
    "switches": 0.0,
    "migrated_bytes": 0.0,
    "completions": 0.0,
    "control_us": 0.0,
}
DEFAULT_REL = 0.10


class Diff:
    """One comparison's accumulated findings."""

    def __init__(self) -> None:
        self.gate_failures: List[str] = []
        self.numeric_failures: List[str] = []
        self.compared_numeric = 0
        self.compared_gates = 0
        self.skipped_config = 0


def _config_matches(base: dict, fresh: dict) -> bool:
    for k in CONFIG_KEYS:
        if k in base and k in fresh and base[k] != fresh[k]:
            return False
    return True


def _rel_dev(a: float, b: float) -> float:
    if a == b:
        return 0.0
    denom = max(abs(a), abs(b))
    return abs(a - b) / denom if denom else 0.0


def _walk(path: str, base, fresh, diff: Diff, config_ok: bool) -> None:
    if isinstance(base, dict) and isinstance(fresh, dict):
        config_ok = config_ok and _config_matches(base, fresh)
        if not config_ok:
            diff.skipped_config += 1
        for k in sorted(set(base) & set(fresh)):
            sub = f"{path}.{k}" if path else k
            bv, fv = base[k], fresh[k]
            if k in GATE_KEYS:
                diff.compared_gates += 1
                if bv is True and fv is not True:
                    diff.gate_failures.append(
                        f"GATE {sub}: baseline True -> fresh {fv!r}"
                    )
                continue
            if _is_wall_key(k):
                continue
            _walk(sub, bv, fv, diff, config_ok)
        # a gate the fresh report dropped entirely is also a regression
        for k in sorted(set(base) - set(fresh)):
            if k in GATE_KEYS and base[k] is True:
                sub = f"{path}.{k}" if path else k
                diff.compared_gates += 1
                diff.gate_failures.append(
                    f"GATE {sub}: baseline True -> missing from fresh report"
                )
        return
    if isinstance(base, list) and isinstance(fresh, list):
        # pair rows positionally; per-row config keys (oversubscription,
        # n_gpus, page_kib, mtbf, ...) still guard the numeric comparison
        for i, (bv, fv) in enumerate(zip(base, fresh)):
            _walk(f"{path}[{i}]", bv, fv, diff, config_ok)
        return
    if isinstance(base, bool) or isinstance(fresh, bool):
        return  # non-gate booleans carry no trajectory contract
    if isinstance(base, (int, float)) and isinstance(fresh, (int, float)):
        if not config_ok:
            return
        key = path.rsplit(".", 1)[-1].split("[")[0]
        tol = TOLERANCES.get(key, DEFAULT_REL)
        diff.compared_numeric += 1
        dev = _rel_dev(float(base), float(fresh))
        if dev > tol:
            diff.numeric_failures.append(
                f"{path}: baseline={base!r} fresh={fresh!r} "
                f"(rel dev {dev * 100:.1f}% > tol {tol * 100:.1f}%)"
            )


def compare(baseline: Path, fresh: Path) -> Tuple[Diff, bool]:
    base = json.loads(baseline.read_text())
    new = json.loads(fresh.read_text())
    diff = Diff()
    _walk("", base, new, diff, config_ok=True)
    ok = not diff.gate_failures and not diff.numeric_failures
    return diff, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    ap.add_argument(
        "pairs", nargs="+", type=Path,
        metavar="BASELINE FRESH",
        help="alternating baseline/fresh report paths",
    )
    args = ap.parse_args(argv)
    if len(args.pairs) % 2:
        ap.error("need an even number of paths (BASELINE FRESH pairs)")

    failures = 0
    for baseline, fresh in zip(args.pairs[::2], args.pairs[1::2]):
        diff, ok = compare(baseline, fresh)
        verdict = "OK" if ok else "REGRESSION"
        print(
            f"[{verdict}] {baseline.name} vs {fresh}: "
            f"{diff.compared_gates} gate(s), "
            f"{diff.compared_numeric} numeric metric(s) compared, "
            f"{diff.skipped_config} subtree(s) skipped (config mismatch)"
        )
        for line in diff.gate_failures + diff.numeric_failures:
            print(f"  {line}")
        if not ok:
            failures += 1
    if failures:
        print(f"bench_diff: {failures} report(s) regressed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
