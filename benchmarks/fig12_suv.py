"""Fig. 12: MSched vs SUV (single-task static-prefetch) vs UM on the RTX 3080
testbed (microbenchmark workloads — SUV can't run closed-source kernels).
Paper: SUV <= UM in multitasking; MSched 7.18x over SUV at 300%."""
from repro.core.hardware import RTX3080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import MatMulTask, VecAddTask

from benchmarks.common import MSCHED_Q, UM_Q, timed

PAGE = 256 << 10


def _tasks():
    return [
        VecAddTask(0, n_bytes=384 << 20, kernels_per_iter=4, page_size=PAGE),
        VecAddTask(1, n_bytes=384 << 20, kernels_per_iter=4, page_size=PAGE),
        MatMulTask(2, dim=8192, n_matrices=8, page_size=PAGE),
        MatMulTask(3, dim=8192, n_matrices=8, page_size=PAGE),
    ]


def run():
    rows = []
    foot = sum(p.footprint_bytes() for p in _tasks())
    for ratio in (1.5, 2.0, 3.0):
        cap = int(foot / ratio)
        res = {}
        total_us = 0.0
        for b in ("um", "suv", "msched"):
            q = MSCHED_Q if b == "msched" else UM_Q
            r, us = timed(
                simulate, _tasks(), RTX3080, b, capacity_bytes=cap,
                sim_us=3_000_000, policy=RoundRobinPolicy(q),
            )
            res[b] = r.throughput_per_s()
            total_us += us
        rows.append(
            (
                f"fig12_sub{int(ratio * 100)}",
                total_us,
                f"um={res['um']:.1f};suv={res['suv']:.1f};msched={res['msched']:.1f};"
                f"msched_vs_suv={res['msched'] / max(res['suv'], 1e-9):.1f}x;"
                f"suv_vs_um={res['suv'] / max(res['um'], 1e-9):.2f}x",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
