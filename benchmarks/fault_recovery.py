"""Fault-injection benchmark: what does recovery quality buy a fleet that
actually fails?

Replays one seeded bursty hotspot trace over a 4-GPU NVLink fleet at 1.5x
HBM oversubscription while a seeded fault schedule (exponential
fail/repair cycles at a swept GPU MTBF) knocks devices out, and compares
three recovery policies on identical fault timelines:

  * **cold**        — the baseline: a victim restarts from the backing
    store at iteration 0; surviving linger copies and warm runs are
    reclaimed, every page faults back in, all progress replays;
  * **checkpoint**  — periodic working-set snapshots to host DRAM (priced
    as real D2H transfers that contend with migrations) let victims resume
    from their newest landed checkpoint;
  * **ckpt+linger** — the full recovery chain (``recovery="auto"``):
    progress-bearing checkpoint first, then a surviving peer linger copy
    harvested through the page directory, then cold — with capped
    exponential backoff when the staging budget denies a restore.

Headline metric: **cluster goodput** over a fixed horizon (offered window
plus a fixed drain) — cold restarts replay lost iterations and under
frequent failures keep missing the horizon, which is exactly the
degraded-mode capacity the recovery subsystem restores. Acceptance: the
checkpoint-based and checkpoint/linger-based arms beat the cold-restart
baseline on goodput at **every** injected MTBF.

A **journal arm** sweeps the *coordinator's* MTBF: engineered
coordinator-crash cycles (each failing a GPU while the control plane is
out, so victims strand in coordinator queues) run twice on identical
timelines — once with write-ahead journal replay, once with a cold
coordinator restart that forfeits the queues. Acceptance: the journal arm's
RT deadline-miss rate is strictly lower than cold restart's, aggregated
over the sweep.

A randomized **chaos suite** rides along: >= 25 seeded fault schedules
(GPU fail/recover, link degrade/restore flaps, task crashes) run on a
2-GPU fleet with the inline :class:`~repro.core.invariants.InvariantAuditor`
enabled at every fault boundary and rebalance tick; the suite must
complete with zero violations. ``--coordinator-chaos`` adds coordinator
crash/recover cycles to the schedules and runs every one under a
replay-checked journal control plane (the CI chaos smoke). Writes
``BENCH_faults.json``.

Usage: PYTHONPATH=src python -m benchmarks.fault_recovery [--smoke]
       [--gpus 4] [--ratio 1.5] [--rate 1.5] [--duration 6.0]
       [--chaos 25] [--coordinator-chaos]
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import time
from pathlib import Path
from typing import Dict, Optional, Sequence

from repro.cluster import FaultEvent, FaultInjector, simulate_cluster
from repro.cluster.topology import homogeneous
from repro.control import ControlPlane, DeadlineSpec
from repro.core.hardware import A100_40G, NVLINK_A100_GBPS
from repro.core.invariants import InvariantViolation
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import (
    MSchedAdmission,
    SLOSpec,
    ServedRequestTask,
    Trace,
    bursty_trace,
)

from benchmarks.common import (
    MSCHED_Q,
    export_telemetry,
    make_telemetry,
    print_json,
    write_json,
)
from benchmarks.p2p_prefetch import HotspotPlacement

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_faults.json"
TENANTS = ("qwen3-1.7b", "llama3.2-3b")
TARGET_CONCURRENCY = 3
# generous SLOs: goodput is completion-dominated, so the sweep measures
# recovered capacity rather than tail-latency noise
SLO = SLOSpec(ttft_us=5_000_000.0, tpot_us=100_000.0)
REBALANCE_US = 400_000.0
CHECKPOINT_US = 250_000.0
MTTR_US = 400_000.0
DRAIN_US = 6_000_000.0  # fixed post-window horizon shared by every arm
PAGE = 1 << 20

# (tag, engine recovery mode, checkpoint period)
ARMS = (
    ("cold", "cold", None),
    ("checkpoint", "checkpoint", CHECKPOINT_US),
    ("ckpt+linger", "auto", CHECKPOINT_US),
)

# coordinator-outage sweep: RT share of the trace and the deadline rubric
# both arms are scored against (bookkeeping only — deadline_period_us=None
# means no enforcement, so the arms differ purely in recovery mode)
RT_FRACTION = 0.4
COORD_MTTR_US = 400_000.0
DEADLINES = DeadlineSpec(rt_ttft_us=2_500_000.0, rt_latency_us=9_000_000.0)


def build_trace(
    n_gpus: int, rate_per_gpu: float, duration_s: float, seed: int,
    rt_fraction: float = 0.0,
) -> Trace:
    """Bursty arrivals with KV-heavy requests (long prompts, long decodes):
    failures mid-decode then have real progress to destroy."""
    tr = bursty_trace(
        rate_per_gpu * n_gpus, duration_s, seed=seed, cv=4.0,
        tenants=TENANTS, prompt_mean=256, output_mean=160, max_output=320,
        rt_fraction=rt_fraction,
    )
    rnd = random.Random(seed + 1)
    reqs = [
        dataclasses.replace(r, tenant=rnd.choice(TENANTS)) for r in tr.requests
    ]
    return Trace(reqs, dict(tr.meta, tenant_mix="iid"))


def mean_request_footprint(trace: Trace) -> float:
    feet: Dict[str, int] = {}
    for tenant in {r.tenant for r in trace}:
        req = next(r for r in trace if r.tenant == tenant)
        feet[tenant] = ServedRequestTask(
            99_000_000, req, page_size=PAGE
        ).footprint_bytes()
    return sum(feet[r.tenant] for r in trace) / len(trace)


def _fleet(n_gpus: int, cap_per_gpu: int):
    return homogeneous(
        n_gpus, A100_40G, capacity_bytes=cap_per_gpu,
        nvlink_gbps=NVLINK_A100_GBPS,
    )


def run_sweep(
    n_gpus: int = 4,
    ratio: float = 1.5,
    rate_per_gpu: float = 1.5,
    duration_s: float = 6.0,
    seed: int = 42,
    mtbfs_us: Sequence[float] = (500_000.0, 1_000_000.0, 2_000_000.0),
    telemetry=None,
) -> Dict[str, object]:
    """Goodput vs MTBF for the three recovery arms on identical fault
    timelines (same seeded schedule per MTBF, same trace, same fleet).
    ``telemetry`` (a hub) traces exactly one run — the full-recovery
    ``ckpt+linger`` arm at the first MTBF point."""
    trace = build_trace(n_gpus, rate_per_gpu, duration_s, seed)
    foot = mean_request_footprint(trace)
    cap_per_gpu = int(TARGET_CONCURRENCY * foot / ratio)
    dur_us = trace.duration_us()
    horizon_us = dur_us + DRAIN_US
    sweep: Dict[str, object] = {
        "n_gpus": n_gpus,
        "ratio": ratio,
        "rate_per_gpu": rate_per_gpu,
        "duration_s": duration_s,
        "seed": seed,
        "n_requests": len(trace),
        "cap_per_gpu_bytes": cap_per_gpu,
        "mean_footprint_bytes": foot,
        "horizon_us": horizon_us,
        "gpu_mttr_us": MTTR_US,
        "checkpoint_period_us": CHECKPOINT_US,
        "slo": {"ttft_us": SLO.ttft_us, "tpot_us": SLO.tpot_us},
        "mtbf_points": [],
    }
    for mtbf in mtbfs_us:
        schedule = FaultInjector.random(
            _fleet(n_gpus, cap_per_gpu), dur_us, seed=seed,
            gpu_mtbf_us=mtbf, gpu_mttr_us=MTTR_US,
        )
        point: Dict[str, object] = {
            "gpu_mtbf_us": mtbf,
            "n_fault_events": len(schedule.events),
            "arms": {},
        }
        for tag, mode, ckpt_us in ARMS:
            t0 = time.perf_counter()
            rep = simulate_cluster(
                trace,
                _fleet(n_gpus, cap_per_gpu),
                backend="msched",
                placement=HotspotPlacement(0.7, seed=seed),
                admission_factory=lambda i: MSchedAdmission(headroom=0.9),
                policy_factory=lambda i: RoundRobinPolicy(MSCHED_Q),
                page_size=PAGE,
                slo=SLO,
                sim_us=horizon_us,
                rebalance_period_us=REBALANCE_US,
                rebalance_threshold=0.4,
                faults=schedule,
                recovery=mode,
                shed_threshold=3.0,
                checkpoint_period_us=ckpt_us,
                telemetry=(
                    telemetry
                    if tag == "ckpt+linger" and mtbf == mtbfs_us[0]
                    else None
                ),
            )
            row = rep.to_row()
            row["wall_s"] = time.perf_counter() - t0
            point["arms"][tag] = row
        arms = point["arms"]
        point["goodput_vs_cold"] = {
            tag: arms[tag]["goodput_per_s"] - arms["cold"]["goodput_per_s"]
            for tag, _m, _c in ARMS
            if tag != "cold"
        }
        sweep["mtbf_points"].append(point)
    return sweep


def coordinator_schedule(
    coord_mtbf_us: float, dur_us: float
) -> FaultInjector:
    """Engineered coordinator crash cycles at a fixed cadence. Each cycle
    fails gpu0 shortly *after* the coordinator goes down and repairs it
    while the coordinator is still out: the victims strand in coordinator
    queues, which is exactly the state journal replay reconstructs and a
    cold restart forfeits."""
    events = []
    t = coord_mtbf_us
    while t + COORD_MTTR_US < dur_us:
        events += [
            FaultEvent(t, "coordinator_crash"),
            FaultEvent(t + 50_000.0, "gpu_fail", gpu="gpu0"),
            FaultEvent(t + 200_000.0, "gpu_recover", gpu="gpu0"),
            FaultEvent(t + COORD_MTTR_US, "coordinator_recover"),
        ]
        t += coord_mtbf_us
    return FaultInjector(events)


def run_journal_sweep(
    n_gpus: int = 2,
    ratio: float = 1.5,
    rate_per_gpu: float = 1.5,
    duration_s: float = 6.0,
    seed: int = 42,
    coord_mtbfs_us: Sequence[float] = (1_200_000.0, 2_400_000.0),
) -> Dict[str, object]:
    """Journal replay vs cold restart across a coordinator-MTBF sweep, on
    identical fault timelines and an RT-heavy trace. Per point: goodput
    delta, RT deadline-miss rates (scored by ``ControlPlane.finalize``
    against the shared ``DEADLINES`` rubric), and the mean completion
    latency of fault-interrupted requests (the recovery-latency proxy:
    cold restarts re-run interrupted work from scratch)."""
    trace = build_trace(
        n_gpus, rate_per_gpu, duration_s, seed, rt_fraction=RT_FRACTION
    )
    foot = mean_request_footprint(trace)
    cap_per_gpu = int(TARGET_CONCURRENCY * foot / ratio)
    dur_us = trace.duration_us()
    horizon_us = dur_us + DRAIN_US
    sweep: Dict[str, object] = {
        "n_gpus": n_gpus,
        "rt_fraction": RT_FRACTION,
        "coord_mttr_us": COORD_MTTR_US,
        "n_requests": len(trace),
        "deadlines": {
            "rt_ttft_us": DEADLINES.rt_ttft_us,
            "rt_latency_us": DEADLINES.rt_latency_us,
        },
        "coord_mtbf_points": [],
    }
    for mtbf in coord_mtbfs_us:
        schedule = coordinator_schedule(mtbf, dur_us)
        point: Dict[str, object] = {
            "coord_mtbf_us": mtbf,
            "n_fault_events": len(schedule.events),
            "arms": {},
            "rt_miss_rate": {},
            "interrupted_latency_us": {},
        }
        for mode in ("journal", "cold"):
            control = ControlPlane(
                deadlines=DEADLINES,
                recovery=mode,
                replay_check=(mode == "journal"),
            )
            t0 = time.perf_counter()
            rep = simulate_cluster(
                trace,
                _fleet(n_gpus, cap_per_gpu),
                backend="msched",
                placement="leastloaded",
                admission_factory=lambda i: MSchedAdmission(headroom=0.9),
                policy_factory=lambda i: RoundRobinPolicy(MSCHED_Q),
                page_size=PAGE,
                slo=SLO,
                sim_us=horizon_us,
                rebalance_period_us=REBALANCE_US,
                faults=schedule,
                recovery="auto",
                checkpoint_period_us=CHECKPOINT_US,
                control=control,
                audit=True,
            )
            row = rep.to_row()
            row["wall_s"] = time.perf_counter() - t0
            row["rt_requests"] = control.rt_requests
            point["arms"][mode] = row
            point["rt_miss_rate"][mode] = control.deadline_misses / max(
                1, control.rt_requests
            )
            hit = [
                r.latency_us()
                for r in rep.merged.requests
                if r.finished_us is not None
                and (
                    "failed_us" in r.meta
                    or "recovered_from" in r.meta
                    or "redispatched_from" in r.meta
                )
            ]
            point["interrupted_latency_us"][mode] = (
                sum(hit) / len(hit) if hit else None
            )
        point["goodput_journal_vs_cold"] = (
            point["arms"]["journal"]["goodput_per_s"]
            - point["arms"]["cold"]["goodput_per_s"]
        )
        sweep["coord_mtbf_points"].append(point)
    # aggregate RT miss rates over the whole sweep — the headline number
    for mode in ("journal", "cold"):
        misses = sum(
            p["arms"][mode]["deadline_misses"]
            for p in sweep["coord_mtbf_points"]
        )
        rts = sum(
            p["arms"][mode]["rt_requests"]
            for p in sweep["coord_mtbf_points"]
        )
        sweep[f"rt_miss_{mode}"] = misses / max(1, rts)
    return sweep


def run_chaos(
    n_schedules: int = 25,
    n_gpus: int = 2,
    rate_per_gpu: float = 2.0,
    duration_s: float = 2.0,
    ratio: float = 1.5,
    base_seed: int = 0,
    coordinator: bool = False,
    telemetry=None,
) -> Dict[str, object]:
    """Seeded randomized chaos suite: every schedule mixes GPU fail/repair
    cycles, link flaps, and task crashes, and runs with the inline auditor
    raising on any conservation/coherence violation. With ``coordinator``
    the schedules also crash/recover the control plane itself and every run
    attaches a journal-recovery :class:`ControlPlane` with ``replay_check``
    — any replay divergence raises and counts as a violation. ``telemetry``
    (a hub) traces the first schedule only."""
    runs = []
    violations = 0
    replays = 0
    for i in range(n_schedules):
        seed = base_seed + i
        trace = build_trace(
            n_gpus, rate_per_gpu, duration_s, seed,
            rt_fraction=RT_FRACTION if coordinator else 0.0,
        )
        while not len(trace):  # cv=4 bursts can leave a short window empty
            seed += 7919
            trace = build_trace(n_gpus, rate_per_gpu, duration_s, seed)
        foot = mean_request_footprint(trace)
        cap = int(TARGET_CONCURRENCY * foot / ratio)
        dur_us = trace.duration_us()
        schedule = FaultInjector.random(
            _fleet(n_gpus, cap), dur_us, seed=seed,
            gpu_mtbf_us=900_000.0, gpu_mttr_us=300_000.0,
            link_mtbf_us=1_100_000.0, link_mttr_us=150_000.0,
            crash_mtbf_us=1_300_000.0,
            coord_mtbf_us=800_000.0 if coordinator else None,
            coord_mttr_us=300_000.0,
        )
        control = (
            ControlPlane(recovery="journal", replay_check=True)
            if coordinator
            else None
        )
        row: Dict[str, object] = {
            "seed": seed,
            "n_requests": len(trace),
            "n_fault_events": len(schedule.events),
        }
        try:
            rep = simulate_cluster(
                trace,
                _fleet(n_gpus, cap),
                backend="msched",
                placement="msched",
                admission_factory=lambda i: MSchedAdmission(headroom=0.9),
                policy_factory=lambda i: RoundRobinPolicy(MSCHED_Q),
                page_size=PAGE,
                slo=SLO,
                drain_factor=14.0,
                rebalance_period_us=REBALANCE_US,
                faults=schedule,
                recovery="auto",
                checkpoint_period_us=300_000.0,
                control=control,
                audit=True,
                telemetry=telemetry if i == 0 else None,
            )
            replays += rep.journal_replays
            row.update(
                faults_applied=rep.faults_applied,
                recoveries=len(rep.recoveries),
                finished=rep.stats.n_finished,
                lost=rep.lost_requests,
                shed=rep.shed_requests,
                coordinator_crashes=rep.coordinator_crashes,
                journal_replays=rep.journal_replays,
                violation=None,
            )
        except InvariantViolation as exc:  # pragma: no cover - must not happen
            violations += 1
            row["violation"] = str(exc)
        runs.append(row)
    return {
        "n_schedules": n_schedules,
        "n_gpus": n_gpus,
        "coordinator": coordinator,
        "violations": violations,
        "total_faults_applied": sum(
            r.get("faults_applied", 0) for r in runs
        ),
        "total_recoveries": sum(r.get("recoveries", 0) for r in runs),
        "total_journal_replays": replays,
        "runs": runs,
    }


def run_bench(
    n_gpus: int = 4,
    ratio: float = 1.5,
    rate_per_gpu: float = 1.5,
    duration_s: float = 6.0,
    seed: int = 42,
    mtbfs_us: Sequence[float] = (500_000.0, 1_000_000.0, 2_000_000.0),
    n_chaos: int = 25,
    out_path: Optional[Path] = DEFAULT_OUT,
    strict: bool = True,
    telemetry_path: Optional[Path] = None,
    coordinator_chaos: bool = False,
    journal_duration_s: float = 6.0,
    coord_mtbfs_us: Sequence[float] = (1_200_000.0, 2_400_000.0),
) -> Dict[str, object]:
    tel = make_telemetry(telemetry_path)
    report: Dict[str, object] = {
        "benchmark": "fault_recovery",
        "sweep": run_sweep(
            n_gpus, ratio, rate_per_gpu, duration_s, seed, mtbfs_us,
            # with coordinator chaos on, the trace follows the chaos suite
            telemetry=None if coordinator_chaos else tel,
        ),
        "journal": run_journal_sweep(
            ratio=ratio, rate_per_gpu=rate_per_gpu,
            duration_s=journal_duration_s, seed=seed,
            coord_mtbfs_us=coord_mtbfs_us,
        ),
        "chaos": run_chaos(
            n_schedules=n_chaos, base_seed=seed,
            coordinator=coordinator_chaos,
            telemetry=tel if coordinator_chaos else None,
        ),
    }
    export_telemetry(tel, telemetry_path)
    # acceptance: at every injected MTBF, both checkpoint-based arms beat
    # the cold-restart baseline on goodput; the chaos suite is clean; and
    # journal replay strictly beats a cold coordinator restart on RT
    # deadline-miss rate (aggregated over the coordinator-MTBF sweep).
    # Smoke configs are too light to separate the arms (every request
    # finishes under any policy), so they gate on no-regression instead.
    recovery_wins = all(
        point["arms"][tag]["goodput_per_s"]
        > point["arms"]["cold"]["goodput_per_s"]
        if strict
        else point["arms"][tag]["goodput_per_s"]
        >= point["arms"]["cold"]["goodput_per_s"]
        for point in report["sweep"]["mtbf_points"]
        for tag in ("checkpoint", "ckpt+linger")
    )
    jr = report["journal"]
    journal_wins = (
        jr["rt_miss_journal"] < jr["rt_miss_cold"]
        if strict
        else jr["rt_miss_journal"] <= jr["rt_miss_cold"]
    )
    report["recovery_beats_cold_at_every_mtbf"] = recovery_wins
    report["journal_beats_cold_rt_miss"] = journal_wins
    report["chaos_clean"] = report["chaos"]["violations"] == 0
    report["meets_target"] = (
        recovery_wins and journal_wins and report["chaos_clean"]
    )
    if out_path is not None:
        write_json(out_path, report)
    return report


def run(telemetry_path=None):
    """benchmarks.run entry point."""
    report = run_bench(telemetry_path=telemetry_path)
    rows = []
    for point in report["sweep"]["mtbf_points"]:
        for tag in ("cold", "checkpoint", "ckpt+linger"):
            row = point["arms"][tag]
            derived = (
                f"goodput={row['goodput_per_s']:.2f}/s;"
                f"finished={row['n_finished']};"
                f"recoveries={row['recoveries']};"
                f"replayed_iters={row['replayed_iters']};"
                f"meets={report['meets_target']}"
            )
            rows.append((
                f"fault_recovery_mtbf{int(point['gpu_mtbf_us'] / 1000)}ms_{tag}",
                row["wall_s"] * 1e6,
                derived,
            ))
    for point in report["journal"]["coord_mtbf_points"]:
        for mode in ("journal", "cold"):
            row = point["arms"][mode]
            rows.append((
                f"fault_recovery_coord{int(point['coord_mtbf_us'] / 1000)}"
                f"ms_{mode}",
                row["wall_s"] * 1e6,
                f"goodput={row['goodput_per_s']:.2f}/s;"
                f"rt_miss={point['rt_miss_rate'][mode]:.3f};"
                f"replays={row['journal_replays']};"
                f"crashes={row['coordinator_crashes']}",
            ))
    chaos = report["chaos"]
    rows.append((
        "fault_recovery_chaos",
        0.0,
        f"schedules={chaos['n_schedules']};violations={chaos['violations']};"
        f"recoveries={chaos['total_recoveries']}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=1.5)
    ap.add_argument("--rate", type=float, default=1.5,
                    help="offered requests/s per GPU")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--chaos", type=int, default=25,
                    help="number of randomized audited fault schedules")
    ap.add_argument(
        "--out", type=Path, default=None,
        help=f"report path (default: {DEFAULT_OUT}; smoke mode writes "
        "only when --out is given explicitly)",
    )
    ap.add_argument(
        "--telemetry", type=Path, default=None, metavar="out.trace",
        help="export a Chrome trace of the ckpt+linger arm at the first "
        "MTBF (load in Perfetto, or run scripts/trace_report.py on it)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast CI config: 2 GPUs, one MTBF, 3 audited chaos schedules, "
        "no artifact",
    )
    ap.add_argument(
        "--coordinator-chaos", action="store_true",
        help="add coordinator crash/recover cycles to the chaos schedules "
        "and run each under a replay-checked journal control plane (the CI "
        "chaos smoke; any replay divergence fails the run)",
    )
    args = ap.parse_args()
    if args.smoke:
        report = run_bench(
            n_gpus=2, ratio=args.ratio, rate_per_gpu=args.rate,
            duration_s=3.0, seed=args.seed,
            mtbfs_us=(800_000.0,), n_chaos=3, out_path=args.out, strict=False,
            telemetry_path=args.telemetry,
            coordinator_chaos=args.coordinator_chaos,
            journal_duration_s=3.0, coord_mtbfs_us=(1_000_000.0,),
        )
    else:
        report = run_bench(
            args.gpus, args.ratio, args.rate, args.duration, args.seed,
            n_chaos=args.chaos, out_path=args.out or DEFAULT_OUT,
            telemetry_path=args.telemetry,
            coordinator_chaos=args.coordinator_chaos,
        )
    print_json(report)
    if not report["meets_target"]:
        raise SystemExit(
            "fault recovery benchmark failed acceptance: "
            f"recovery_beats_cold={report['recovery_beats_cold_at_every_mtbf']} "
            f"journal_beats_cold_rt={report['journal_beats_cold_rt_miss']} "
            f"chaos_clean={report['chaos_clean']}"
        )


if __name__ == "__main__":
    main()
