"""Cluster serving benchmark: placement policies on a heterogeneous fleet.

Replays one seeded **bursty** multi-tenant trace (two model sizes, i.i.d.
tenant draw so no placement policy gets an accidental parity gift) across
{2, 4, 8}-GPU heterogeneous clusters — alternating 1x/3x-capacity device
classes (A100-40G/A100-80G presets, differing swap bandwidths) — at a fixed
HBM oversubscription ratio, and compares:

  * **roundrobin**   — arrival order, load-blind;
  * **leastloaded**  — fewest active+queued tasks (count-based, the classic
    balancer; blind to memory and device capacity);
  * **msched**       — the MSched-aware bin-packer: best-fit of the
    arrival's footprint against per-GPU *predicted* working-set headroom;
  * **msched+mig**   — the packer plus periodic inter-GPU migration
    (checkpointed working-set moves over the link graph).

The regime is the paper's: bursts oversubscribe HBM while sustained compute
has headroom, so the cost of mispacking is admission queueing and TTFT blowup
on the small devices, not raw FLOP starvation. Headline metric: **cluster
goodput** (requests/s over the offered window meeting TTFT+TPOT SLOs).
Acceptance: msched beats leastloaded at every cluster size at ≥1.5x
oversubscription. Writes ``BENCH_cluster.json``.

Usage: PYTHONPATH=src python -m benchmarks.cluster_oversub [--smoke]
       [--gpus 2 4 8] [--ratio 1.5] [--rate 2.0] [--duration 6.0]
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import time
from pathlib import Path
from typing import Dict, Optional, Sequence

from repro.cluster import mixed, simulate_cluster
from repro.core.hardware import A100_40G, A100_80G
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import (
    MSchedAdmission,
    SLOSpec,
    ServedRequestTask,
    Trace,
    bursty_trace,
)

from benchmarks.common import (
    MSCHED_Q,
    export_telemetry,
    make_telemetry,
    print_json,
    write_json,
)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_cluster.json"
TENANTS = ("qwen3-1.7b", "llama3.2-3b")
TARGET_CONCURRENCY = 3  # per-GPU resident working sets the load wants
SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)
REBALANCE_US = 500_000.0
PAGE = 1 << 20

POLICY_VARIANTS = (
    ("roundrobin", "roundrobin", None),
    ("leastloaded", "leastloaded", None),
    ("msched", "msched", None),
    ("msched+mig", "msched", REBALANCE_US),
)


def build_trace(
    n_gpus: int, rate_per_gpu: float, duration_s: float, seed: int
) -> Trace:
    """Bursty arrivals at cluster rate n x per-GPU rate; tenants drawn
    i.i.d. (the generator's deterministic alternation would correlate with
    round-robin placement parity and hand it an optimal pairing)."""
    tr = bursty_trace(
        rate_per_gpu * n_gpus, duration_s, seed=seed, cv=4.0,
        tenants=TENANTS, prompt_mean=128, output_mean=96, max_output=192,
    )
    rnd = random.Random(seed + 1)
    reqs = [
        dataclasses.replace(r, tenant=rnd.choice(TENANTS)) for r in tr.requests
    ]
    return Trace(reqs, dict(tr.meta, tenant_mix="iid"))


def build_topology(n_gpus: int, cap_per_gpu: int):
    """Alternating small/large device classes at a 1:3 capacity split (the
    pair sums to 2x the nominal per-GPU capacity, so total capacity matches
    the homogeneous cluster every policy is sized against)."""
    nodes = []
    for i in range(n_gpus):
        if i % 2 == 0:
            nodes.append((A100_40G, cap_per_gpu // 2))
        else:
            nodes.append((A100_80G, 3 * cap_per_gpu // 2))
    return mixed(nodes)


def mean_request_footprint(trace: Trace) -> float:
    feet: Dict[str, int] = {}
    for tenant in {r.tenant for r in trace}:
        req = next(r for r in trace if r.tenant == tenant)
        feet[tenant] = ServedRequestTask(
            99_000_000, req, page_size=PAGE
        ).footprint_bytes()
    return sum(feet[r.tenant] for r in trace) / len(trace)


def run_bench(
    gpu_counts: Sequence[int] = (2, 4, 8),
    ratio: float = 1.5,
    rate_per_gpu: float = 2.0,
    duration_s: float = 6.0,
    seed: int = 42,
    variants=POLICY_VARIANTS,
    drain_factor: float = 8.0,
    out_path: Optional[Path] = DEFAULT_OUT,
    telemetry_path: Optional[Path] = None,
) -> Dict[str, object]:
    # one traced run per invocation: the last policy variant at the
    # smallest fleet (msched+mig in the full sweep — the variant whose
    # migrations the trace is most interesting for)
    tel = make_telemetry(telemetry_path)
    report: Dict[str, object] = {
        "benchmark": "cluster_oversub",
        "ratio": ratio,
        "rate_per_gpu": rate_per_gpu,
        "duration_s": duration_s,
        "seed": seed,
        "tenants": list(TENANTS),
        "target_concurrency": TARGET_CONCURRENCY,
        "slo": {"ttft_us": SLO.ttft_us, "tpot_us": SLO.tpot_us},
        "sweep": [],
    }
    for n in gpu_counts:
        trace = build_trace(n, rate_per_gpu, duration_s, seed)
        foot = mean_request_footprint(trace)
        cap_per_gpu = int(TARGET_CONCURRENCY * foot / ratio)
        row: Dict[str, object] = {
            "n_gpus": n,
            "n_requests": len(trace),
            "offered_rps": trace.offered_rate_rps(),
            "cap_per_gpu_bytes": cap_per_gpu,
            "mean_footprint_bytes": foot,
        }
        for tag, placement, rebalance in variants:
            t0 = time.perf_counter()
            rep = simulate_cluster(
                trace,
                build_topology(n, cap_per_gpu),
                backend="msched",
                placement=placement,
                admission_factory=lambda i: MSchedAdmission(headroom=0.9),
                policy_factory=lambda i: RoundRobinPolicy(MSCHED_Q),
                page_size=PAGE,
                slo=SLO,
                drain_factor=drain_factor,
                rebalance_period_us=rebalance,
                rebalance_threshold=0.4,
                telemetry=(
                    tel
                    if tag == variants[-1][0] and n == gpu_counts[0]
                    else None
                ),
            )
            r = rep.to_row()
            r["wall_s"] = time.perf_counter() - t0
            row[tag] = r
        ll = row["leastloaded"]["goodput_per_s"]
        ms = row["msched"]["goodput_per_s"]
        row["goodput_gain_vs_leastloaded"] = ms / ll if ll > 0 else None
        report["sweep"].append(row)

    # acceptance: the MSched-aware packer beats the count balancer on
    # cluster goodput at every fleet size, under pressure (ratio >= 1.5)
    report["meets_target"] = ratio < 1.5 or all(
        row["msched"]["goodput_per_s"] > row["leastloaded"]["goodput_per_s"]
        for row in report["sweep"]
    )
    export_telemetry(tel, telemetry_path)
    if out_path is not None:
        write_json(out_path, report)
    return report


def run(telemetry_path=None):
    """benchmarks.run entry point (the {2,4} slice keeps the full-suite wall
    time reasonable; the standalone CLI sweeps {2,4,8})."""
    report = run_bench(gpu_counts=(2, 4), telemetry_path=telemetry_path)
    rows = []
    for row in report["sweep"]:
        ms = row["msched"]
        derived = (
            f"goodput_msched={ms['goodput_per_s']:.2f}/s;"
            f"goodput_leastloaded={row['leastloaded']['goodput_per_s']:.2f}/s;"
            f"goodput_rr={row['roundrobin']['goodput_per_s']:.2f}/s;"
            f"goodput_mig={row['msched+mig']['goodput_per_s']:.2f}/s;"
            f"migrations={row['msched+mig']['migrations']};"
            f"meets={report['meets_target']}"
        )
        rows.append(
            (f"cluster_oversub_{row['n_gpus']}gpu", ms["wall_s"] * 1e6, derived)
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gpus", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--ratio", type=float, default=1.5)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="offered requests/s per GPU")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--out", type=Path, default=None,
        help=f"report path (default: {DEFAULT_OUT}; smoke mode writes "
        "only when --out is given explicitly)",
    )
    ap.add_argument(
        "--telemetry", type=Path, default=None, metavar="out.trace",
        help="export a Chrome trace of the last policy variant at the "
        "smallest fleet size",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast CI config: 2 GPUs, short trace, packer-vs-leastloaded only",
    )
    args = ap.parse_args()
    if args.smoke:
        report = run_bench(
            gpu_counts=(2,), ratio=args.ratio, rate_per_gpu=args.rate,
            duration_s=3.0, seed=args.seed, out_path=args.out,
            variants=[v for v in POLICY_VARIANTS if v[0] in
                      ("leastloaded", "msched")],
            telemetry_path=args.telemetry,
        )
    else:
        report = run_bench(
            tuple(args.gpus), args.ratio, args.rate, args.duration,
            args.seed, out_path=args.out or DEFAULT_OUT,
            telemetry_path=args.telemetry,
        )
    print_json(report)
    if not report["meets_target"]:
        raise SystemExit(
            "MSched-aware placement did not beat least-loaded under pressure"
        )


if __name__ == "__main__":
    main()
