"""Fig. 6: microbenchmark (2x vecadd + 2x matmul) sweeping memory
subscription — throughput vs Ideal, page faults, and migration volume per
task completion. Paper: UM cliffs ~16x at >100%; MSched ~9.7x over UM at
200% and stays near Ideal; at 100% MSched overhead is 0.59%."""
from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import MatMulTask, VecAddTask

from benchmarks.common import MSCHED_Q, UM_Q, timed

PAGE = 256 << 10


def _tasks():
    return [
        VecAddTask(0, n_bytes=512 << 20, kernels_per_iter=4, page_size=PAGE),
        VecAddTask(1, n_bytes=512 << 20, kernels_per_iter=4, page_size=PAGE),
        MatMulTask(2, dim=8192, n_matrices=12, page_size=PAGE),
        MatMulTask(3, dim=8192, n_matrices=12, page_size=PAGE),
    ]


def run():
    rows = []
    tasks = _tasks()
    foot = sum(p.footprint_bytes() for p in tasks)
    base = simulate(
        _tasks(), RTX5080, "msched", capacity_bytes=int(foot * 1.05),
        sim_us=2_000_000, policy=RoundRobinPolicy(MSCHED_Q),
    ).throughput_per_s()
    for ratio in (1.0, 1.5, 2.0, 3.0):
        # 2% headroom at 100%: exactly-full LRU batch eviction thrashes
        cap = int(foot * 1.02) if ratio == 1.0 else int(foot / ratio)
        res = {}
        t_total = 0.0
        for b in ("um", "msched", "ideal"):
            q = UM_Q if b == "um" else MSCHED_Q
            r, us = timed(
                simulate,
                _tasks(),
                RTX5080,
                b,
                capacity_bytes=cap,
                sim_us=3_000_000,
                policy=RoundRobinPolicy(q),
            )
            res[b] = r
            t_total += us
        um, ms, idl = (res[b] for b in ("um", "msched", "ideal"))
        c = lambda r: max(r.total_completions(), 1)
        rows.append(
            (
                f"fig06_sub{int(ratio * 100)}",
                t_total,
                f"um={um.throughput_per_s() / base:.4f};msched={ms.throughput_per_s() / base:.4f};"
                f"ideal={idl.throughput_per_s() / base:.4f};"
                f"um_faults_per_task={um.faults / c(um):.0f};"
                f"msched_faults_per_task={ms.faults / c(ms):.2f};"
                f"um_migGB_per_task={um.migrated_bytes / 1e9 / c(um):.3f};"
                f"msched_migGB_per_task={ms.migrated_bytes / 1e9 / c(ms):.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
