"""Fig. 11: MSched control-plane (madvise) overhead per context switch vs
task count — REAL measured wall time of our coordinator implementation, plus
the calibrated model's µs accounting. Paper: linear in task count, <1 ms for
tens of tasks."""
import time

from repro.core.hardware import RTX5080
from repro.core.hbm import HBMPool
from repro.core.memory_manager import Coordinator, TaskHelper
from repro.core.predictor import OraclePredictor
from repro.core.scheduler import RoundRobinPolicy, SchedTask
from repro.core.timeline import TaskTimeline
from repro.core.workloads import VecAddTask

PAGE = 256 << 10


def run():
    rows = []
    for n_tasks in (2, 4, 8, 16, 32):
        progs = [
            VecAddTask(i, n_bytes=128 << 20, kernels_per_iter=2, page_size=PAGE)
            for i in range(n_tasks)
        ]
        foot = sum(p.footprint_bytes() for p in progs)
        pool = HBMPool(max(1, int(foot / 1.5) // PAGE))
        coord = Coordinator(RTX5080, pool, page_size=PAGE)
        helpers = {}
        for p in progs:
            h = TaskHelper(p.task_id, p.space, OraclePredictor())
            helpers[p.task_id] = h
            coord.register(h)
            for it in range(2):
                for cmd in p.iteration(it):
                    h.launch(cmd)
        policy = RoundRobinPolicy(50_000.0)
        sched = {p.task_id: SchedTask(p.task_id) for p in progs}
        # measure a steady-state switch (first switches populate)
        walls, madv = [], []
        for i in range(2 * n_tasks + 4):
            entry = policy.next_entry(sched)
            tl = TaskTimeline([entry] + policy.timeline(sched).entries)
            t0 = time.perf_counter()
            rep = coord.on_context_switch(entry.task_id, tl)
            walls.append(time.perf_counter() - t0)
            madv.append(rep.madvise_us)
        steady = walls[n_tasks:]
        rows.append(
            (
                f"fig11_tasks{n_tasks}",
                sum(steady) / len(steady) * 1e6,
                f"model_madvise_us={sum(madv[n_tasks:]) / len(madv[n_tasks:]):.0f};"
                f"real_coordinator_ms={sum(steady) / len(steady) * 1e3:.2f}",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
