"""Fig. 7: end-to-end throughput for combos A-D under Light/Medium/Heavy
pressure. Paper speedups msched/um: A-C avg 11.05/9.35/7.52x,
D: 57.88/44.79/33.60x."""
from benchmarks.common import bench_combo, timed


def run():
    rows = []
    for name in ("A", "B", "C", "D"):
        for scale, label in ((1.5, "light"), (2.0, "medium"), (3.0, "heavy")):
            r, us = timed(bench_combo, name, scale, ("um", "msched"))
            um = r["um"].throughput_per_s() / max(r["base"], 1e-9)
            ms = r["msched"].throughput_per_s() / max(r["base"], 1e-9)
            rows.append(
                (
                    f"fig07_{name}_{label}",
                    us,
                    f"oversub={r['oversub']:.2f};um={um:.4f};msched={ms:.4f};"
                    f"speedup={ms / max(um, 1e-9):.1f}x",
                )
            )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
