"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. ``python -m benchmarks.run`` runs
everything; ``--only fig07`` filters by prefix. ``--profile`` wraps each
module's run() in cProfile and prints its top-20 cumulative-time entries to
stderr — the supported way to find the simulator's current hot path (see
EXPERIMENTS.md, "Profiling the simulator"). ``--telemetry DIR`` passes a
``DIR/<module>.trace`` Chrome-trace path to every module whose ``run()``
accepts ``telemetry_path`` (the serving/cluster/fault benchmarks).
"""
import argparse
import cProfile
import inspect
import io
import pstats
import sys
import traceback
from pathlib import Path

MODULES = [
    "fig01_llm_multitask",
    "fig02_access_pattern",
    "table1_prediction_accuracy",
    "table2_template_mix",
    "fig06_microbench",
    "fig07_end_to_end",
    "fig08_prediction_ablation",
    "fig09_pipeline",
    "fig10_hardware",
    "fig11_overhead",
    "fig12_suv",
    "fig13_rt_be",
    "sim_throughput",
    "serve_oversub",
    "cluster_oversub",
    "p2p_prefetch",
    "fault_recovery",
    "kernels_bench",
    "roofline_report",
]

PROFILE_TOP_N = 20


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="module name prefix filter")
    ap.add_argument(
        "--profile", action="store_true",
        help=f"cProfile each module; print top-{PROFILE_TOP_N} by cumulative "
        "time to stderr",
    )
    ap.add_argument(
        "--telemetry", type=Path, default=None, metavar="DIR",
        help="write a DIR/<module>.trace Chrome trace for each module whose "
        "run() accepts telemetry_path",
    )
    args = ap.parse_args()
    if args.telemetry is not None:
        args.telemetry.mkdir(parents=True, exist_ok=True)

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        if args.only and not mod_name.startswith(args.only):
            continue
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            kwargs = {}
            if (
                args.telemetry is not None
                and "telemetry_path"
                in inspect.signature(mod.run).parameters
            ):
                kwargs["telemetry_path"] = (
                    args.telemetry / f"{mod_name}.trace"
                )
            if args.profile:
                prof = cProfile.Profile()
                rows = prof.runcall(mod.run, **kwargs)
                buf = io.StringIO()
                stats = pstats.Stats(prof, stream=buf)
                stats.sort_stats("cumulative").print_stats(PROFILE_TOP_N)
                print(f"==== profile: {mod_name} ====", file=sys.stderr)
                print(buf.getvalue(), file=sys.stderr, flush=True)
            else:
                rows = mod.run(**kwargs)
            for name, us, derived in rows:
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{mod_name},0,ERROR", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
