"""Fig. 10: RTX 5080 (16 GB, PCIe5) vs RTX 3080 (10 GB, PCIe4) under equal
oversubscribed volume and equal ratio. Paper: at equal volume the 5080 wins
(bandwidth), at equal ratio they converge (smaller absolute volume masks the
3080's bandwidth deficit)."""
from repro.core.hardware import RTX3080, RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

from benchmarks.common import MSCHED_Q, PAGE, timed


def _thr(plat, cap_bytes, scale=1.0):
    progs = combo("D", page_size=PAGE["D"], scale=scale)
    r = simulate(
        progs, plat, "msched", capacity_bytes=cap_bytes,
        sim_us=3_000_000, policy=RoundRobinPolicy(MSCHED_Q),
    )
    return r.throughput_per_s()


def run():
    rows = []
    # equal oversubscribed VOLUME: footprint - capacity = const (6 GiB)
    vol = 6 << 30
    progs = combo("D", page_size=PAGE["D"], scale=1.0)
    foot = sum(p.footprint_bytes() for p in progs)
    for plat in (RTX5080, RTX3080):
        t, us = timed(_thr, plat, max(foot - vol, 1 << 30))
        rows.append((f"fig10a_equal_volume_{plat.name}", us, f"thr={t:.1f}"))
    # equal oversubscription RATIO (150%)
    for plat in (RTX5080, RTX3080):
        t, us = timed(_thr, plat, int(foot / 1.5))
        rows.append((f"fig10b_equal_ratio_{plat.name}", us, f"thr={t:.1f}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
