"""Shared benchmark helpers.

Calibration (validated against the paper, see EXPERIMENTS.md):
  * MSched runs under XSched-style scheduling with ~350 ms timeslices
    (≈20 decode steps/slice); the UM baseline runs under the commodity GPU
    TSG timeslice (~2 ms) — the paper's native demand-paging setup.
  * Simulation pages are 1 MiB for LLM workloads (footprints in GiB), 256 KiB
    for DNNs, 64 KiB for SciComp; fault costs are page-size-corrected.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.hardware import RTX3080, RTX5080
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

MSCHED_Q = 350_000.0
UM_Q = 2_000.0

PAGE = {"A": 64 << 10, "B": 256 << 10, "C": 1 << 20, "D": 1 << 20}
SIM_US = 4_000_000.0

Row = Dict[str, object]


def bench_combo(
    name: str,
    scale: float,
    backends=("um", "msched"),
    platform=RTX5080,
    sim_us: float = SIM_US,
) -> Dict[str, object]:
    """Oversubscription = ``scale``. Combo D reaches it the paper's way
    (more model instances over the fixed HBM); A-C scale the capacity to
    footprint/scale — equivalent ratio, avoids Python-side command explosion
    from scaling problem sizes."""
    if name == "D":
        progs = combo(name, page_size=PAGE[name], scale=scale)
        foot = sum(p.footprint_bytes() for p in progs)
        cap = platform.hbm_bytes
    else:
        progs = combo(name, page_size=PAGE[name], scale=1.0)
        foot = sum(p.footprint_bytes() for p in progs)
        cap = int(foot / scale)
    base = simulate(
        progs,
        platform,
        "msched",
        capacity_bytes=int(foot * 1.05),
        sim_us=sim_us / 2,
        policy=RoundRobinPolicy(MSCHED_Q),
    ).throughput_per_s()
    out = {"combo": name, "scale": scale, "base": base, "oversub": foot / cap}
    for b in backends:
        q = UM_Q if b in ("um", "suv") else MSCHED_Q
        res = simulate(
            progs,
            platform,
            b,
            capacity_bytes=cap,
            sim_us=sim_us,
            policy=RoundRobinPolicy(q),
        )
        out[b] = res
    return out


def _json_default(obj):
    """Artifact serialization: anything exposing ``to_json()`` (notably
    ``ClusterReport``) serializes through it; other non-JSON leaves fall
    back to ``str`` (the historical behavior every writer hand-rolled)."""
    to_json = getattr(obj, "to_json", None)
    if callable(to_json):
        return to_json()
    return str(obj)


def write_json(path, payload: Dict[str, object]) -> None:
    """The shared ``BENCH_*.json`` artifact writer."""
    normalized = json.loads(json.dumps(payload, default=_json_default))
    Path(path).write_text(json.dumps(normalized, indent=2) + "\n")


def print_json(payload: Dict[str, object]) -> None:
    print(json.dumps(
        json.loads(json.dumps(payload, default=_json_default)), indent=2
    ))


def make_telemetry(telemetry_path: Optional[str], metrics_path: Optional[str] = None):
    """Build a :class:`repro.telemetry.Telemetry` hub when a ``--telemetry``
    or ``--metrics`` path was given, else ``None`` (the benchmark runs
    untraced).  Traced hubs carry the metrics registry and the prediction
    auditor — both are observer-only, so results stay bit-for-bit identical."""
    if telemetry_path is None and metrics_path is None:
        return None
    from repro.telemetry import Telemetry

    return Telemetry(metrics=True, audit=True)


def export_telemetry(tel, telemetry_path) -> None:
    """Write the hub's Chrome trace (load in Perfetto, or feed to
    ``scripts/trace_report.py``). No-op when the benchmark ran untraced."""
    if tel is None or telemetry_path is None:
        return
    tel.write_chrome(telemetry_path)
    print(f"telemetry: wrote Chrome trace to {telemetry_path}")


def export_metrics(tel, metrics_path) -> None:
    """Write the hub's versioned ``metrics-report-v1`` JSON artifact
    (pretty-print or scrape it via ``scripts/msctl.py metrics``). No-op
    when the benchmark ran untraced or the hub has no registry."""
    if tel is None or metrics_path is None or tel.metrics is None:
        return
    tel.metrics_report().write(metrics_path)
    print(f"telemetry: wrote metrics report to {metrics_path}")


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: List[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
