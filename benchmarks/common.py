"""Shared benchmark helpers.

Calibration (validated against the paper, see EXPERIMENTS.md):
  * MSched runs under XSched-style scheduling with ~350 ms timeslices
    (≈20 decode steps/slice); the UM baseline runs under the commodity GPU
    TSG timeslice (~2 ms) — the paper's native demand-paging setup.
  * Simulation pages are 1 MiB for LLM workloads (footprints in GiB), 256 KiB
    for DNNs, 64 KiB for SciComp; fault costs are page-size-corrected.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core.hardware import RTX3080, RTX5080
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

MSCHED_Q = 350_000.0
UM_Q = 2_000.0

PAGE = {"A": 64 << 10, "B": 256 << 10, "C": 1 << 20, "D": 1 << 20}
SIM_US = 4_000_000.0

Row = Dict[str, object]


def bench_combo(
    name: str,
    scale: float,
    backends=("um", "msched"),
    platform=RTX5080,
    sim_us: float = SIM_US,
) -> Dict[str, object]:
    """Oversubscription = ``scale``. Combo D reaches it the paper's way
    (more model instances over the fixed HBM); A-C scale the capacity to
    footprint/scale — equivalent ratio, avoids Python-side command explosion
    from scaling problem sizes."""
    if name == "D":
        progs = combo(name, page_size=PAGE[name], scale=scale)
        foot = sum(p.footprint_bytes() for p in progs)
        cap = platform.hbm_bytes
    else:
        progs = combo(name, page_size=PAGE[name], scale=1.0)
        foot = sum(p.footprint_bytes() for p in progs)
        cap = int(foot / scale)
    base = simulate(
        progs,
        platform,
        "msched",
        capacity_bytes=int(foot * 1.05),
        sim_us=sim_us / 2,
        policy=RoundRobinPolicy(MSCHED_Q),
    ).throughput_per_s()
    out = {"combo": name, "scale": scale, "base": base, "oversub": foot / cap}
    for b in backends:
        q = UM_Q if b in ("um", "suv") else MSCHED_Q
        res = simulate(
            progs,
            platform,
            b,
            capacity_bytes=cap,
            sim_us=sim_us,
            policy=RoundRobinPolicy(q),
        )
        out[b] = res
    return out


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6


def emit(rows: List[tuple]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
