"""Transfer-storm benchmark: scheduled link-graph planning vs greedy.

Drives a deterministic migration storm over a 4-GPU A100 fleet wired as a
*partial* NVLink ring (gpu0-1, 1-2, 2-3, 3-0 — opposite pairs have no direct
edge), at 1.5x and 2x link oversubscription: per submission window, the
storm's aggregate solo transfer time demands that multiple of the window's
host-link capacity. The mix is the cluster engine's real traffic — RT
restores, best-effort restores, peer fetches, vault snapshots, and
speculative rebalance checkpoints across both adjacent (NVLink) and
opposite (host-staged) pairs.

Both systems price the *same* request storm:

  * **greedy** — ``ClusterTopology.plan_transfer`` per request, in arrival
    order: fluid-at-start shares, host staging for opposite pairs, no
    urgency classes.
  * **planned** — ``TransferPlanner.submit`` per window: urgency-ordered
    admission, piecewise-constant shares with rebooking, NVLink detours
    around saturated host legs, speculative deferral (deferred moves retry
    at the next window, like the engine's rebalance protocol).

Truth is one shared event-loop replay of the equal-share fluid model over
each system's *actual* routes and start times. Headline metrics:

  * **makespan_us** — when the storm's last byte lands (truth);
  * **p99_landing_error_us** — p99 of |estimated landing - true landing|:
    greedy estimates go stale the moment a sharer drains, the planner
    rebooks so its committed plans track the truth.

Acceptance (``planned_beats_greedy_makespan``): the planned makespan is
strictly lower than greedy at every oversubscription level, and the planned
p99 landing error is no worse (``planned_landing_error_not_worse``).
Writes ``BENCH_transfer.json``.

Usage: PYTHONPATH=src python -m benchmarks.transfer_storm [--smoke]
       [--ratios 1.5 2.0] [--windows 8] [--seed 7] [--telemetry PATH]
"""
from __future__ import annotations

import argparse
import math
import random
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.cluster.topology import (
    HOST,
    ClusterTopology,
    GPUNode,
    TransferPlan,
)
from repro.cluster.transfer_plan import (
    URGENCY_RESTORE,
    URGENCY_RT,
    TransferPlanner,
    TransferRequest,
)
from repro.core.hardware import A100_40G, NVLINK_A100_GBPS
from repro.telemetry.hub import TRACK_CLUSTER

from benchmarks.common import print_json, write_json

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_transfer.json"
WINDOW_US = 50_000.0
GB = 1 << 30
MB = 1 << 20

# request mix: (kind, urgency, route shape, weight). Opposite pairs are the
# storm's pressure point — greedy must host-stage them.
_MIX = (
    ("restore", URGENCY_RT, "restore", 1),
    ("restore", URGENCY_RESTORE, "restore", 2),
    ("snapshot", None, "snapshot", 2),
    ("peer_fetch", None, "adjacent", 1),
    ("checkpoint", None, "opposite", 6),
)


def ring_topology() -> ClusterTopology:
    """4 x A100-40G, NVLink ring with no cross edges: gpu0<->gpu2 and
    gpu1<->gpu3 must either host-stage or detour around the ring."""
    names = [f"gpu{i}" for i in range(4)]
    ring = [(names[i], names[(i + 1) % 4], NVLINK_A100_GBPS) for i in range(4)]
    return ClusterTopology([GPUNode(n, A100_40G) for n in names], nvlinks=ring)


def build_storm(
    topo: ClusterTopology, ratio: float, windows: int, seed: int
) -> List[Tuple[float, List[TransferRequest]]]:
    """One storm: ``windows`` submission windows, each demanding ``ratio`` x
    the window's aggregate host-link byte capacity (the oversubscription
    knob). Deterministic per (ratio, windows, seed)."""
    rnd = random.Random(seed)
    names = [g.name for g in topo.gpus]
    host_bw = topo.link(names[0], HOST).gbps * 1e3  # bytes/us per link
    # ratio x what ALL host links can drain in one window: at 1.5x every
    # window leaves host-leg backlog for the next, the storm regime
    budget = ratio * WINDOW_US * host_bw * len(names)
    weights = [w for *_, w in _MIX]
    out = []
    for w in range(windows):
        t = w * WINDOW_US
        reqs: List[TransferRequest] = []
        remaining = budget
        while remaining > 64 * MB:
            kind, urgency, shape, _ = rnd.choices(_MIX, weights)[0]
            nbytes = min(remaining, rnd.randint(256 * MB, 2 * GB))
            i = rnd.randrange(4)
            if shape == "restore":
                src, dst = HOST, names[i]
            elif shape == "snapshot":
                src, dst = names[i], HOST
            elif shape == "adjacent":
                src, dst = names[i], names[(i + 1) % 4]
            else:  # opposite pair: no direct NVLink edge
                src, dst = names[i], names[(i + 2) % 4]
            reqs.append(
                TransferRequest(src, dst, int(nbytes), kind, urgency,
                                task_id=len(out) * 100 + len(reqs))
            )
            remaining -= nbytes
        out.append((t, reqs))
    return out


# --------------------------------------------------------------------------
# shared truth: event-loop replay of the equal-share fluid model
# --------------------------------------------------------------------------


def _true_landings(
    flights: List[Tuple[int, float, List, List[float], int]],
) -> Dict[int, float]:
    """Replay ``(fid, start_us, link_keys, caps, nbytes)`` flights through
    the equal-share fluid model: shares re-split at every admission and leg
    completion. Returns true landing time per fid."""
    pending = sorted(flights, key=lambda f: (f[1], f[0]))
    i = 0
    active: List[dict] = []
    out: Dict[int, float] = {}
    t = 0.0
    while i < len(pending) or active:
        if not active:
            t = max(t, pending[i][1])
        while i < len(pending) and pending[i][1] <= t + 1e-9:
            fid, start, keys, caps, nbytes = pending[i]
            i += 1
            active.append({"fid": fid, "keys": keys, "caps": caps, "leg": 0,
                           "rem": float(nbytes), "nbytes": nbytes})
        occ: Dict = {}
        for a in active:
            k = a["keys"][a["leg"]]
            occ[k] = occ.get(k, 0) + 1
        dt = math.inf
        rates = []
        for a in active:
            r = a["caps"][a["leg"]] / occ[a["keys"][a["leg"]]]
            rates.append(r)
            if r > 0.0:
                dt = min(dt, a["rem"] / r)
        t_adm = pending[i][1] if i < len(pending) else math.inf
        end = min(t + dt, t_adm)
        for a, r in zip(active, rates):
            a["rem"] -= r * (end - t)
        t = end
        done = []
        for a, r in zip(active, rates):
            eps = 1e-6 + 1e-9 * a["nbytes"]
            stuck = r > 0.0 and a["rem"] / r <= 4.0 * math.ulp(max(t, 1.0))
            if r > 0.0 and (a["rem"] <= eps or stuck):
                a["leg"] += 1
                if a["leg"] >= len(a["keys"]):
                    out[a["fid"]] = t
                    done.append(a)
                else:
                    a["rem"] = float(a["nbytes"])
        for a in done:
            active.remove(a)
    return out


def _plan_flights(
    topo: ClusterTopology, plans: List[TransferPlan]
) -> List[Tuple[int, float, List, List[float], int]]:
    """Lift committed plans into replayable flights: per-leg link keys and
    full (uncontended) capacities — the truth model re-derives the shares."""
    flights = []
    for fid, plan in enumerate(plans):
        keys = [frozenset(name.split("<->")) for name, _ in plan.legs]
        caps = [topo._links[k].gbps * 1e3 for k in keys]
        flights.append((fid, plan.start_us, keys, caps, plan.nbytes))
    return flights


def _percentile(values: List[float], q: float) -> float:
    if not values:
        return 0.0
    s = sorted(values)
    idx = min(len(s) - 1, int(math.ceil(q * len(s))) - 1)
    return s[max(0, idx)]


# --------------------------------------------------------------------------
# the two systems
# --------------------------------------------------------------------------


def run_greedy(storm, topo: ClusterTopology) -> Dict[str, object]:
    """Arrival-order ``plan_transfer`` / ``plan_restore`` — the pre-planner
    model. Budget-deferred requests retry at the next window."""
    plans: List[TransferPlan] = []
    backlog: List[TransferRequest] = []
    t = 0.0
    for t, reqs in storm:
        todo, backlog = backlog + list(reqs), []
        for req in todo:
            if req.src == HOST:
                p = topo.plan_restore(req.dst, req.nbytes, t,
                                      urgency=req.urgency,
                                      task_id=req.task_id)
            else:
                p = topo.plan_transfer(req.src, req.dst, req.nbytes, t,
                                       kind=req.kind, urgency=req.urgency,
                                       task_id=req.task_id)
            if p is None:
                backlog.append(req)
            else:
                plans.append(p)
    retries = 0
    while backlog:  # drain the tail exactly like later rebalance ticks
        t += WINDOW_US
        retries += 1
        todo, backlog = backlog, []
        for req in todo:
            p = (topo.plan_restore(req.dst, req.nbytes, t,
                                   urgency=req.urgency, task_id=req.task_id)
                 if req.src == HOST else
                 topo.plan_transfer(req.src, req.dst, req.nbytes, t,
                                    kind=req.kind, urgency=req.urgency,
                                    task_id=req.task_id))
            if p is None:
                backlog.append(req)
            else:
                plans.append(p)
        if retries > 10_000:
            raise RuntimeError("greedy backlog never drained")
    truth = _true_landings(_plan_flights(topo, plans))
    errors = [abs(p.arrival_us - truth[fid]) for fid, p in enumerate(plans)]
    return {
        "transfers": len(plans),
        "deferred_retries": topo.deferred,
        "makespan_us": max(truth.values()),
        "estimate_makespan_us": max(p.arrival_us for p in plans),
        "p99_landing_error_us": _percentile(errors, 0.99),
        "mean_landing_error_us": sum(errors) / len(errors),
    }


def run_planned(
    storm, topo: ClusterTopology, telemetry=None
) -> Dict[str, object]:
    """Window-batched ``TransferPlanner.submit``; deferred moves (budget or
    urgency) retry at the next window."""
    planner = TransferPlanner(topo, telemetry=telemetry)
    topo.planner = planner
    backlog: List[TransferRequest] = []
    t = 0.0
    for t, reqs in storm:
        todo, backlog = backlog + list(reqs), []
        results = planner.submit(todo, t)
        backlog = [r for r, p in zip(todo, results) if p is None]
        if telemetry is not None:
            for key, depth in planner.link_queue_depths(t).items():
                a, b = sorted(key)
                telemetry.counter(f"link:{a}<->{b}", "queue_depth", t, depth)
    retries = 0
    while backlog:
        t += WINDOW_US
        retries += 1
        todo, backlog = backlog, []
        results = planner.submit(todo, t)
        backlog = [r for r, p in zip(todo, results) if p is None]
        if retries > 10_000:
            raise RuntimeError("planned backlog never drained")
    plans = [f.plan for f in planner.log]
    truth = _true_landings(_plan_flights(topo, plans))
    errors = [abs(p.arrival_us - truth[fid]) for fid, p in enumerate(plans)]
    return {
        "transfers": len(plans),
        "windows": planner.windows,
        "detours": planner.detours,
        "replans": topo.replans,
        "urgency_deferred": planner.urgency_deferred,
        "makespan_us": max(truth.values()),
        "estimate_makespan_us": max(p.arrival_us for p in plans),
        "p99_landing_error_us": _percentile(errors, 0.99),
        "mean_landing_error_us": sum(errors) / len(errors),
    }


def bench_level(
    ratio: float, windows: int, seed: int, telemetry=None
) -> Dict[str, object]:
    storm = build_storm(ring_topology(), ratio, windows, seed)
    n_reqs = sum(len(r) for _, r in storm)
    greedy = run_greedy(storm, ring_topology())
    planned = run_planned(storm, ring_topology(), telemetry=telemetry)
    if telemetry is not None:
        telemetry.span(
            "transfer_plan", TRACK_CLUSTER, windows * WINDOW_US,
            planned["makespan_us"], requests=n_reqs,
            admitted=planned["transfers"], deferred=planned["urgency_deferred"],
            replans=planned["replans"], detours=planned["detours"],
            in_flight=0,
        )
    return {
        "oversubscription": ratio,
        "n_requests": n_reqs,
        "seed": seed,
        "greedy": greedy,
        "planned": planned,
        "makespan_gain": greedy["makespan_us"] / planned["makespan_us"],
        "planned_beats_greedy_makespan":
            planned["makespan_us"] < greedy["makespan_us"],
        "planned_landing_error_not_worse":
            planned["p99_landing_error_us"]
            <= greedy["p99_landing_error_us"] + 1e-6,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2 windows per level (CI)")
    ap.add_argument("--ratios", nargs="+", type=float, default=[1.5, 2.0])
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--telemetry", type=Path, default=None,
                    help="write a Chrome trace of the planned runs")
    args = ap.parse_args(argv)
    windows = 2 if args.smoke else args.windows

    tel = None
    if args.telemetry is not None:
        from repro.telemetry import Telemetry

        tel = Telemetry()

    t0 = time.perf_counter()
    levels = [
        bench_level(r, windows, args.seed, telemetry=tel)
        for r in args.ratios
    ]
    payload = {
        "schema": "bench-transfer-v1",
        "benchmark": "transfer_storm",
        "topology": "4x A100-40G partial NVLink ring",
        "window_us": WINDOW_US,
        "windows": windows,
        "seed": args.seed,
        "smoke": args.smoke,
        "wall_s": round(time.perf_counter() - t0, 3),
        "levels": levels,
        "planned_beats_greedy_makespan": all(
            lv["planned_beats_greedy_makespan"] for lv in levels
        ),
        "planned_landing_error_not_worse": all(
            lv["planned_landing_error_not_worse"] for lv in levels
        ),
    }
    print_json(payload)
    write_json(args.out, payload)
    print(f"wrote {args.out}")
    if tel is not None:
        tel.write_chrome(args.telemetry)
        print(f"telemetry: wrote Chrome trace to {args.telemetry}")
    return 0 if payload["planned_beats_greedy_makespan"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
