"""Fig. 8: allocation-granularity vs template prediction inside MSched —
migration volume inflation and throughput. Paper: 4.77x volume inflation and
5.2-5.4x throughput drop (Light/Medium); 12.27x / 15.67x at Heavy (HBM
pollution displaces active working sets)."""
from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

from benchmarks.common import PAGE, timed

# short slices: over-prediction then matters per-switch (the paper's config)
Q = 5_000.0


def run():
    rows = []
    for scale, label in ((1.15, "light"), (1.3, "medium"), (1.5, "heavy")):
        progs_f = lambda: combo("D", page_size=PAGE["D"], scale=1.0)
        foot = sum(p.footprint_bytes() for p in progs_f())
        cap = int(foot / scale)

        def one(kind):
            return simulate(
                progs_f(), RTX5080, "msched", capacity_bytes=cap,
                sim_us=2_500_000, policy=RoundRobinPolicy(Q),
                predictor_kind=kind,
            )

        (tmpl, us1) = timed(one, "template")
        (alloc, us2) = timed(one, "allocation")
        per_step = lambda r: r.migrated_bytes / max(r.total_completions(), 1)
        inflation = per_step(alloc) / max(per_step(tmpl), 1e-9)
        thr_drop = tmpl.throughput_per_s() / max(alloc.throughput_per_s(), 1e-9)
        rows.append(
            (
                f"fig08_{label}",
                us1 + us2,
                f"migration_inflation={inflation:.2f}x;throughput_drop={thr_drop:.1f}x;"
                f"tmpl_thr={tmpl.throughput_per_s():.1f};alloc_thr={alloc.throughput_per_s():.1f}",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
