"""Fig. 1: total decoding throughput of N concurrent Llama3-8B instances,
UM vs MSched on a 16 GB GPU. Paper: UM collapses 78x; MSched sustains most
of the in-HBM rate."""
from benchmarks.common import bench_combo, timed


def run():
    rows = []
    for n_inst, scale in ((2, 1.0), (3, 1.5), (4, 2.0)):
        r, us = timed(bench_combo, "D", scale, ("um", "msched"))
        um = r["um"].throughput_per_s() / max(r["base"], 1e-9)
        ms = r["msched"].throughput_per_s() / max(r["base"], 1e-9)
        slowdown = 1.0 / max(um, 1e-9)
        rows.append(
            (
                f"fig01_n{len_name(r)}",
                us,
                f"um={um:.4f};msched={ms:.4f};um_slowdown={slowdown:.0f}x;"
                f"speedup={ms / max(um, 1e-9):.1f}x",
            )
        )
    return rows


def len_name(r):
    return f"{r['oversub']:.2f}oversub"


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
