"""Fig. 13: RT/BE colocation under the priority policy — P99 latency of the
real-time task and throughput of the best-effort task, MSched vs compute-only
scheduling (XSched = priority scheduling + demand paging). Paper: 4.06x P99
reduction, 2.43x BE throughput."""
from repro.core.hardware import RTX5080
from repro.core.scheduler import PriorityPolicy
from repro.core.simulator import simulate
from repro.core.workloads import DNNInferTask, DNNTrainTask

from benchmarks.common import timed

PAGE = 256 << 10


def _setup(be_kind):
    rt = DNNInferTask(0, model="resnet152", batch=16, page_size=PAGE)
    if be_kind == "infer":
        be = DNNInferTask(1, model="resnet152", batch=48, page_size=PAGE)
    else:
        be = DNNTrainTask(1, model="resnet152", batch=24, page_size=PAGE)
    return [rt, be]


def run():
    rows = []
    for be_kind in ("infer", "train"):
        progs = _setup(be_kind)
        foot = sum(p.footprint_bytes() for p in progs)
        arrivals = {0: [float(i) * 120_000.0 for i in range(24)]}

        def one(backend):
            return simulate(
                _setup(be_kind), RTX5080, backend,
                capacity_bytes=int(foot / 1.5),
                sim_us=3_000_000,
                policy=PriorityPolicy(quantum_us=50_000.0, rt_quantum_us=30_000.0),
                arrivals=arrivals,
                priorities={0: 10, 1: 0},
            )

        ms, us1 = timed(one, "msched")
        um, us2 = timed(one, "um")  # XSched: priority compute sched + UM paging
        p99_ms = ms.p99_latency_us(0) / 1e3
        p99_um = um.p99_latency_us(0) / 1e3
        be_ms = ms.per_task[1].completions / (ms.sim_us * 1e-6)
        be_um = um.per_task[1].completions / (um.sim_us * 1e-6)
        rows.append(
            (
                f"fig13_{be_kind}",
                us1 + us2,
                f"rt_p99_ms_msched={p99_ms:.1f};rt_p99_ms_xsched={p99_um:.1f};"
                f"p99_reduction={p99_um / max(p99_ms, 1e-9):.2f}x;"
                f"be_thr_msched={be_ms:.2f};be_thr_xsched={be_um:.2f};"
                f"be_speedup={be_ms / max(be_um, 1e-9):.2f}x",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
