"""Fig. 2: memory access pattern of one Llama3-8B decode step — bytes touched
and duration (paper: ~8.5 GB in ~12.7 ms, streaming with poor locality)."""
from repro.core.pages import extents_bytes
from repro.core.workloads import LLMDecodeTask

from benchmarks.common import timed


def run():
    task = LLMDecodeTask(0, arch="paper-llama3-8b", page_size=1 << 20)

    def step_stats():
        cmds = task.iteration(100)
        ext = [e for c in cmds for e in c.true_extents]
        return extents_bytes(ext), sum(c.latency_us for c in cmds), len(cmds)

    (touched, dur_us, n_cmds), us = timed(step_stats)
    # reuse: unique bytes vs summed command bytes (streaming => ratio ~1)
    total = sum(c.data_bytes() for c in task.iteration(100))
    return [
        (
            "fig02_decode_step",
            us,
            f"touched_GB={touched / 1e9:.2f};step_ms={dur_us / 1e3:.1f};"
            f"commands={n_cmds};reuse_ratio={total / max(touched, 1):.2f}",
        )
    ]


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
