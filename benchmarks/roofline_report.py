"""Roofline table from the dry-run artifacts (results/dryrun.jsonl).

Per (arch x shape x mesh): the three roofline terms in seconds —
  compute    = dot_FLOPs_per_device / 197 TF/s   (bf16 MXU peak, v5e)
  memory     = bytes_per_device / 819 GB/s       (HBM BW)
  collective = collective_bytes_per_device / 50 GB/s (ICI link)
dominant term, MODEL_FLOPS = 6·N(active)·D tokens, and the useful-compute
ratio MODEL_FLOPS / compiled_FLOPs.

The memory-bytes term uses cost_analysis 'bytes accessed' corrected by the
scan trip count ratio (dot_flops / flops_raw), since XLA's analysis counts
while bodies once (see roofline/hlo_costs.py).
"""
import json
import os

from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.core.hardware import (
    TPU_V5E_HBM_GBPS,
    TPU_V5E_ICI_GBPS,
    TPU_V5E_PEAK_BF16_FLOPS,
)

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analytic_bytes_floor(arch: str, shape_name: str, n_dev: int) -> float:
    """Minimum per-device HBM traffic: parameters (+optimizer state for
    train), KV/state cache, and the remat carry stack — each touched at
    least once per step. cost_analysis counts loop-carried tensors once,
    which is roughly right for these (weights/cache read once per step),
    so the roofline memory term is max(raw_bytes, this floor)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    p_bytes = cfg.param_count() * 2  # bf16
    if shape.kind == "train":
        # fwd read + bwd read + grad write + param rw + f32 opt state rw
        opt_mult = 12 if cfg.optimizer == "adamw" else 6
        d = cfg.d_model
        local_batch = max(shape.global_batch // 16, 1)  # data axis
        carry = cfg.num_layers * local_batch * shape.seq_len * d * 2
        return (opt_mult * p_bytes) / n_dev + 3 * carry / 16  # model axis
    # serving: params + cache traffic
    hd = cfg.resolved_head_dim() if cfg.num_heads else 0
    if cfg.family == "ssm":
        cache = (
            cfg.num_layers
            * shape.global_batch
            * (cfg.ssm.expand * cfg.d_model // cfg.ssm.head_dim)
            * cfg.ssm.head_dim
            * cfg.ssm.state_dim
            * 4
        )
    elif cfg.rglru is not None:
        kinds = cfg.layer_kinds()
        n_attn = sum(1 for k in kinds if k == "attn")
        n_rec = sum(1 for k in kinds if k == "rec")
        cache = (
            n_attn * shape.global_batch * min(cfg.rglru.window, shape.seq_len)
            * cfg.num_kv_heads * hd * 2 * 2
            + n_rec * shape.global_batch * cfg.d_model * 4
        )
    else:
        cache = (
            cfg.num_layers * shape.global_batch * shape.seq_len
            * cfg.num_kv_heads * hd * 2 * 2
        )
    mult = 2 if shape.kind == "prefill" else 1  # prefill writes + attends
    return (p_bytes + mult * cache) / n_dev


def roofline_terms(rec: dict) -> dict:
    n_dev = rec["n_devices"]
    hc = rec["hlo_costs"]
    ca = rec["cost_analysis"]
    dot = hc["dot_flops"]  # per-device, trip-count-corrected
    bytes_dev = max(
        ca["bytes_raw"],
        analytic_bytes_floor(rec["arch"], rec["shape"], n_dev),
    )
    coll = sum(hc["collective_bytes"].values())
    t_compute = dot / TPU_V5E_PEAK_BF16_FLOPS
    t_memory = bytes_dev / (TPU_V5E_HBM_GBPS * 1e9)
    t_coll = coll / (TPU_V5E_ICI_GBPS * 1e9)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"])
    useful = mf / max(dot * n_dev, 1.0)
    bound = max(terms.values())
    ideal = mf / (n_dev * TPU_V5E_PEAK_BF16_FLOPS)
    return {
        **{f"t_{k}_s": v for k, v in terms.items()},
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": useful,
        "roofline_fraction": (ideal / bound) if bound > 0 else 0.0,
    }


def load_rows(path: str = RESULTS):
    rows = []
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            rows.append(json.loads(line))
    return rows


def run():
    out = []
    for rec in load_rows():
        name = f"roofline_{rec['arch']}_{rec['shape']}_{rec['mesh']}"
        if rec["status"] == "skipped":
            out.append((name, 0.0, f"skipped:{rec['skip_reason']}"))
            continue
        if rec["status"] != "ok":
            out.append((name, 0.0, f"error:{rec.get('error', '?')[:80]}"))
            continue
        t = roofline_terms(rec)
        out.append(
            (
                name,
                t["t_" + t["dominant"] + "_s"] * 1e6,
                f"compute_s={t['t_compute_s']:.4f};memory_s={t['t_memory_s']:.4f};"
                f"collective_s={t['t_collective_s']:.4f};dominant={t['dominant']};"
                f"useful_ratio={t['useful_ratio']:.3f};"
                f"roofline_fraction={t['roofline_fraction']:.3f}",
            )
        )
    return out


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
