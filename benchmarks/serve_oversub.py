"""Serving-under-oversubscription benchmark: UM vs MSched on one trace.

Replays the same seeded Poisson request trace (multi-tenant LLM serving,
one finite task per request) through the dynamic simulator at a sweep of
HBM oversubscription ratios:

  * **um**     — native demand paging with naive always-admit (the commodity
    baseline: unbounded concurrency, 2 ms TSG timeslices);
  * **msched** — proactive memory scheduling with MSched-aware admission
    (working-set-guarded concurrency, 350 ms XSched-style timeslices).

The oversubscription ratio r sizes HBM as ``target_concurrency ×
request_footprint / r`` — at r = 1.5 the device can hold 2 of the 3 resident
working sets the load wants. Headline metric: **goodput** (completed
requests/s meeting both the TTFT and TPOT SLOs). Acceptance: at r ≥ 1.5,
MSched goodput ≥ 3× UM. Writes ``BENCH_serving.json``.

Usage: PYTHONPATH=src python -m benchmarks.serve_oversub [--smoke]
       [--ratios 1.0 1.5 2.0] [--rate 5.0] [--duration 3.0] [--out path]
       [--requests 500]   # long-trace mode: ~N requests at 1.5x, <2 min wall
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import (
    AlwaysAdmit,
    MSchedAdmission,
    SLOSpec,
    poisson_trace,
    serve_trace,
)
from repro.serving.lifecycle import ServedRequestTask

from benchmarks.common import (
    MSCHED_Q,
    UM_Q,
    export_metrics,
    export_telemetry,
    make_telemetry,
    print_json,
    write_json,
)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_serving.json"
TARGET_GOODPUT_RATIO = 3.0
TARGET_CONCURRENCY = 3  # resident working sets the offered load wants

SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)


def _request_footprint(trace, page_size: int) -> int:
    """Footprint of a representative request (weights dominate, so any
    request of the tenant is representative)."""
    probe = ServedRequestTask(99_000_000, trace.requests[0], page_size=page_size)
    return probe.footprint_bytes()


def run_bench(
    ratios: Sequence[float] = (1.0, 1.5, 2.0),
    rate_rps: float = 5.0,
    duration_s: float = 3.0,
    seed: int = 42,
    arch: str = "paper-llama3-8b",
    page_size: int = 1 << 20,
    out_path: Optional[Path] = DEFAULT_OUT,
    output_mean: int = 32,
    drain_factor: float = 8.0,
    telemetry_path: Optional[Path] = None,
    metrics_path: Optional[Path] = None,
) -> Dict[str, object]:
    # one traced run per invocation: the msched arm at the first (lowest)
    # oversubscription ratio in the sweep
    tel = make_telemetry(telemetry_path, metrics_path)
    trace = poisson_trace(
        rate_rps,
        duration_s,
        seed=seed,
        tenants=(arch,),
        prompt_mean=256,
        output_mean=output_mean,
        max_output=2 * output_mean,
    )
    req_foot = _request_footprint(trace, page_size)
    report: Dict[str, object] = {
        "benchmark": "serve_oversub",
        "trace": dict(trace.meta, n_requests=len(trace),
                      offered_rps=trace.offered_rate_rps()),
        "arch": arch,
        "request_footprint_bytes": req_foot,
        "target_concurrency": TARGET_CONCURRENCY,
        "slo": {"ttft_us": SLO.ttft_us, "tpot_us": SLO.tpot_us},
        "target_goodput_ratio": TARGET_GOODPUT_RATIO,
        "sweep": [],
    }
    for ratio in ratios:
        cap = int(TARGET_CONCURRENCY * req_foot / ratio)
        row: Dict[str, object] = {"oversubscription": ratio,
                                  "capacity_bytes": cap}
        for backend, admission, quantum in (
            ("um", AlwaysAdmit(), UM_Q),
            ("msched", MSchedAdmission(headroom=0.9), MSCHED_Q),
        ):
            t0 = time.perf_counter()
            rep = serve_trace(
                trace,
                RTX5080,
                backend=backend,
                capacity_bytes=cap,
                admission=admission,
                policy=RoundRobinPolicy(quantum),
                page_size=page_size,
                slo=SLO,
                drain_factor=drain_factor,
                telemetry=(
                    tel
                    if backend == "msched" and ratio == ratios[0]
                    else None
                ),
            )
            r = rep.to_row()
            r["wall_s"] = time.perf_counter() - t0
            row[backend] = r
        um_good = row["um"]["goodput_per_s"]
        ms_good = row["msched"]["goodput_per_s"]
        # None (JSON null) when UM's goodput is zero: float('inf') would
        # serialize as bare Infinity, which strict JSON parsers reject
        row["goodput_ratio"] = ms_good / um_good if um_good > 0 else None
        report["sweep"].append(row)

    pressured = [r for r in report["sweep"] if r["oversubscription"] >= 1.5]
    report["meets_target"] = bool(pressured) and all(
        r["msched"]["goodput_per_s"]
        >= TARGET_GOODPUT_RATIO * r["um"]["goodput_per_s"]
        and r["msched"]["goodput_per_s"] > 0
        for r in pressured
    )
    export_telemetry(tel, telemetry_path)
    export_metrics(tel, metrics_path)
    if out_path is not None:
        write_json(out_path, report)
    return report


def run(telemetry_path=None):
    """benchmarks.run entry point: name,us,derived rows."""
    report = run_bench(telemetry_path=telemetry_path)
    rows = []
    for row in report["sweep"]:
        ms, um = row["msched"], row["um"]
        ratio = row["goodput_ratio"]
        derived = (
            f"goodput_msched={ms['goodput_per_s']:.2f}/s;"
            f"goodput_um={um['goodput_per_s']:.2f}/s;"
            f"ratio={f'{ratio:.1f}x' if ratio is not None else 'inf (um=0)'};"
            f"ttft_p99_ms={ms['ttft_p99_us'] / 1e3:.0f};"
            f"meets={report['meets_target']}"
        )
        rows.append(
            (f"serve_oversub_r{row['oversubscription']}",
             ms["wall_s"] * 1e6, derived)
        )
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ratios", type=float, nargs="+", default=[1.0, 1.5, 2.0])
    ap.add_argument("--rate", type=float, default=5.0)
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument(
        "--arch", default=None,
        help="tenant architecture (default: paper-llama3-8b for the sweep, "
        "qwen3-1.7b for --requests long-trace mode)",
    )
    ap.add_argument(
        "--out", type=Path, default=None,
        help=f"report path (default: {DEFAULT_OUT}; smoke mode writes only "
        "when --out is given explicitly)",
    )
    ap.add_argument(
        "--telemetry", type=Path, default=None, metavar="out.trace",
        help="export a Chrome trace of the msched arm at the first ratio",
    )
    ap.add_argument(
        "--metrics", type=Path, default=None, metavar="metrics.json",
        help="export a metrics-report-v1 JSON of the traced arm "
        "(see scripts/msctl.py metrics)",
    )
    ap.add_argument(
        "--requests", type=int, default=None,
        help="long-trace mode: replay a trace of ~this many requests at 1.5x "
        "oversubscription (run-native hierarchy makes 500+ tractable)",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast CI config: small model, short trace, 1.5x only",
    )
    args = ap.parse_args()
    if args.smoke:
        report = run_bench(
            ratios=[1.5], rate_rps=4.0, duration_s=2.0, seed=args.seed,
            arch=args.arch or "qwen3-1.7b", out_path=args.out, output_mean=16,
            telemetry_path=args.telemetry, metrics_path=args.metrics,
        )
    elif args.requests:
        # long-trace mode: the drain window shrinks to 2x the offered-load
        # window — UM never drains anyway, MSched finishes well within it,
        # and goodput is normalized by the shared offered window either way
        report = run_bench(
            ratios=args.ratios if args.ratios != [1.0, 1.5, 2.0] else [1.5],
            rate_rps=args.rate,
            duration_s=args.requests / args.rate, seed=args.seed,
            arch=args.arch or "qwen3-1.7b", out_path=args.out or DEFAULT_OUT,
            drain_factor=2.0, telemetry_path=args.telemetry,
            metrics_path=args.metrics,
        )
    else:
        report = run_bench(
            args.ratios, args.rate, args.duration, args.seed,
            args.arch or "paper-llama3-8b", out_path=args.out or DEFAULT_OUT,
            telemetry_path=args.telemetry, metrics_path=args.metrics,
        )
    print_json(report)
    if not report["meets_target"]:
        raise SystemExit("MSched goodput below target vs UM under pressure")


if __name__ == "__main__":
    main()
