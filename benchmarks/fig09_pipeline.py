"""Fig. 9 (+§3 bandwidth table): migration bandwidth by method, and the
end-to-end effect of pipelined migration. Paper: faulted 0.12 GB/s vs
batched 41.7 GB/s (347x); pipelined swap 63.5 GB/s on RTX 5080 (1.52x) /
39.8 GB/s on RTX 3080 (1.79x); end-to-end 1.27-1.51x."""
from repro.core.hardware import RTX3080, RTX5080, fault_bandwidth_gbps
from repro.core.migration import effective_swap_bandwidth_gbps
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

from benchmarks.common import MSCHED_Q, PAGE, timed


def run():
    rows = []
    for plat in (RTX5080, RTX3080):
        def bw():
            faulted = fault_bandwidth_gbps(plat)
            plain = effective_swap_bandwidth_gbps(plat, 1 << 30, pipelined=False)
            piped = effective_swap_bandwidth_gbps(plat, 1 << 30, pipelined=True)
            return faulted, plain, piped

        (faulted, plain, piped), us = timed(bw)
        rows.append(
            (
                f"fig09a_{plat.name}",
                us,
                f"faulted_GBps={faulted:.3f};swap_GBps={plain:.1f};"
                f"pipelined_GBps={piped:.1f};pipeline_speedup={piped / plain:.2f}x;"
                f"batched_vs_fault={plat.h2d_gbps / faulted:.0f}x",
            )
        )

    # fig 9b: end-to-end with and without pipelining
    for scale, label in ((1.5, "150"), (2.0, "200"), (3.0, "300")):
        progs = lambda: combo("D", page_size=PAGE["D"], scale=scale)
        foot = sum(p.footprint_bytes() for p in progs())

        def one(pipelined):
            return simulate(
                progs(), RTX5080, "msched",
                capacity_bytes=RTX5080.hbm_bytes,
                sim_us=3_000_000, policy=RoundRobinPolicy(MSCHED_Q),
                pipelined=pipelined,
            ).throughput_per_s()

        w, us1 = timed(one, True)
        wo, us2 = timed(one, False)
        rows.append(
            (
                f"fig09b_sub{label}",
                us1 + us2,
                f"with_pipeline={w:.1f};without={wo:.1f};speedup={w / max(wo, 1e-9):.2f}x",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
