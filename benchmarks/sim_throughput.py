"""Simulator-throughput benchmark: incremental planning engine vs legacy.

Measures simulated-µs per wall-clock-second on the paper's combo-D
oversubscription scenario (multiple Llama3-8B-class decode instances over one
fixed HBM) with the msched backend — the configuration whose per-switch plan
rebuild made the *simulator* the bottleneck. Runs the preserved pre-refactor
path (``planning="legacy"``: per-switch future rebuilds, set-based plans,
per-command extent re-decode) and the incremental engine on the identical
scenario, checks the SimResults agree, and writes ``BENCH_sim_throughput.json``
for the perf trajectory. Target: >= 5x.

Usage: PYTHONPATH=src python -m benchmarks.sim_throughput [--legacy-only]
       [--scale 2.0] [--sim-us 2000000] [--out path.json]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

from benchmarks.common import MSCHED_Q, PAGE

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_sim_throughput.json"
TARGET_SPEEDUP = 5.0


def _result_fingerprint(res) -> dict:
    return {
        "sim_us": res.sim_us,
        "switches": res.switches,
        "faults": res.faults,
        "migrated_bytes": res.migrated_bytes,
        "control_us": res.control_us,
        "completions": res.total_completions(),
    }


def _one(planning: str, scale: float, sim_us: float) -> dict:
    progs = combo("D", page_size=PAGE["D"], scale=scale)
    foot = sum(p.footprint_bytes() for p in progs)
    t0 = time.perf_counter()
    res = simulate(
        progs,
        RTX5080,
        "msched",
        sim_us=sim_us,
        policy=RoundRobinPolicy(MSCHED_Q),
        planning=planning,
    )
    wall_s = time.perf_counter() - t0
    return {
        "planning": planning,
        "tasks": len(progs),
        "footprint_bytes": foot,
        "oversubscription": foot / RTX5080.hbm_bytes,
        "wall_s": wall_s,
        "sim_us": res.sim_us,
        "sim_us_per_wall_s": res.sim_us / wall_s if wall_s else 0.0,
        "result": _result_fingerprint(res),
    }


def run_bench(
    scale: float = 2.0,
    sim_us: float = 2_000_000.0,
    out_path: Path = DEFAULT_OUT,
    legacy_only: bool = False,
    incremental_only: bool = False,
) -> dict:
    report: dict = {
        "benchmark": "sim_throughput",
        "scenario": "combo-D msched oversubscription",
        "scale": scale,
        "target_speedup": TARGET_SPEEDUP,
    }
    if not incremental_only:
        report["legacy"] = _one("legacy", scale, sim_us)
    if not legacy_only:
        report["incremental"] = _one("incremental", scale, sim_us)
    if "legacy" in report and "incremental" in report:
        report["speedup"] = (
            report["incremental"]["sim_us_per_wall_s"]
            / max(report["legacy"]["sim_us_per_wall_s"], 1e-12)
        )
        report["meets_target"] = report["speedup"] >= TARGET_SPEEDUP
        report["results_identical"] = (
            report["incremental"]["result"] == report["legacy"]["result"]
        )
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    return report


def run():
    """benchmarks.run entry point: name,us,derived rows."""
    report = run_bench()
    inc = report["incremental"]
    leg = report["legacy"]
    derived = (
        f"sim_us_per_wall_s={inc['sim_us_per_wall_s']:.0f};"
        f"legacy={leg['sim_us_per_wall_s']:.0f};"
        f"speedup={report['speedup']:.2f}x;"
        f"identical={report['results_identical']}"
    )
    return [("sim_throughput", inc["wall_s"] * 1e6, derived)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--legacy-only", action="store_true")
    ap.add_argument("--incremental-only", action="store_true")
    ap.add_argument("--scale", type=float, default=2.0)
    ap.add_argument("--sim-us", type=float, default=2_000_000.0)
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    args = ap.parse_args()
    report = run_bench(
        args.scale, args.sim_us, args.out, args.legacy_only, args.incremental_only
    )
    print(json.dumps(report, indent=2))
    if report.get("speedup") is not None and not report["meets_target"]:
        raise SystemExit(f"speedup {report['speedup']:.2f}x below target")


if __name__ == "__main__":
    main()
