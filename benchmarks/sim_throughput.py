"""Simulator-throughput benchmark: run-native memory hierarchy vs references.

Three measurements on the paper's combo-D oversubscription scenario (multiple
Llama3-8B-class decode instances over one fixed HBM) with the msched backend:

  * **legacy vs incremental** (1 MiB pages) — the PR 1 planning speedup,
    preserved: per-switch future rebuilds + set-based plans vs incremental
    planning, identical SimResult asserted.
  * **page-granularity sweep** (``--page-kib {4,64,2048}``) — the run-native
    pool + vectorized pager + macro-stepper at fine page sizes, reported as
    simulated-µs per wall-second and compared against the recorded PR 1
    baseline (the 4 KiB point was intractable before this refactor).
  * **serving trace** — a 500-request multi-tenant trace through the dynamic
    engine (msched), the long-trace regime the run-native hierarchy unlocks.

Writes ``BENCH_sim_throughput.json``. The committed 2048 KiB sweep number is
the CI smoke regression baseline (``--check-regression`` fails on >30% drop;
numbers are machine-relative, so CI compares against a fresh same-machine
legacy run, not this file's absolute values).

Usage: PYTHONPATH=src python -m benchmarks.sim_throughput [--legacy-only]
       [--scale 2.0] [--sim-us 2000000] [--page-kib 4 64 2048]
       [--skip-sweep] [--skip-serving] [--check-regression] [--out path.json]
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from repro.core.hardware import RTX5080
from repro.core.scheduler import RoundRobinPolicy
from repro.core.simulator import simulate
from repro.core.workloads import combo

from benchmarks.common import MSCHED_Q, PAGE

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_sim_throughput.json"
TARGET_SPEEDUP = 5.0
# acceptance: >= 4x over the PR 1 engine at 64 KiB pages on combo-D
TARGET_SWEEP_SPEEDUP = 4.0
REGRESSION_TOLERANCE = 0.30

# PR 1 engine (commit 3b732e0) measured on the reference machine with the
# same scenario/sim_us; the 4 KiB case did not complete in any usable time
PR1_BASELINE_SIM_US_PER_WALL_S = {2048: 1_806_239.0, 64: 486_050.0, 4: None}


def _result_fingerprint(res) -> dict:
    return {
        "sim_us": res.sim_us,
        "switches": res.switches,
        "faults": res.faults,
        "migrated_bytes": res.migrated_bytes,
        "control_us": res.control_us,
        "completions": res.total_completions(),
    }


def _one(
    planning: str,
    scale: float,
    sim_us: float,
    page_size: int = 0,
    pool: str = "run",
    repeats: int = 1,
) -> dict:
    page_size = page_size or PAGE["D"]
    best = None
    for _ in range(max(1, repeats)):
        progs = combo("D", page_size=page_size, scale=scale)
        foot = sum(p.footprint_bytes() for p in progs)
        t0 = time.perf_counter()
        res = simulate(
            progs,
            RTX5080,
            "msched",
            sim_us=sim_us,
            policy=RoundRobinPolicy(MSCHED_Q),
            planning=planning,
            pool=pool,
        )
        wall_s = time.perf_counter() - t0
        row = {
            "planning": planning,
            "pool": pool,
            "page_size": page_size,
            "tasks": len(progs),
            "footprint_bytes": foot,
            "oversubscription": foot / RTX5080.hbm_bytes,
            "wall_s": wall_s,
            "sim_us": res.sim_us,
            "sim_us_per_wall_s": res.sim_us / wall_s if wall_s else 0.0,
            "result": _result_fingerprint(res),
        }
        if best is None or row["sim_us_per_wall_s"] > best["sim_us_per_wall_s"]:
            best = row
    return best


def _serving_case(n_requests: int = 500, rate_rps: float = 5.0) -> dict:
    """msched over a long multi-tenant request trace — the dynamic-lifecycle
    regime (one finite task per request) at production trace length."""
    from repro.core.scheduler import RoundRobinPolicy as RR
    from repro.serving import MSchedAdmission, SLOSpec, poisson_trace, serve_trace
    from repro.serving.lifecycle import ServedRequestTask

    trace = poisson_trace(
        rate_rps, n_requests / rate_rps, seed=42, tenants=("qwen3-1.7b",),
        prompt_mean=256, output_mean=32, max_output=64,
    )
    probe = ServedRequestTask(99_000_000, trace.requests[0], page_size=1 << 20)
    cap = int(3 * probe.footprint_bytes() / 1.5)
    t0 = time.perf_counter()
    rep = serve_trace(
        trace, RTX5080, backend="msched", capacity_bytes=cap,
        admission=MSchedAdmission(headroom=0.9), policy=RR(MSCHED_Q),
        page_size=1 << 20, slo=SLOSpec(), drain_factor=2.0,
    )
    wall_s = time.perf_counter() - t0
    return {
        "n_requests": len(trace),
        "n_finished": rep.n_finished,
        "goodput_per_s": rep.goodput_per_s,
        "wall_s": wall_s,
        "sim_us": rep.result.sim_us,
        "sim_us_per_wall_s": rep.result.sim_us / wall_s if wall_s else 0.0,
    }


def run_bench(
    scale: float = 2.0,
    sim_us: float = 2_000_000.0,
    out_path: Path = DEFAULT_OUT,
    legacy_only: bool = False,
    incremental_only: bool = False,
    page_kibs=(2048, 64, 4),
    skip_sweep: bool = False,
    skip_serving: bool = False,
) -> dict:
    report: dict = {
        "benchmark": "sim_throughput",
        "scenario": "combo-D msched oversubscription",
        "scale": scale,
        "target_speedup": TARGET_SPEEDUP,
        "target_sweep_speedup_vs_pr1": TARGET_SWEEP_SPEEDUP,
    }
    if not incremental_only:
        report["legacy"] = _one("legacy", scale, sim_us)
    if not legacy_only:
        report["incremental"] = _one("incremental", scale, sim_us)
    if "legacy" in report and "incremental" in report:
        report["speedup"] = (
            report["incremental"]["sim_us_per_wall_s"]
            / max(report["legacy"]["sim_us_per_wall_s"], 1e-12)
        )
        report["meets_target"] = report["speedup"] >= TARGET_SPEEDUP
        report["results_identical"] = (
            report["incremental"]["result"] == report["legacy"]["result"]
        )
    if not skip_sweep:
        sweep = []
        for kib in page_kibs:
            row = _one("incremental", scale, sim_us, page_size=kib << 10,
                       repeats=2)
            row["page_kib"] = kib
            base = PR1_BASELINE_SIM_US_PER_WALL_S.get(kib)
            row["pr1_baseline_sim_us_per_wall_s"] = base
            if base:
                row["speedup_vs_pr1"] = row["sim_us_per_wall_s"] / base
            if kib == 2048:
                # same-scenario legacy reference, measured back to back: the
                # CI regression gate tracks this *ratio*, which normalizes
                # out machine speed and load far better than absolute rates
                leg = _one("legacy", scale, sim_us, page_size=kib << 10,
                           repeats=2)
                row["legacy_sim_us_per_wall_s"] = leg["sim_us_per_wall_s"]
                row["speedup_vs_legacy"] = (
                    row["sim_us_per_wall_s"]
                    / max(leg["sim_us_per_wall_s"], 1e-12)
                )
            sweep.append(row)
        report["page_sweep"] = sweep
        pinned = [r for r in sweep if r["page_kib"] == 64]
        if pinned:
            report["meets_sweep_target"] = (
                pinned[0].get("speedup_vs_pr1", 0.0) >= TARGET_SWEEP_SPEEDUP
            )
    if not skip_serving:
        report["serving_500"] = _serving_case()
    out_path.write_text(json.dumps(report, indent=2) + "\n")
    return report


def run():
    """benchmarks.run entry point: name,us,derived rows."""
    report = run_bench()
    inc = report["incremental"]
    leg = report["legacy"]
    rows = [(
        "sim_throughput",
        inc["wall_s"] * 1e6,
        f"sim_us_per_wall_s={inc['sim_us_per_wall_s']:.0f};"
        f"legacy={leg['sim_us_per_wall_s']:.0f};"
        f"speedup={report['speedup']:.2f}x;"
        f"identical={report['results_identical']}",
    )]
    for row in report.get("page_sweep", []):
        vs = row.get("speedup_vs_pr1")
        rows.append((
            f"sim_throughput_p{row['page_kib']}k",
            row["wall_s"] * 1e6,
            f"sim_us_per_wall_s={row['sim_us_per_wall_s']:.0f};"
            f"vs_pr1={f'{vs:.1f}x' if vs else 'n/a (was intractable)'}",
        ))
    srv = report.get("serving_500")
    if srv:
        rows.append((
            "sim_throughput_serve500",
            srv["wall_s"] * 1e6,
            f"requests={srv['n_requests']};finished={srv['n_finished']};"
            f"sim_us_per_wall_s={srv['sim_us_per_wall_s']:.0f}",
        ))
    return rows


def check_regression(report: dict, committed: dict) -> None:
    """CI guard: the fresh 2048 KiB point's speedup over a back-to-back
    legacy run at the same page size must stay within
    ``REGRESSION_TOLERANCE`` of the committed ratio — same-scenario,
    same-process pairs normalize out machine speed and load."""
    ref_rows = [r for r in committed.get("page_sweep", []) if r["page_kib"] == 2048]
    new_rows = [r for r in report.get("page_sweep", []) if r["page_kib"] == 2048]
    if not ref_rows or not new_rows:
        raise SystemExit("missing 2048 KiB sweep point for regression check")
    ref = ref_rows[0].get("speedup_vs_legacy")
    new = new_rows[0].get("speedup_vs_legacy")
    if not ref or not new:
        raise SystemExit("missing speedup_vs_legacy for regression check")
    if new < (1.0 - REGRESSION_TOLERANCE) * ref:
        raise SystemExit(
            f"2 MiB sim throughput regressed: {new:.2f}x legacy vs committed "
            f"{ref:.2f}x legacy (tolerance {REGRESSION_TOLERANCE:.0%})"
        )
    print(f"regression check OK: {new:.2f}x legacy (committed {ref:.2f}x)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--legacy-only", action="store_true")
    ap.add_argument("--incremental-only", action="store_true")
    ap.add_argument("--scale", type=float, default=2.0)
    ap.add_argument("--sim-us", type=float, default=2_000_000.0)
    ap.add_argument(
        "--page-kib", type=int, nargs="+", default=[2048, 64, 4],
        help="page-granularity sweep points (KiB)",
    )
    ap.add_argument("--skip-sweep", action="store_true")
    ap.add_argument("--skip-serving", action="store_true")
    ap.add_argument(
        "--check-regression", action="store_true",
        help="fail if the 2 MiB case regressed >30%% vs the committed JSON",
    )
    ap.add_argument(
        "--enforce-pr1-target", action="store_true",
        help="exit non-zero when the 64 KiB point is below 4x the recorded "
        "PR 1 baseline (absolute rates are machine-relative, so this is only "
        "meaningful on reference-class hardware)",
    )
    ap.add_argument(
        "--out", type=Path, default=None,
        help="report path (default: the committed JSON, or a temp file when "
        "--check-regression would otherwise clobber its own baseline)",
    )
    args = ap.parse_args()
    out_path = args.out or (
        Path("/tmp/bench_sim_throughput.json")
        if args.check_regression
        else DEFAULT_OUT
    )
    committed = (
        json.loads(DEFAULT_OUT.read_text()) if DEFAULT_OUT.exists() else None
    )
    report = run_bench(
        args.scale, args.sim_us, out_path, args.legacy_only,
        args.incremental_only, tuple(args.page_kib), args.skip_sweep,
        args.skip_serving,
    )
    print(json.dumps(report, indent=2))
    if args.check_regression:
        if committed is None:
            raise SystemExit("no committed BENCH_sim_throughput.json to compare")
        check_regression(report, committed)
    if report.get("speedup") is not None and not report["meets_target"]:
        raise SystemExit(f"speedup {report['speedup']:.2f}x below target")
    if args.enforce_pr1_target and report.get("meets_sweep_target") is False:
        raise SystemExit("64 KiB sweep speedup vs PR1 baseline below 4x")


if __name__ == "__main__":
    main()
