"""Table 2: share of fixed/linear/strided/other access regions per workload.
Paper: fixed ~60-99%, linear up to ~38% (llama), strided up to ~10%,
other <1%."""
from repro.core.profiler import profile_programs
from repro.core.templates import analyze_traces, template_mix_table
from repro.core.workloads import combo

from benchmarks.common import PAGE, timed


def run():
    rows = []
    for name, label in (("A", "rodinia"), ("B", "pytorch_infer"), ("D", "llama")):
        def mix():
            progs = combo(name, page_size=PAGE[name])
            store = profile_programs(progs, iters=4)
            return template_mix_table(analyze_traces(store), store)

        m, us = timed(mix)
        rows.append(
            (
                f"table2_{label}",
                us,
                f"fixed={m['fixed']:.1f};linear={m['linear']:.1f};"
                f"strided={m['strided']:.1f};other={m['opaque']:.2f}",
            )
        )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
