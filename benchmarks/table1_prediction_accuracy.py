"""Table 1: kernel-level F-/F+ of allocation-granularity vs template-based
prediction across workloads. Paper: template F- <= 0.92%, F+ = 0.00%;
allocation F+ up to 99.7% (LLM)."""
from repro.core.predictor import AllocationPredictor, TemplatePredictor, evaluate_accuracy
from repro.core.profiler import profile_programs
from repro.core.templates import analyze_traces
from repro.core.workloads import combo

from benchmarks.common import PAGE, timed


def run():
    rows = []
    for name, label in (("A", "rodinia"), ("B", "pytorch_infer"), ("D", "llama")):
        def eval_combo():
            progs = combo(name, page_size=PAGE[name])
            store = profile_programs(progs, iters=4)
            desc = analyze_traces(store)
            out = []
            for p in progs:
                cmds = [c for it in (10, 11) for c in p.iteration(it)]
                t = evaluate_accuracy(TemplatePredictor(desc), cmds, p.space)
                a = evaluate_accuracy(AllocationPredictor(p.space), cmds, p.space)
                out.append((p.name, t, a))
            return out

        res, us = timed(eval_combo)
        for pname, t, a in res:
            rows.append(
                (
                    f"table1_{label}_{pname}",
                    us / len(res),
                    f"tmpl_Fneg={t.false_negative_pct:.2f};tmpl_Fpos={t.false_positive_pct:.2f};"
                    f"alloc_Fneg={a.false_negative_pct:.2f};alloc_Fpos={a.false_positive_pct:.2f}",
                )
            )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
