"""Pallas kernel microbenches (interpret mode on CPU — wall times are NOT
TPU times; 'derived' reports the analytic TPU v5e roofline estimate for the
same shapes: max(flops/197TF, bytes/819GBps))."""
import jax
import jax.numpy as jnp

from repro.core.hardware import TPU_V5E_HBM_GBPS, TPU_V5E_PEAK_BF16_FLOPS
from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.streammm.kernel import stream_matmul, stream_matmul_int8

from benchmarks.common import timed


def _roofline_us(flops, bytes_):
    return max(flops / TPU_V5E_PEAK_BF16_FLOPS, bytes_ / (TPU_V5E_HBM_GBPS * 1e9)) * 1e6


def run():
    rows = []
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))

    m, k, n = 256, 512, 256
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(k2, (k, n), jnp.float32).astype(jnp.bfloat16)
    _, us = timed(
        lambda: jax.block_until_ready(
            stream_matmul(x, w, block_m=128, block_n=128, block_k=128, interpret=True)
        )
    )
    fl, by = 2 * m * k * n, 2 * (m * k + k * n + m * n)
    rows.append(
        ("kernel_streammm", us, f"tpu_roofline_us={_roofline_us(fl, by):.2f};interpret=True")
    )

    wq = jax.random.randint(k2, (k, n), -127, 127, jnp.int8)
    scales = jnp.ones((k // 128, n), jnp.float32) * 0.01
    _, us = timed(
        lambda: jax.block_until_ready(
            stream_matmul_int8(x, wq, scales, block_m=128, block_n=128, block_k=128, interpret=True)
        )
    )
    by8 = 2 * m * k + k * n + 2 * m * n
    rows.append(
        ("kernel_streammm_int8", us, f"tpu_roofline_us={_roofline_us(fl, by8):.2f};interpret=True")
    )

    b, s, h, hkv, d = 1, 512, 8, 2, 64
    q = jax.random.normal(k1, (b, s, h, d), jnp.float32).astype(jnp.bfloat16)
    kk = jax.random.normal(k2, (b, s, hkv, d), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(k1, (b, s, hkv, d), jnp.float32).astype(jnp.bfloat16)
    _, us = timed(
        lambda: jax.block_until_ready(
            flash_attention(q, kk, v, block_q=128, block_kv=128, interpret=True)
        )
    )
    fl = 2 * 2 * b * h * s * s * d * 0.5  # causal
    by = 2 * (q.size + kk.size + v.size + q.size)
    rows.append(
        ("kernel_flash_attention", us, f"tpu_roofline_us={_roofline_us(fl, by):.2f};interpret=True")
    )

    bb, hh, dd, pt, mp = 4, 8, 64, 32, 8
    pool_k = jax.random.normal(k1, (bb * mp, pt, 2, dd), jnp.float32).astype(jnp.bfloat16)
    pool_v = pool_k
    qq = jax.random.normal(k2, (bb, hh, dd), jnp.float32).astype(jnp.bfloat16)
    table = jnp.arange(bb * mp, dtype=jnp.int32).reshape(bb, mp)
    lens = jnp.full((bb,), pt * mp - 3, jnp.int32)
    _, us = timed(
        lambda: jax.block_until_ready(
            paged_attention(qq, pool_k, pool_v, table, lens, interpret=True)
        )
    )
    by = 2 * (pool_k.size + pool_v.size)
    fl = 2 * 2 * bb * hh * pt * mp * dd
    rows.append(
        ("kernel_paged_attention", us, f"tpu_roofline_us={_roofline_us(fl, by):.2f};interpret=True")
    )
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
