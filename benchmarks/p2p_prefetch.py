"""NVLink peer-to-peer prefetch benchmark: what does the interconnect buy
the extended context switch when tasks migrate under pressure?

Replays one seeded bursty trace with a deliberate **hotspot** (a fraction of
arrivals pinned to gpu0 — a hot tenant) over the same fleet twice:

  * **pcie**   — no peer edges: every migration bulk-transfers the working
    set host-staged (src → host DRAM → dst) at PCIe rates;
  * **nvlink** — an all-to-all NVLink mesh: migrations ship only the
    manifest, the working set lingers on the source, and the target's
    extended context switches *prefetch* it peer-to-peer at the link graph's
    fluid-share bandwidth (host fallback for anything the source evicted).

Headline metric: **working-set movement time per GiB** of migrated working
set — for the pcie fleet the bulk checkpoint transfer, for the nvlink fleet
the manifest hop plus the task's peer fetches (plus a host-fallback penalty
term at the PCIe staging rate). That is the cluster-level cost of the
paper's core move — one proactive migration instead of fragmented faults —
and the acceptance criterion is that the NVLink-rich fleet beats PCIe-only
on it at ≥1.5x oversubscription. TTFT/goodput ride along for the end-to-end
view. Writes ``BENCH_p2p.json``.

Usage: PYTHONPATH=src python -m benchmarks.p2p_prefetch [--smoke]
       [--gpus 4] [--ratio 1.5] [--rate 2.0] [--duration 6.0]
       [--hotspot 0.7]
"""
from __future__ import annotations

import argparse
import dataclasses
import random
import time
from pathlib import Path
from typing import Dict, Optional

from repro.cluster import MSchedPlacement, PlacementPolicy, simulate_cluster
from repro.cluster.topology import homogeneous
from repro.core.hardware import A100_40G, NVLINK_A100_GBPS
from repro.core.scheduler import RoundRobinPolicy
from repro.serving import (
    MSchedAdmission,
    SLOSpec,
    ServedRequestTask,
    Trace,
    bursty_trace,
)

from benchmarks.common import (
    MSCHED_Q,
    export_telemetry,
    make_telemetry,
    print_json,
    write_json,
)

DEFAULT_OUT = Path(__file__).resolve().parent.parent / "BENCH_p2p.json"
TENANTS = ("qwen3-1.7b", "llama3.2-3b")
TARGET_CONCURRENCY = 3
SLO = SLOSpec(ttft_us=3_000_000.0, tpot_us=100_000.0)
REBALANCE_US = 400_000.0
PAGE = 1 << 20
GIB = float(1 << 30)


class HotspotPlacement(PlacementPolicy):
    """Route ``fraction`` of arrivals to gpu0 (the hot tenant's home), the
    rest through the MSched bin-packer — a realistic skew that keeps the
    rebalancer busy."""

    name = "hotspot"

    def __init__(self, fraction: float = 0.7, seed: int = 0):
        self.fraction = fraction
        self._rnd = random.Random(seed)
        self._inner = MSchedPlacement()

    def place(self, prog, arrival_us, cores):
        if self._rnd.random() < self.fraction:
            return 0
        return self._inner.place(prog, arrival_us, cores)


def build_trace(n_gpus: int, rate_per_gpu: float, duration_s: float, seed: int) -> Trace:
    tr = bursty_trace(
        rate_per_gpu * n_gpus, duration_s, seed=seed, cv=4.0,
        tenants=TENANTS, prompt_mean=128, output_mean=96, max_output=192,
    )
    rnd = random.Random(seed + 1)
    reqs = [
        dataclasses.replace(r, tenant=rnd.choice(TENANTS)) for r in tr.requests
    ]
    return Trace(reqs, dict(tr.meta, tenant_mix="iid"))


def mean_request_footprint(trace: Trace) -> float:
    feet: Dict[str, int] = {}
    for tenant in {r.tenant for r in trace}:
        req = next(r for r in trace if r.tenant == tenant)
        feet[tenant] = ServedRequestTask(
            99_000_000, req, page_size=PAGE
        ).footprint_bytes()
    return sum(feet[r.tenant] for r in trace) / len(trace)


def ws_movement_stats(rep) -> Dict[str, object]:
    """Working-set movement accounting over one run's migration log.

    Bulk (``checkpoint``) moves carry their whole working set in the
    transfer: movement time is the link-graph arrival delta. Lazy (``p2p``)
    moves spread it: the manifest hop, plus every peer fetch the target
    issued, plus a host-penalty term for fallback pages (pages the source
    evicted mid-stream, re-fetched from host DRAM at the staging rate)."""
    moved_bytes = 0
    move_us = 0.0
    n_moves = 0
    host_rate = A100_40G.h2d_gbps * 1e3  # bytes/us, the fallback tier
    for m in rep.migrations:
        if m.kind == "checkpoint" and m.pages:
            moved_bytes += m.pages * PAGE
            move_us += m.arrival_us - m.time_us
            n_moves += 1
        elif m.kind == "p2p" and m.pages:
            moved_bytes += m.pages * PAGE
            move_us += m.arrival_us - m.time_us  # manifest hop
            n_moves += 1
    for f in rep.peer_fetches:
        move_us += f.arrival_us - f.time_us
        move_us += f.fallback_pages * PAGE / host_rate
    return {
        "n_ws_moves": n_moves,
        "moved_ws_bytes": moved_bytes,
        "ws_move_us": move_us,
        "ws_move_us_per_gib": (
            move_us / (moved_bytes / GIB) if moved_bytes else None
        ),
    }


def run_bench(
    n_gpus: int = 4,
    ratio: float = 1.5,
    rate_per_gpu: float = 2.0,
    duration_s: float = 6.0,
    seed: int = 42,
    hotspot: float = 0.7,
    drain_factor: float = 8.0,
    out_path: Optional[Path] = DEFAULT_OUT,
    telemetry_path: Optional[Path] = None,
) -> Dict[str, object]:
    # one traced run per invocation: the nvlink fleet (the trace shows the
    # manifest-hop + peer-fetch spans behind the p2p working-set-movement win)
    tel = make_telemetry(telemetry_path)
    trace = build_trace(n_gpus, rate_per_gpu, duration_s, seed)
    foot = mean_request_footprint(trace)
    cap_per_gpu = int(TARGET_CONCURRENCY * foot / ratio)
    report: Dict[str, object] = {
        "benchmark": "p2p_prefetch",
        "n_gpus": n_gpus,
        "ratio": ratio,
        "rate_per_gpu": rate_per_gpu,
        "duration_s": duration_s,
        "seed": seed,
        "hotspot_fraction": hotspot,
        "n_requests": len(trace),
        "cap_per_gpu_bytes": cap_per_gpu,
        "mean_footprint_bytes": foot,
        "nvlink_gbps": NVLINK_A100_GBPS,
        "slo": {"ttft_us": SLO.ttft_us, "tpot_us": SLO.tpot_us},
        "fleets": {},
    }
    for tag, nvlink in (("pcie", None), ("nvlink", NVLINK_A100_GBPS)):
        topo = homogeneous(
            n_gpus, A100_40G, capacity_bytes=cap_per_gpu, nvlink_gbps=nvlink
        )
        t0 = time.perf_counter()
        rep = simulate_cluster(
            trace,
            topo,
            backend="msched",
            placement=HotspotPlacement(hotspot, seed=seed),
            admission_factory=lambda i: MSchedAdmission(headroom=0.9),
            policy_factory=lambda i: RoundRobinPolicy(MSCHED_Q),
            page_size=PAGE,
            slo=SLO,
            drain_factor=drain_factor,
            rebalance_period_us=REBALANCE_US,
            rebalance_threshold=0.4,
            telemetry=tel if tag == "nvlink" else None,
        )
        row = rep.to_row()
        row["wall_s"] = time.perf_counter() - t0
        row.update(ws_movement_stats(rep))
        row["migration_kinds"] = {
            k: sum(1 for m in rep.migrations if m.kind == k)
            for k in ("steal", "checkpoint", "p2p", "retry")
        }
        report["fleets"][tag] = row

    pcie = report["fleets"]["pcie"]
    nv = report["fleets"]["nvlink"]
    report["observed_oversubscription"] = {
        "pcie": pcie["oversubscription"], "nvlink": nv["oversubscription"],
    }
    a, b = nv["ws_move_us_per_gib"], pcie["ws_move_us_per_gib"]
    report["ws_move_speedup"] = (b / a) if (a and b) else None
    # acceptance: at pressure, the NVLink-rich fleet moves migrated working
    # sets faster than host-staged PCIe — the context-switch migration win
    report["meets_target"] = (
        a is not None and b is not None and a < b
    ) or ratio < 1.5
    export_telemetry(tel, telemetry_path)
    if out_path is not None:
        write_json(out_path, report)
    return report


def run(telemetry_path=None):
    """benchmarks.run entry point."""
    report = run_bench(telemetry_path=telemetry_path)
    rows = []
    for tag in ("pcie", "nvlink"):
        row = report["fleets"][tag]
        derived = (
            f"ws_move_us_per_gib={row['ws_move_us_per_gib']};"
            f"goodput={row['goodput_per_s']:.2f}/s;"
            f"ttft_p99_us={row['ttft_p99_us']:.0f};"
            f"peer_fetches={row['peer_fetches']};"
            f"meets={report['meets_target']}"
        )
        rows.append((f"p2p_prefetch_{tag}", row["wall_s"] * 1e6, derived))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--gpus", type=int, default=4)
    ap.add_argument("--ratio", type=float, default=1.5)
    ap.add_argument("--rate", type=float, default=2.0,
                    help="offered requests/s per GPU")
    ap.add_argument("--duration", type=float, default=6.0)
    ap.add_argument("--seed", type=int, default=42)
    ap.add_argument("--hotspot", type=float, default=0.7)
    ap.add_argument(
        "--out", type=Path, default=None,
        help=f"report path (default: {DEFAULT_OUT}; smoke mode writes "
        "only when --out is given explicitly)",
    )
    ap.add_argument(
        "--telemetry", type=Path, default=None, metavar="out.trace",
        help="export a Chrome trace of the nvlink fleet's run",
    )
    ap.add_argument(
        "--smoke", action="store_true",
        help="fast CI config: 2 GPUs, short trace, no artifact",
    )
    args = ap.parse_args()
    if args.smoke:
        report = run_bench(
            n_gpus=2, ratio=args.ratio, rate_per_gpu=args.rate,
            duration_s=3.0, seed=args.seed, hotspot=args.hotspot,
            out_path=args.out, telemetry_path=args.telemetry,
        )
    else:
        report = run_bench(
            args.gpus, args.ratio, args.rate, args.duration, args.seed,
            args.hotspot, out_path=args.out or DEFAULT_OUT,
            telemetry_path=args.telemetry,
        )
    print_json(report)
    if not report["meets_target"]:
        raise SystemExit(
            "NVLink-rich fleet did not beat PCIe-only on working-set "
            "movement time"
        )


if __name__ == "__main__":
    main()
