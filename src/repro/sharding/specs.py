"""Sharding rules: parameter / optimizer-state / activation / cache specs.

Strategy (see DESIGN.md §5):
  * TP over ``model``: attention heads, FFN hidden, vocab, SSM heads,
    RG-LRU channels; experts over ``model`` (EP) when E >= |model|, else
    per-expert tensor parallelism.
  * FSDP over ``data``: the non-TP dimension of every large matrix (ZeRO-
    style, optimizer state follows parameters).
  * DP over ``(pod, data)``: the global batch; gradients reduce over both.
  * SP: decode-time KV caches shard the sequence dim over ``model``
    (flash-decoding-style distributed attention combine by GSPMD).

Rules are path-based over the parameter pytree, so every architecture in the
zoo gets consistent shardings without per-model code.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec


def _path_str(path) -> str:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return "/".join(out)


def _param_spec(cfg: ModelConfig, name: str, ndim: int, shape, mesh) -> P:
    """PartitionSpec for one parameter leaf (without the stacked-layer axis —
    callers prepend None for leaves living under 'layers')."""
    model_n = mesh.shape["model"]
    leaf = name.rsplit("/", 1)[-1]

    def fsdp_ok(dim_size) -> bool:
        return dim_size % mesh.shape["data"] == 0

    def tp_ok(dim_size) -> bool:
        return dim_size % model_n == 0

    if leaf == "embed":  # (V, D)
        return P("model" if tp_ok(shape[0]) else None, None)
    if leaf == "lm_head":  # (D, V)
        return P("data" if fsdp_ok(shape[0]) else None, "model" if tp_ok(shape[1]) else None)
    if leaf in ("wq", "wk", "wv", "w1", "w3", "in_proj", "w_in", "w_gate_branch", "w_a", "w_x"):
        return P(
            "data" if fsdp_ok(shape[0]) else None,
            "model" if tp_ok(shape[1]) else None,
        )
    if leaf in ("wo", "w2", "out_proj", "w_out"):
        return P(
            "model" if tp_ok(shape[0]) else None,
            "data" if fsdp_ok(shape[1]) else None,
        )
    if leaf in ("bq", "bk", "bv"):
        return P("model" if tp_ok(shape[0]) else None)
    if leaf == "router":  # (D, E)
        return P("data" if fsdp_ok(shape[0]) else None, None)
    if leaf == "conv_w":  # (W, C)
        return P(None, "model" if tp_ok(shape[1]) else None)
    if leaf in ("conv_b", "gate_norm", "lam"):
        return P("model" if tp_ok(shape[0]) else None)
    if leaf in ("A_log", "D", "dt_bias"):
        return P("model" if tp_ok(shape[0]) else None)
    # moe experts handled by caller (3D); norms and scalars replicate
    return P(*([None] * ndim))


def _moe_expert_spec(cfg: ModelConfig, shape, mesh) -> P:
    """(E, D, F) or (E, F, D): EP over model when E divides, else TP on the
    hidden dim (per-expert tensor parallelism, e.g. grok-1's 8 experts on a
    16-way model axis)."""
    model_n = mesh.shape["model"]
    e, a, b = shape
    if e % model_n == 0:
        return P("model", "data" if a % mesh.shape["data"] == 0 else None, None)
    # hidden dim is whichever of a/b equals moe.d_ff
    dff = cfg.moe.d_ff
    if b == dff:
        return P(None, "data" if a % mesh.shape["data"] == 0 else None, "model" if b % model_n == 0 else None)
    return P(None, "model" if a % model_n == 0 else None, "data" if b % mesh.shape["data"] == 0 else None)


def param_shardings(cfg: ModelConfig, params_shape: Any, mesh) -> Any:
    """NamedSharding tree matching the (abstract) params tree."""

    def leaf_spec(path, leaf):
        name = _path_str(path)
        shape = leaf.shape
        stacked = (
            "layers/" in name
            or name.startswith("layers")
            or "rec_layers" in name
            or "attn_layers" in name
        )
        if stacked:
            inner_shape = shape[1:]
        else:
            inner_shape = shape
        lname = name.rsplit("/", 1)[-1]
        if cfg.moe is not None and lname in ("w1", "w2", "w3") and len(inner_shape) == 3:
            spec = _moe_expert_spec(cfg, inner_shape, mesh)
        else:
            spec = _param_spec(cfg, name, len(inner_shape), inner_shape, mesh)
        if stacked:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shape)


def opt_state_shardings(cfg: ModelConfig, opt_shape: Any, pspecs: Any, mesh) -> Any:
    """Optimizer state follows parameters (AdamW m/v mirror; Adafactor
    factored moments drop the corresponding axis)."""
    flat_params, _ = jax.tree_util.tree_flatten(pspecs)

    # adamw: {'m': tree, 'v': tree, 'count': scalar}
    def build(node_shape, node_spec):
        return node_spec

    if isinstance(opt_shape, dict) and "m" in opt_shape:
        return {
            "m": pspecs,
            "v": pspecs,
            "count": NamedSharding(mesh, P()),
        }
    if isinstance(opt_shape, dict) and "state" in opt_shape:
        # adafactor: per-leaf {'vr','vc'} or {'v'}
        def leaf_state(param_spec_leaf, state_leaf):
            spec = param_spec_leaf.spec
            if isinstance(state_leaf, dict) and "vr" in state_leaf:
                return {
                    "vr": NamedSharding(mesh, P(*spec[:-1])),
                    "vc": NamedSharding(mesh, P(*(tuple(spec[:-2]) + (spec[-1],)))),
                }
            return {"v": param_spec_leaf}

        state = jax.tree.map(
            leaf_state,
            pspecs,
            opt_shape["state"],
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x),
        )
        return {"state": state, "count": NamedSharding(mesh, P())}
    if isinstance(opt_shape, dict) and "mu" in opt_shape:
        return {"mu": pspecs}
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_shape)


def batch_shardings(cfg: ModelConfig, spec: Dict[str, jax.ShapeDtypeStruct], mesh) -> Dict:
    """Input batch: global batch over (pod, data)."""
    from repro.launch.mesh import dp_axes

    dp = dp_axes(mesh)
    dp = dp if np.prod([mesh.shape[a] for a in dp]) <= list(spec.values())[0].shape[
        0 if "positions3" not in spec else 0
    ] else ("data",)
    out = {}
    for name, sds in spec.items():
        b = sds.shape[0]
        dpa = dp if (b % int(np.prod([mesh.shape[a] for a in dp])) == 0) else None
        if name == "positions3":  # (3, B, S)
            out[name] = NamedSharding(mesh, P(None, dpa, None))
        elif name == "frames":  # (B, S, D)
            out[name] = NamedSharding(mesh, P(dpa, None, None))
        elif name == "vision_embeds":
            out[name] = NamedSharding(mesh, P(dpa, None, None))
        else:  # tokens / labels / frame_mask: (B, S)
            out[name] = NamedSharding(mesh, P(dpa, *([None] * (len(sds.shape) - 1))))
    return out


def cache_shardings(cfg: ModelConfig, cache_shape: Any, mesh, batch: int) -> Any:
    """Decode caches. KV sequence dim shards over `model` (SP / flash-
    decoding); batch over `data` when divisible."""
    data_n = mesh.shape["data"]
    model_n = mesh.shape["model"]
    b_ax = "data" if batch % data_n == 0 else None

    def leaf(path, l):
        name = _path_str(path)
        shape = l.shape
        if name in ("k", "v"):  # (L, B, S, Hkv, hd)
            s_ax = "model" if shape[2] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_ax, s_ax, None, None))
        if name == "state":  # ssm: (L, B, nh, hd, ns)
            h_ax = "model" if shape[2] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_ax, h_ax, None, None))
        if name == "conv":  # ssm: (L,B,W,C) / hybrid: (n_rec,B,W,D)
            c_ax = "model" if shape[-1] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_ax, None, c_ax))
        if name == "h":  # hybrid rec state (n_rec, B, D)
            d_ax = "model" if shape[2] % model_n == 0 else None
            return NamedSharding(mesh, P(None, b_ax, d_ax))
        if name == "index":
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(*([None] * len(shape))))

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def replicated(mesh, tree: Any) -> Any:
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
