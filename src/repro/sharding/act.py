"""Activation sharding constraints.

GSPMD propagates parameter shardings well, but scan carries and gather
outputs can silently resolve to replicated — at trillion-parameter scale that
turns per-device activations into global ones (we measured 74 GB/device of
batch-replicated logits before constraining). Launchers install the mesh via
``use_activation_mesh``; model code sprinkles ``constrain`` calls with
logical axes. Without an installed mesh (unit tests, single-device smoke
runs) ``constrain`` is a no-op.

Logical axes: "dp" (batch: pod+data), "tp" (model), None (replicated).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_state = threading.local()


def _current():
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_activation_mesh(mesh):
    prev = _current()
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.mesh = prev


def _resolve(mesh, axis: Optional[str]):
    if axis is None:
        return None
    if axis == "dp":
        axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        return axes if len(axes) > 1 else axes[0]
    if axis == "tp":
        return "model" if "model" in mesh.axis_names else None
    return axis if axis in mesh.axis_names else None


def axis_size(axis: str) -> int:
    """Size of a logical axis in the installed mesh (0 when no mesh)."""
    mesh = _current()
    if mesh is None:
        return 0
    r = _resolve(mesh, axis)
    if r is None:
        return 0
    n = 1
    for a in (r if isinstance(r, tuple) else (r,)):
        n *= mesh.shape[a]
    return n


def constrain(x, *logical_axes):
    """Constrain ``x`` (or return it untouched when no mesh is installed).

    Axes whose size does not divide the corresponding dimension are dropped
    (GSPMD would pad; we prefer explicit replication there).
    """
    mesh = _current()
    if mesh is None:
        return x
    spec = []
    for dim, ax in zip(x.shape, logical_axes):
        r = _resolve(mesh, ax)
        if r is None:
            spec.append(None)
            continue
        n = 1
        for a in (r if isinstance(r, tuple) else (r,)):
            n *= mesh.shape[a]
        spec.append(r if dim % n == 0 else None)
    if all(s is None for s in spec):
        # nothing shardable: leave GSPMD free — an explicit all-None spec
        # would force REPLICATION (measured 8.8x compiled-FLOPs inflation on
        # grok-1's 8-expert tensors under a 16-way model axis)
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )
