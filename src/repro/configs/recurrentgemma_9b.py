"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2. [arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    head_dim=256,
    rglru=RGLRUConfig(window=2048, pattern=("rec", "rec", "attn")),
    sub_quadratic=True,  # recurrence + sliding-window attention
    notes="Griffin-style: 2 RG-LRU blocks : 1 local-attention block; MQA kv=1",
)
