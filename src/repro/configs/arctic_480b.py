"""arctic-480b [moe] — 128 experts top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    moe=MoEConfig(
        num_experts=128,
        top_k=2,
        d_ff=4864,
        dense_residual=True,
        dense_d_ff=4864,
    ),
    optimizer="adafactor",
    notes="Dense-MoE hybrid: residual dense FFN in parallel with 128e top-2 MoE",
)
