"""llama3.2-3b [dense] — small llama3. [hf:meta-llama/Llama-3.2-1B; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    notes="small llama3; GQA kv=8",
)
