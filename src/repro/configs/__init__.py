"""Architecture registry: the 10 assigned architectures + the paper's Llama3-8B."""
from __future__ import annotations

from repro.configs.base import (
    FAMILIES,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    ShapeSpec,
    SSMConfig,
    SHAPES,
    SHAPE_ORDER,
    cell_applicable,
)

from repro.configs import (  # noqa: E402
    grok1_314b,
    arctic_480b,
    qwen3_1p7b,
    qwen15_110b,
    llama32_3b,
    minicpm_2b,
    qwen2_vl_7b,
    recurrentgemma_9b,
    mamba2_1p3b,
    hubert_xlarge,
    paper_llama3_8b,
)

ARCHS = {
    "grok-1-314b": grok1_314b.CONFIG,
    "arctic-480b": arctic_480b.CONFIG,
    "qwen3-1.7b": qwen3_1p7b.CONFIG,
    "qwen1.5-110b": qwen15_110b.CONFIG,
    "llama3.2-3b": llama32_3b.CONFIG,
    "minicpm-2b": minicpm_2b.CONFIG,
    "qwen2-vl-7b": qwen2_vl_7b.CONFIG,
    "recurrentgemma-9b": recurrentgemma_9b.CONFIG,
    "mamba2-1.3b": mamba2_1p3b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    # the paper's own evaluation model (llama.cpp int8 Llama3-8B)
    "paper-llama3-8b": paper_llama3_8b.CONFIG,
}

ASSIGNED = tuple(k for k in ARCHS if k != "paper-llama3-8b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def list_archs():
    return list(ARCHS)


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "FAMILIES",
    "ModelConfig",
    "MoEConfig",
    "RGLRUConfig",
    "SSMConfig",
    "ShapeSpec",
    "SHAPES",
    "SHAPE_ORDER",
    "cell_applicable",
    "get_config",
    "list_archs",
]
