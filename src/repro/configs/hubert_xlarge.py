"""hubert-xlarge [audio] — encoder-only, same arch as w2v2. [arXiv:2106.07447; unverified]

Backbone transformer only; the CNN waveform frontend is a stub:
``input_specs()`` provides precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5120,
    vocab_size=504,  # masked-prediction codebook targets
    head_dim=80,
    causal=False,  # bidirectional encoder
    has_decode=False,  # encoder-only: no autoregressive decode step
    frontend="frames",
    notes="Encoder-only (w2v2 arch); MHA; masked-frame prediction objective",
)
