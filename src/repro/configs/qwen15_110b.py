"""qwen1.5-110b [dense] — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    notes="QKV bias; GQA kv=8",
)
