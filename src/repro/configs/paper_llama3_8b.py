"""The paper's own evaluation model: int8-quantized Llama3-8B (llama.cpp), 8.5 GB.

Used by the MSched benchmarks (Figs. 1, 2, 7, 8) to generate the decode command
stream and ground-truth working sets.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="paper-llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    dtype="int8",  # weight quantization as in the paper's llama.cpp setup
    notes="Paper workload (Fig. 1): int8 Llama3-8B, 8.5 GB working set",
)
