"""mamba2-1.3b [ssm] — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,  # attention-free
    num_kv_heads=0,
    d_ff=0,  # no MLP; the mamba block is the whole layer
    vocab_size=50280,
    ssm=SSMConfig(state_dim=128, head_dim=64, conv_width=4, expand=2, chunk=256),
    sub_quadratic=True,  # O(1) decode state
    notes="SSD chunked algorithm; attention-free; constant-size decode state",
)
