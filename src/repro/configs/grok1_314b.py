"""grok-1-314b [moe] — 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32768),
    optimizer="adafactor",  # AdamW state (12 B/param) would exceed 16 GB/chip
    notes="MoE 8e top-2; GQA kv=8",
)
