"""qwen2-vl-7b [vlm] — M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone transformer only (per assignment); the vision frontend is a stub:
``input_specs()`` provides precomputed patch embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18944,
    vocab_size=152064,
    head_dim=128,
    qkv_bias=True,
    mrope_sections=(16, 24, 24),  # (temporal, height, width) rotary sections
    frontend="patch",
    notes="M-RoPE backbone; patch-embedding frontend stubbed per assignment",
)
