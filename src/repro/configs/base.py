"""Model / shape configuration system.

Every assigned architecture is expressed as a frozen ``ModelConfig``. The same
config object drives:
  * model construction (``repro.models.model.build_model``),
  * sharding rules (``repro.sharding.specs``),
  * the dry-run input specs (``repro.launch.specs``),
  * the MSched workload generators (``repro.core.workloads``) — each config
    deterministically yields the command stream + ground-truth working sets
    that the paper's predictor/scheduler operate on.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# --------------------------------------------------------------------------
# Sub-configs
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-Experts feed-forward settings."""

    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden width
    dense_residual: bool = False  # Arctic: dense FFN running in parallel
    dense_d_ff: int = 0
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD (state-space duality) settings."""

    state_dim: int = 128
    head_dim: int = 64
    conv_width: int = 4
    expand: int = 2
    chunk: int = 256  # SSD chunk length


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    """RecurrentGemma (Griffin) RG-LRU + local-attention settings."""

    window: int = 2048
    # Griffin pattern: two recurrent blocks followed by one local-attn block.
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    conv_width: int = 4


# --------------------------------------------------------------------------
# Main config
# --------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "vlm", "audio")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default: d_model // num_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    causal: bool = True  # False => bidirectional encoder (hubert)
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: Optional[str] = None  # 'patch' (vlm) | 'frames' (audio); stubs
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # substrate defaults
    optimizer: str = "adamw"  # 'adamw' | 'adafactor'
    schedule: str = "cosine"  # 'cosine' | 'wsd'
    remat: bool = True
    # capability flags
    sub_quadratic: bool = False  # can run long_500k
    has_decode: bool = True  # False for encoder-only archs
    notes: str = ""

    # -- derived ----------------------------------------------------------
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim() if self.num_heads else 0
        total = V * d  # embedding
        if not self.tie_embeddings:
            total += V * d  # lm head / output proj
        per_layer = 0
        if self.family == "ssm":
            assert self.ssm is not None
            di = self.ssm.expand * d
            nheads = di // self.ssm.head_dim
            # in_proj -> [z, x, B, C, dt], out_proj
            per_layer += d * (2 * di + 2 * self.ssm.state_dim + nheads)
            per_layer += di * d  # out proj
            per_layer += self.ssm.conv_width * (di + 2 * self.ssm.state_dim)
            per_layer += 3 * nheads  # A_log, D, dt_bias
            per_layer += d  # norm
        else:
            layer_kinds = self.layer_kinds()
            # attention params (per attn layer)
            attn = d * hd * self.num_heads  # q
            attn += 2 * d * hd * self.num_kv_heads  # k, v
            attn += hd * self.num_heads * d  # o
            if self.qkv_bias:
                attn += hd * (self.num_heads + 2 * self.num_kv_heads)
            # mlp params
            if self.moe is not None:
                mlp = self.moe.num_experts * 3 * d * self.moe.d_ff
                mlp += d * self.moe.num_experts  # router
                if self.moe.dense_residual:
                    mlp += 3 * d * self.moe.dense_d_ff
            else:
                mlp = 3 * d * self.d_ff
            rec = 0
            if self.rglru is not None:
                # recurrent block: two input projs, conv, gates, out proj
                rec = 2 * d * d + self.rglru.conv_width * d + 2 * d * d + d * d + 2 * d
            n_attn = sum(1 for k in layer_kinds if k == "attn")
            n_rec = sum(1 for k in layer_kinds if k == "rec")
            per_layer = 0
            total += n_attn * (attn + mlp + 2 * d) + n_rec * (rec + mlp + 2 * d)
            total += d  # final norm
            return total
        total += per_layer * L + d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        inactive_experts = self.moe.num_experts - self.moe.top_k
        per_layer_inactive = inactive_experts * 3 * d * self.moe.d_ff
        return full - per_layer_inactive * self.num_layers

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer temporal-mixing kind: 'attn' | 'rec' | 'ssm'."""
        if self.family == "ssm":
            return tuple("ssm" for _ in range(self.num_layers))
        if self.rglru is not None:
            pat = self.rglru.pattern
            kinds = [pat[i % len(pat)] for i in range(self.num_layers)]
            return tuple(kinds)
        return tuple("attn" for _ in range(self.num_layers))

    # -- smoke-test shrink -------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 3 if self.rglru is not None else 2),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_heads else 0,
            head_dim=32 if self.num_heads else None,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            remat=False,
        )
        if self.num_kv_heads == self.num_heads and self.num_heads:
            changes["num_kv_heads"] = 4  # keep MHA archs MHA
        if self.mrope_sections is not None:
            # rescale M-RoPE sections to the reduced head_dim (sum == hd // 2)
            changes["mrope_sections"] = (4, 6, 6)
        if self.moe is not None:
            changes["moe"] = MoEConfig(
                num_experts=4,
                top_k=min(self.moe.top_k, 2),
                d_ff=128,
                dense_residual=self.moe.dense_residual,
                dense_d_ff=128 if self.moe.dense_residual else 0,
                capacity_factor=2.0,
            )
        if self.ssm is not None:
            changes["ssm"] = SSMConfig(
                state_dim=16, head_dim=16, conv_width=4, expand=2, chunk=32
            )
        if self.rglru is not None:
            changes["rglru"] = RGLRUConfig(
                window=16, pattern=self.rglru.pattern, conv_width=4
            )
        if self.rglru is not None:
            changes["num_layers"] = 3  # one full (rec, rec, attn) pattern
        return dataclasses.replace(self, **changes)


# --------------------------------------------------------------------------
# Input shapes (assigned)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def cell_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether (arch x shape) is runnable; else the documented skip reason."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only arch: no autoregressive decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k requires sub-quadratic attention"
    return True, ""
