"""The crash-safe control plane: lifecycle tracking, the write-ahead
decision journal, coordinator crash/recovery, and SLO deadline enforcement.

One :class:`ControlPlane` observes (and, when deadlines or coordinator
faults are configured, steers) a whole ``simulate_cluster`` run. Its state
splits in two, and the split is the whole design:

  * **durable** — the :class:`~repro.control.journal.DecisionJournal`
    (per-node agents keep appending even while the coordinator is down) and
    the client-side backlog of arrivals buffered during an outage (clients
    retry on reconnect, identically under every recovery mode);
  * **coordinator-volatile** — the lifecycle map, the deadline monitor's
    escalation counters, the peer-prefetch page directory, and the fault
    runtime's held/stranded/retry queues. A ``coordinator_crash`` fault
    wipes all of it mid-run.

``recovery="journal"`` rebuilds the volatile state by replaying the journal
against the surviving cores: lifecycle from the record stream, linger-hint
directory entries from unconsumed lazy-migration records validated against
live pool residency, and the fault runtime's queues from unreleased
``hold``/``strand``/``requeue`` records. The replay is idempotent —
replaying twice changes nothing (``replay_check=True`` asserts it at every
recovery, the CI chaos smoke's divergence check). ``recovery="cold"`` is
the ablation baseline: the restarted coordinator rediscovers only what the
cores still hold — parked victims and linger hints are simply lost.

Attached to a zero-fault run with no deadline monitoring, the control plane
is a pure observer: it adds no events to the DES loop and mutates nothing,
so such runs stay bit-for-bit identical to runs without it (pinned in
tests/control/test_control_plane.py).
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Sequence

from repro.core.hbm import resident_runs_in
from repro.core.invariants import InvariantViolation
from repro.core.simulator import RequestRecord, TaskArrival
from repro.cluster.migration import ResumedTask
from repro.telemetry.hub import TRACK_CLUSTER
from repro.control.deadline import DeadlineMonitor, DeadlineSpec, slo_class_of
from repro.control.journal import DecisionJournal
from repro.control.lifecycle import (
    ADMITTED,
    RUNNING,
    TERMINAL_STATES,
    TaskLifecycle,
    apply_event,
)


class ControlPlane:
    """Submit/cancel/status API, decision journaling, crash recovery, and
    deadline enforcement over one cluster run.

    ``recovery`` picks how a ``coordinator_recover`` fault rebuilds the
    volatile state (``"journal"`` replay vs ``"cold"`` rediscovery);
    ``deadlines`` (+ ``deadline_period_us``) enables the RT deadline
    monitor. One instance serves exactly one run — :meth:`attach` refuses
    reuse, because the journal is the run's durable history.
    """

    def __init__(
        self,
        deadlines: Optional[DeadlineSpec] = None,
        deadline_period_us: Optional[float] = None,
        recovery: str = "journal",
        preempt_backoff_us: float = 50_000.0,
        preempt_backoff_cap_us: float = 400_000.0,
        max_preemptions: int = 3,
        replay_check: bool = False,
    ):
        if recovery not in ("journal", "cold"):
            raise ValueError(
                f"unknown control-plane recovery mode {recovery!r} "
                "(expected 'journal' or 'cold')"
            )
        self.recovery = recovery
        self.deadlines = deadlines
        self.deadline_period_us = deadline_period_us
        self.monitor = (
            DeadlineMonitor(
                deadlines,
                backoff_us=preempt_backoff_us,
                backoff_cap_us=preempt_backoff_cap_us,
                max_preemptions=max_preemptions,
            )
            if deadlines is not None and deadline_period_us
            else None
        )
        self.replay_check = replay_check

        self.journal = DecisionJournal()  # durable
        self.lifecycle = TaskLifecycle()  # coordinator-volatile
        self.down = False
        self.crashes = 0
        self.replays = 0
        self.preemptions = 0
        self.deadline_sheds = 0
        self.deadline_misses = 0  # filled by finalize()
        self.rt_requests = 0
        self.lost = 0

        # client-retry buffer for arrivals during an outage (external state:
        # identical under both recovery modes, by design)
        self._backlog: List[TaskArrival] = []
        self._lost_records: List[RequestRecord] = []
        # scheduled operator ops: (time_us, seq, ("submit", ev) | ("cancel", tid))
        self._ops: List[tuple] = []
        self._opseq = 0
        self._next_deadline = (
            deadline_period_us if self.monitor is not None else float("inf")
        )
        self._miss_emitted: set = set()

        # wired by attach()
        self._attached = False
        self.cores: Sequence = ()
        self.topology = None
        self.placement = None
        self.fabric = None
        self.rebalancer = None
        self.vault = None
        self.fault_rt = None
        self.telemetry = None
        self.placed: List[int] = []

    # -- wiring ---------------------------------------------------------------
    def attach(
        self,
        cores: Sequence,
        topology=None,
        placement=None,
        fabric=None,
        rebalancer=None,
        vault=None,
        fault_rt=None,
        telemetry=None,
    ) -> None:
        if self._attached:
            raise ValueError(
                "ControlPlane instances serve exactly one run; construct a "
                "fresh one per simulate_cluster call"
            )
        self._attached = True
        self.cores = list(cores)
        self.topology = topology
        self.placement = placement
        self.fabric = fabric
        self.rebalancer = rebalancer
        self.vault = vault
        self.fault_rt = fault_rt
        self.telemetry = telemetry
        self.placed = [0] * len(self.cores)
        for core in self.cores:
            core.lifecycle_hook = (
                lambda tid, event, now, _c=core: self._core_event(
                    _c, tid, event, now
                )
            )
        for component in (fault_rt, rebalancer, vault):
            if component is not None:
                component.control = self

    # -- the write-ahead journal ----------------------------------------------
    def record(
        self, kind: str, now: float, task_id: Optional[int] = None, **payload
    ):
        """Append the decision to the journal *before* it takes effect, then
        apply its lifecycle transition. While the coordinator is down the
        per-node agents still journal (the log is durable) but the lifecycle
        map is dead — replay reconstructs it at recovery."""
        rec = self.journal.append(kind, now, task_id, **payload)
        if not self.down:
            apply_event(self.lifecycle, kind, task_id, now)
        return rec

    def _core_event(self, core, tid: int, event: str, now: float) -> None:
        kind = {"admitted": "admit", "finished": "finish", "rejected": "reject"}[
            event
        ]
        self.record(kind, now, tid, gpu=core.name)

    # -- submit/cancel/status -------------------------------------------------
    def submit(self, program, time_us: float, meta: Optional[dict] = None):
        """Schedule a client submission at ``time_us`` (processed by the
        engine's control tick)."""
        ev = TaskArrival(time_us, program, dict(meta or {}))
        heapq.heappush(self._ops, (time_us, self._opseq, ("submit", ev)))
        self._opseq += 1
        return ev

    def cancel(self, task_id: int, time_us: float) -> None:
        """Schedule an operator cancel at ``time_us``."""
        heapq.heappush(self._ops, (time_us, self._opseq, ("cancel", task_id)))
        self._opseq += 1

    def status(self, task_id: int) -> Optional[str]:
        """Current lifecycle state, or None for an unknown task (including
        every task while the coordinator is down — the map is volatile)."""
        return self.lifecycle.state(task_id)

    def prediction_health(self) -> Optional[dict]:
        """Fleet prediction-accuracy gauges from the online auditor, so
        operators see template health next to the deadline counters.  None
        when the run is untraced or the hub has no auditor attached."""
        tel = self.telemetry
        aud = getattr(tel, "audit", None) if tel is not None else None
        if aud is None or not aud.fleet.commands:
            return None
        return aud.health()

    # -- engine interface -----------------------------------------------------
    def next_time(self) -> float:
        if self.down:
            return float("inf")
        t = self._ops[0][0] if self._ops else float("inf")
        return min(t, self._next_deadline)

    def tick(self, now: float) -> None:
        while self._ops and self._ops[0][0] <= now:
            _t, _s, (op, arg) = heapq.heappop(self._ops)
            if op == "submit":
                self._submit_and_place(arg, now)
            else:
                self._do_cancel(arg, now)
        if self.monitor is not None and now >= self._next_deadline:
            self._deadline_tick(now)
            while self._next_deadline <= now:
                self._next_deadline += self.deadline_period_us

    def on_arrival(self, ev: TaskArrival) -> Optional[int]:
        """Route one trace arrival. During an outage the arrival is
        buffered client-side and retried at ``coordinator_recover``."""
        if self.down:
            self._backlog.append(ev)
            return None
        return self._submit_and_place(ev, ev.time_us)

    def _submit_and_place(self, ev: TaskArrival, now: float) -> Optional[int]:
        tid = ev.program.task_id
        self.record(
            "submit",
            now,
            tid,
            tenant=ev.meta.get("tenant"),
            slo_class=slo_class_of(ev.meta, ev.program),
            arrival_us=ev.time_us,
            ev=ev,
        )
        if self.fault_rt is not None:
            # the fault runtime journals the place (or hold) itself
            return self.fault_rt.dispatch(ev)
        gi = self.placement.place(ev.program, ev.time_us, self.cores)
        self.record("place", now, tid, gpu=self.cores[gi].name)
        self.cores[gi].inject(ev)
        self.placed[gi] += 1
        return gi

    def _do_cancel(self, tid: int, now: float) -> bool:
        st = self.lifecycle.state(tid)
        if st is None or st in TERMINAL_STATES:
            return False
        self.record("cancel", now, tid, prior=st)
        found = False
        for core in self.cores:
            if not core.failed and core.cancel_task(tid, now):
                found = True
                break
        if not found and self.fault_rt is not None:
            found = self._cancel_parked(tid, now)
        if self.fabric is not None:
            self.fabric.release(tid)
        if self.vault is not None:
            self.vault.drop(tid)
        if self.telemetry is not None:
            self.telemetry.instant(
                "cancel", TRACK_CLUSTER, now, task_id=tid, found=found
            )
        return True

    def _cancel_parked(self, tid: int, now: float) -> bool:
        """Cancel a task parked in a coordinator queue (held/stranded/
        backing off)."""
        frt = self.fault_rt
        for i, (ev, _w, rec) in enumerate(frt._held):
            if ev.program.task_id == tid:
                del frt._held[i]
                self.record("release", now, tid, of="hold", why="cancel")
                self._mark_cancelled(rec, tid, ev.time_us, now)
                return True
        for i, (prog, completed, rec, _o) in enumerate(frt._stranded):
            if prog.task_id == tid:
                del frt._stranded[i]
                self.record("release", now, tid, of="strand", why="cancel")
                self._mark_cancelled(rec, tid, 0.0, now, completed)
                return True
        for i, (_d, _s, victim) in enumerate(frt._retryq):
            if victim[0].task_id == tid:
                del frt._retryq[i]
                heapq.heapify(frt._retryq)
                self.record("release", now, tid, of="requeue", why="cancel")
                self._mark_cancelled(victim[2], tid, 0.0, now, victim[1])
                return True
        return False

    def _mark_cancelled(
        self, rec, tid: int, arrival_us: float, now: float, completed: int = 0
    ) -> None:
        if rec is not None:
            rec.rejected = True
            rec.meta["cancelled_us"] = now
        else:
            self._lost_records.append(
                RequestRecord(
                    tid,
                    arrival_us,
                    rejected=True,
                    iterations_done=completed,
                    meta={"cancelled_us": now},
                )
            )

    # -- deadline enforcement -------------------------------------------------
    def _deadline_tick(self, now: float) -> None:
        for core in self.cores:
            if core.failed:
                continue
            risky = self.monitor.at_risk(core, now)
            if not risky:
                continue
            if self.telemetry is not None:
                for tid in risky:
                    if tid not in self._miss_emitted:
                        self._miss_emitted.add(tid)
                        self.telemetry.instant(
                            "deadline_miss",
                            core.name,
                            now,
                            task_id=tid,
                            projected=True,
                        )
            victim = self.monitor.pick_victim(core, now)
            if victim is None:
                continue  # nothing best-effort to preempt here
            if self.monitor.preempt_count(victim) >= self.monitor.max_preemptions:
                self._deadline_shed(core, victim, now, rt_task=risky[0])
            else:
                self._preempt(core, victim, now, rt_task=risky[0])

    def _preempt(self, core, victim: int, now: float, rt_task: int) -> None:
        backoff = self.monitor.backoff_for(victim)
        self.record(
            "preempt",
            now,
            victim,
            gpu=core.name,
            rt_task=rt_task,
            backoff_us=backoff,
            count=self.monitor.preempt_count(victim),
        )
        ej = core.eject(victim)
        if ej.record is not None:
            ej.record.meta["preempted_us"] = now
        cont = (
            ResumedTask(ej.program, ej.completed) if ej.completed else ej.program
        )
        core.inject(
            TaskArrival(
                now + backoff,
                cont,
                meta={
                    "migrated_from": core.name,
                    "preempted": True,
                    "slo_class": slo_class_of(
                        ej.record.meta if ej.record else None, ej.program
                    ),
                },
            )
        )
        self.preemptions += 1
        if self.telemetry is not None:
            self.telemetry.instant(
                "preempt",
                core.name,
                now,
                task_id=victim,
                rt_task=rt_task,
                backoff_us=backoff,
            )

    def _deadline_shed(self, core, victim: int, now: float, rt_task: int) -> None:
        # the escalation ladder's last rung: through MIGRATING (the eject)
        # to SHED, mirroring the lifecycle graph's RUNNING -> MIGRATING ->
        # SHED path
        self.record("preempt", now, victim, gpu=core.name, escalated=True)
        self.record("shed", now, victim, gpu=core.name, rt_task=rt_task)
        ej = core.eject(victim)
        if ej.record is not None:
            ej.record.rejected = True
            ej.record.meta["deadline_shed_us"] = now
        if self.fabric is not None:
            self.fabric.release(victim)
        if self.vault is not None:
            self.vault.drop(victim)
        self.deadline_sheds += 1
        if self.telemetry is not None:
            self.telemetry.instant(
                "shed", core.name, now, task_id=victim, reason="deadline_shed"
            )

    # -- coordinator crash/recovery -------------------------------------------
    def crash(self, now: float) -> None:
        """``coordinator_crash``: every piece of coordinator-volatile state
        dies. The journal (durable, node-local) survives."""
        if self.down:
            return
        self.down = True
        self.crashes += 1
        self.journal.append("crash", now)
        if self.telemetry is not None:
            self.telemetry.instant("coordinator_crash", TRACK_CLUSTER, now)
        self.lifecycle = TaskLifecycle()
        if self.monitor is not None:
            self.monitor.reset()
        self._miss_emitted.clear()
        if self.fabric is not None:
            for e in list(self.fabric.directory.entries()):
                self.fabric.directory.forget(e.task_id)
        if self.fault_rt is not None:
            if self.recovery == "journal":
                # the in-memory queues die; the journal holds the only copy
                self.fault_rt.wipe_queues()
            else:
                self._lost_records.extend(
                    self.fault_rt.drop_queues(now, "coordinator_crash")
                )

    def recover(self, now: float) -> None:
        if not self.down:
            return
        self.down = False
        self.journal.append("recover", now, mode=self.recovery)
        if self.telemetry is not None:
            self.telemetry.instant("coordinator_recover", TRACK_CLUSTER, now)
        if self.recovery == "journal":
            self._replay(now)
            if self.replay_check:
                fp1 = self._state_fingerprint()
                self._replay(now)
                if self._state_fingerprint() != fp1:
                    raise InvariantViolation(
                        "journal replay diverged: replaying twice at "
                        f"t={now:.0f}us is not a no-op"
                    )
            self.replays += 1
            if self.telemetry is not None:
                self.telemetry.instant(
                    "journal_replay",
                    TRACK_CLUSTER,
                    now,
                    records=len(self.journal),
                )
        else:
            self._cold_restart(now)
        # clients retry everything buffered during the outage — identical
        # under both modes, so the recovery comparison isolates queue and
        # hint loss
        backlog, self._backlog = self._backlog, []
        for ev in backlog:
            self._submit_and_place(ev, now)
        if self.fault_rt is not None:
            self.fault_rt._flush(now)
            self.fault_rt.drain_due_retries(now)
        if self.monitor is not None:
            self._next_deadline = max(
                self._next_deadline, now + self.deadline_period_us
            )

    # -- journal replay -------------------------------------------------------
    def _replay(self, now: float) -> None:
        lc = TaskLifecycle()
        for r in self.journal.records:
            apply_event(lc, r.kind, r.task_id, r.time_us)
        self.lifecycle = lc
        if self.fabric is not None:
            self._rebuild_directory(now)
        if self.fault_rt is not None:
            self._rebuild_queues()

    def _rebuild_directory(self, now: float) -> None:
        """Reconstruct linger hints: for every surviving linger flag, the
        journal's last lazy-migration record supplies src/dst/arrival, the
        live pool supplies the (possibly shrunken) resident runs, and
        anything unverifiable is reclaimed — recovery must close the
        orphaned-copy window the crash opened."""
        last_linger: Dict[int, object] = {}
        terminal: set = set()
        for r in self.journal.records:
            if r.kind == "migrate" and r.payload.get("linger"):
                last_linger[r.task_id] = r
            elif r.kind in ("finish", "reject", "shed", "cancel"):
                terminal.add(r.task_id)
        locate: Dict[int, str] = {}
        for core in self.cores:
            if core.failed:
                continue
            for tid in core.tasks:
                locate[tid] = core.name
            for ev, _r, _p in core.waiting:
                locate[ev.program.task_id] = core.name
            for ev in core.pending:
                locate[ev.program.task_id] = core.name
        directory = self.fabric.directory
        for core in self.cores:
            if core.failed:
                continue
            for tid in sorted(core.lingering):
                if directory.get(tid) is not None:
                    continue  # idempotent re-entry: already rebuilt
                rec = last_linger.get(tid)
                runs = []
                dst = None
                ok = (
                    rec is not None
                    and rec.payload.get("src") == core.name
                    and tid not in terminal
                )
                if ok:
                    dst = locate.get(tid, rec.payload.get("dst"))
                    ok = (
                        dst is not None
                        and dst != core.name
                        and self.topology.nvlink_peer(core.name, dst)
                        is not None
                    )
                if ok:
                    span = core.pool._task_spans.get(tid)
                    runs = (
                        resident_runs_in(core.pool, span)
                        if span is not None
                        else []
                    )
                    ok = bool(runs)
                if not ok:
                    self.fabric.reclaimed_pages += core.reclaim_linger(tid)
                    continue
                directory.record(
                    tid,
                    core.name,
                    dst,
                    runs,
                    rec.payload.get("arrival_us", now),
                )

    def _rebuild_queues(self) -> None:
        """Re-park unreleased hold/strand/requeue records into the fault
        runtime's queues (the payload references are the durable copy).
        Items already present — parked while the coordinator was down —
        are recognized by identity, keeping the rebuild idempotent."""
        frt = self.fault_rt
        held_ids = {id(t[0]) for t in frt._held}
        stranded_ids = {id(t[0]) for t in frt._stranded}
        retry_ids = {id(v[0]) for _d, _s, v in frt._retryq}
        for r in self.journal.unreleased():
            p = r.payload
            if r.kind == "hold":
                ev = p["ev"]
                if id(ev) not in held_ids:
                    frt._held.append((ev, p.get("warm"), p.get("rec")))
                    held_ids.add(id(ev))
            elif r.kind == "strand":
                prog = p["prog"]
                if id(prog) not in stranded_ids:
                    frt._stranded.append(
                        (prog, p["completed"], p.get("rec"), p["origin"])
                    )
                    stranded_ids.add(id(prog))
            elif r.kind == "requeue":
                prog = p["prog"]
                if id(prog) not in retry_ids:
                    heapq.heappush(
                        frt._retryq,
                        (
                            p["due_us"],
                            frt._seq,
                            (
                                prog,
                                p["completed"],
                                p.get("rec"),
                                p["origin"],
                                p["attempt"],
                            ),
                        ),
                    )
                    frt._seq += 1
                    retry_ids.add(id(prog))

    def _state_fingerprint(self):
        """Everything replay reconstructs, hashable — equal fingerprints
        before/after a second replay certify idempotence."""
        dir_entries = ()
        if self.fabric is not None:
            dir_entries = tuple(
                sorted(
                    (e.task_id, e.src, e.dst, tuple(e.runs), e.arrival_us)
                    for e in self.fabric.directory.entries()
                )
            )
        linger = tuple(tuple(sorted(c.lingering)) for c in self.cores)
        queues = ()
        if self.fault_rt is not None:
            frt = self.fault_rt
            queues = (
                tuple(id(t[0]) for t in frt._held),
                tuple(id(t[0]) for t in frt._stranded),
                tuple(
                    (d, id(v[0])) for d, _s, v in sorted(frt._retryq)
                ),
            )
        return (
            tuple(sorted(self.lifecycle.states().items())),
            dir_entries,
            linger,
            queues,
        )

    # -- cold restart ---------------------------------------------------------
    def _cold_restart(self, now: float) -> None:
        """The ablation baseline: an amnesiac coordinator rediscovers only
        what the data plane still holds. Work parked in coordinator queues
        (including victims stranded during the outage) and every linger
        hint are lost — exactly the cost the journal exists to avoid."""
        if self.fault_rt is not None:
            self._lost_records.extend(
                self.fault_rt.drop_queues(now, "coordinator_outage")
            )
        lc = TaskLifecycle()
        for core in self.cores:
            if core.failed:
                continue
            for tid in core.tasks:
                lc.assume(tid, RUNNING, now)
            for ev, _r, _p in core.waiting:
                lc.assume(ev.program.task_id, ADMITTED, now)
            for ev in core.pending:
                lc.assume(ev.program.task_id, ADMITTED, now)
            for tid in list(core.lingering):
                # hints unknowable without the journal: reclaim the copies
                self._reclaim(core, tid)
        self.lifecycle = lc

    def _reclaim(self, core, tid: int) -> None:
        freed = core.reclaim_linger(tid)
        if self.fabric is not None:
            self.fabric.reclaimed_pages += freed

    # -- end-of-run accounting ------------------------------------------------
    def drain_lost(self) -> List[RequestRecord]:
        """Account work the control plane lost (cold-dropped queues,
        cancels of parked items, and — if the run ends mid-outage — the
        client backlog plus journal-parked work the replay never ran)."""
        out, self._lost_records = self._lost_records, []
        if not self.down:
            return out
        if self.recovery == "journal" and self.fault_rt is not None:
            frt = self.fault_rt
            live = (
                {id(t[0]) for t in frt._held}
                | {id(t[0]) for t in frt._stranded}
                | {id(v[0]) for _d, _s, v in frt._retryq}
            )
            for r in self.journal.unreleased():
                obj = r.payload.get("ev") or r.payload.get("prog")
                if obj is None or id(obj) in live:
                    continue  # still parked: fault_rt drain accounts it
                self.lost += 1
                rec = r.payload.get("rec")
                if rec is not None:
                    rec.rejected = True
                    rec.meta["lost"] = "coordinator_down"
                else:
                    out.append(
                        RequestRecord(
                            r.task_id,
                            getattr(obj, "time_us", 0.0),
                            rejected=True,
                            iterations_done=r.payload.get("completed", 0),
                            meta={"lost": "coordinator_down"},
                        )
                    )
        for ev in self._backlog:
            self.lost += 1
            out.append(
                RequestRecord(
                    ev.program.task_id,
                    ev.time_us,
                    rejected=True,
                    meta=dict(ev.meta, lost="coordinator_down"),
                )
            )
        self._backlog.clear()
        if self.fabric is not None:
            # the wiped directory can never reap surviving linger flags
            for core in self.cores:
                if core.failed:
                    continue
                for tid in list(core.lingering):
                    if self.fabric.directory.get(tid) is None:
                        self._reclaim(core, tid)
        return out

    def finalize(self, records: Sequence[RequestRecord]) -> None:
        """Deadline-miss accounting over the merged request records: an RT
        request misses when it never finished, blew its TTFT budget, or
        blew its completion budget."""
        if self.deadlines is None:
            return
        spec = self.deadlines
        misses = 0
        rt = 0
        for rec in records:
            if slo_class_of(rec.meta, None) != "rt":
                continue
            rt += 1
            ttft = rec.ttft_us()
            lat = rec.latency_us()
            if rec.finished_us is None:
                misses += 1
            elif ttft is not None and ttft > spec.rt_ttft_us:
                misses += 1
            elif lat is not None and lat > spec.rt_latency_us:
                misses += 1
        self.deadline_misses = misses
        self.rt_requests = rt
