"""The write-ahead decision journal: every scheduler decision, appended
*before* it takes effect.

The journal is the control plane's only durable state across a
``coordinator_crash`` (fsync-free and in-sim: per-node agents append to a
log the coordinator's memory loss cannot touch, the way etcd/raft logs
survive an apiserver restart). Records carry two kinds of payload field:

  * **primitive** fields (str/int/float/bool/None) — what ``to_json``
    exports for the ``msctl`` CLI and offline lifecycle replay;
  * **reference** fields (live sim objects: ``TaskArrival``\\ s, programs,
    request records) — what :meth:`ControlPlane.replay` re-inserts into the
    fault runtime's queues after a crash. In-sim, the durable log *is* the
    object store.

``hold``/``strand``/``requeue`` records are matched against ``release``
records (FIFO per ``(kind, task_id)``) to find work that was parked in a
coordinator queue and never dispatched — exactly what replay must
reconstruct and what end-of-run drain must account as lost if the
coordinator never came back.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

# every decision kind the control plane journals. "crash"/"recover" are
# markers (no lifecycle effect); "hold"/"strand"/"requeue"/"release" are
# coordinator-queue bookkeeping; the rest map 1:1 onto lifecycle events.
JOURNAL_KINDS = frozenset(
    {
        "submit",  # client arrival accepted by the control plane
        "place",  # placement decision (fresh or re-dispatched arrival)
        "admit",  # core admitted the task (data-plane ack)
        "finish",  # task retired
        "reject",  # admission reject / graceful-degradation shed
        "shed",  # deadline-enforcement shed of a running task
        "cancel",  # operator cancel
        "migrate",  # rebalancer checkpoint/p2p move decision
        "reroute",  # steal or retry bounce (state-preserving)
        "checkpoint",  # vault snapshot decision
        "recovery",  # recovery-tier choice for a fault victim
        "preempt",  # deadline-enforcement BE preemption
        "fail",  # a core failure/crash tore the task down
        "hold",  # arrival parked: no alive GPU / coordinator down
        "strand",  # running victim parked: no alive GPU / coordinator down
        "requeue",  # denied restore backing off on the retry heap
        "release",  # a parked item left its queue (payload "of" names it)
        "crash",  # coordinator_crash marker
        "recover",  # coordinator_recover marker
    }
)

_PRIMITIVES = (str, int, float, bool, type(None))


@dataclasses.dataclass(frozen=True)
class JournalRecord:
    """One appended decision. ``seq`` is the global append order (replay
    order); ``payload`` holds both primitive and reference fields."""

    seq: int
    time_us: float
    kind: str
    task_id: Optional[int]
    payload: Dict[str, object]

    def primitives(self) -> Dict[str, object]:
        return {
            k: v
            for k, v in self.payload.items()
            if isinstance(v, _PRIMITIVES)
        }


class DecisionJournal:
    """Append-only decision log. Appending an unknown kind raises — the
    journal's schema is closed, mirroring ``EVENT_TYPES``."""

    def __init__(self):
        self.records: List[JournalRecord] = []
        self._seq = 0

    def append(
        self,
        kind: str,
        time_us: float,
        task_id: Optional[int] = None,
        **payload,
    ) -> JournalRecord:
        if kind not in JOURNAL_KINDS:
            raise ValueError(f"unknown journal kind {kind!r}")
        rec = JournalRecord(self._seq, time_us, kind, task_id, payload)
        self._seq += 1
        self.records.append(rec)
        return rec

    def __len__(self) -> int:
        return len(self.records)

    def __iter__(self) -> Iterator[JournalRecord]:
        return iter(self.records)

    def unreleased(self) -> List[JournalRecord]:
        """Every ``hold``/``strand``/``requeue`` record whose item never got
        a matching ``release`` — the parked work a journal replay must
        reconstruct (FIFO matching per ``(kind, task_id)``)."""
        open_holds: Dict[tuple, List[JournalRecord]] = {}
        for r in self.records:
            if r.kind in ("hold", "strand", "requeue"):
                open_holds.setdefault((r.kind, r.task_id), []).append(r)
            elif r.kind == "release":
                lst = open_holds.get((r.payload.get("of"), r.task_id))
                if lst:
                    lst.pop(0)
        out = [r for lst in open_holds.values() for r in lst]
        out.sort(key=lambda r: r.seq)
        return out

    def to_json(self) -> List[Dict[str, object]]:
        """Primitive-only export (the ``msctl`` dump format): reference
        payload fields are dropped, everything else round-trips."""
        return [
            {
                "seq": r.seq,
                "time_us": r.time_us,
                "kind": r.kind,
                "task_id": r.task_id,
                **r.primitives(),
            }
            for r in self.records
        ]
