"""Crash-safe control plane over ``simulate_cluster``: task lifecycle
state machine, write-ahead decision journal with idempotent replay, and
SLO deadline enforcement. See ``docs/architecture.md`` ("Control plane:
journal, replay, and deadline enforcement")."""
from repro.control.deadline import DeadlineMonitor, DeadlineSpec, slo_class_of
from repro.control.journal import JOURNAL_KINDS, DecisionJournal, JournalRecord
from repro.control.lifecycle import (
    ADMITTED,
    CANCELLED,
    CHECKPOINTED,
    FAILED,
    FINISHED,
    LEGAL_EDGES,
    MIGRATING,
    RUNNING,
    SHED,
    SUBMITTED,
    TASK_STATES,
    TERMINAL_STATES,
    LifecycleError,
    TaskLifecycle,
    apply_event,
)
from repro.control.plane import ControlPlane

__all__ = [
    "ADMITTED",
    "CANCELLED",
    "CHECKPOINTED",
    "ControlPlane",
    "DeadlineMonitor",
    "DeadlineSpec",
    "DecisionJournal",
    "FAILED",
    "FINISHED",
    "JOURNAL_KINDS",
    "JournalRecord",
    "LEGAL_EDGES",
    "LifecycleError",
    "MIGRATING",
    "RUNNING",
    "SHED",
    "SUBMITTED",
    "TASK_STATES",
    "TERMINAL_STATES",
    "TaskLifecycle",
    "apply_event",
    "slo_class_of",
]
