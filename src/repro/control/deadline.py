"""SLO deadline policy: which RT tasks are at risk, and which BE task pays.

``DeadlineSpec`` gives real-time ("rt" SLO class) requests a TTFT deadline
and a completion deadline; the :class:`DeadlineMonitor` projects both at
every control tick:

  * an RT request with no first iteration past ``ttft_grace`` of its TTFT
    budget is at risk (queued or starved behind best-effort work);
  * a started RT request whose rate-extrapolated completion lands past its
    completion deadline is at risk.

Risk does not miss the deadline by itself — the control plane preempts a
best-effort task on the same GPU (eject + delayed re-injection through the
existing migration machinery), escalating through capped-exponential
backoff per victim until ``max_preemptions``, after which the victim is
shed. The per-victim counters are coordinator-volatile (wiped by
``coordinator_crash``): a restarted coordinator restarts the escalation
ladder, which only delays — never skips — the shed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class DeadlineSpec:
    """Per-class deadlines. ``rt_ttft_us`` bounds arrival → first iteration
    for "rt" requests; ``rt_latency_us`` bounds arrival → completion.
    ``ttft_grace`` is the fraction of the TTFT budget an un-started RT
    request may burn before enforcement kicks in (enforcing at 1.0 would
    always be too late to matter)."""

    rt_ttft_us: float = 200_000.0
    rt_latency_us: float = 5_000_000.0
    ttft_grace: float = 0.5

    def __post_init__(self):
        if self.rt_ttft_us <= 0 or self.rt_latency_us <= 0:
            raise ValueError("deadlines must be positive")
        if not 0.0 < self.ttft_grace <= 1.0:
            raise ValueError("ttft_grace must be in (0, 1]")


def slo_class_of(meta: Optional[dict], prog) -> str:
    """A request's SLO class, the way the fault runtime and admission
    already read it: the arrival's meta wins, then the program attribute
    (continuations carry it — see ``ResumedTask``), default best-effort."""
    k = (meta or {}).get("slo_class") or getattr(prog, "slo_class", None)
    return k or "be"


class DeadlineMonitor:
    """Risk projection + victim selection. Enforcement (journal, eject,
    re-inject, telemetry) lives on the control plane; the monitor only
    answers "who is at risk on this core" and "which BE task pays"."""

    def __init__(
        self,
        spec: DeadlineSpec,
        backoff_us: float = 50_000.0,
        backoff_cap_us: float = 400_000.0,
        max_preemptions: int = 3,
    ):
        if backoff_us <= 0 or backoff_cap_us < backoff_us:
            raise ValueError("need 0 < backoff_us <= backoff_cap_us")
        if max_preemptions < 1:
            raise ValueError("max_preemptions must be >= 1")
        self.spec = spec
        self.backoff_us = backoff_us
        self.backoff_cap_us = backoff_cap_us
        self.max_preemptions = max_preemptions
        # coordinator-volatile escalation state
        self._preempts: Dict[int, int] = {}

    def reset(self) -> None:
        """Coordinator crash: escalation counters are coordinator memory."""
        self._preempts.clear()

    def preempt_count(self, task_id: int) -> int:
        return self._preempts.get(task_id, 0)

    def backoff_for(self, task_id: int) -> float:
        """Capped-exponential re-injection delay for the *next* preemption
        of this victim, and bump its counter."""
        n = self._preempts.get(task_id, 0)
        self._preempts[task_id] = n + 1
        return min(self.backoff_us * (2.0 ** n), self.backoff_cap_us)

    # -- risk projection -----------------------------------------------------
    def _rt_record_at_risk(self, rec, completions: int, now: float) -> bool:
        ttft_cut = rec.arrival_us + self.spec.ttft_grace * self.spec.rt_ttft_us
        if rec.first_iter_us is None:
            return now > ttft_cut
        total = rec.total_iterations
        if not total or completions <= 0:
            return False
        elapsed = now - rec.first_iter_us
        if elapsed <= 0.0:
            return False
        eta = now + (elapsed / completions) * max(0, total - completions)
        return eta > rec.arrival_us + self.spec.rt_latency_us

    def at_risk(self, core, now: float) -> List[int]:
        """RT task ids on ``core`` (running or queued) projected to miss a
        deadline at ``now``."""
        risky: List[int] = []
        for tid in sorted(core.tasks):
            rt = core.tasks[tid]
            rec = core.rec_by_tid.get(tid)
            if rec is None:
                continue
            if slo_class_of(rec.meta, rt.prog) != "rt":
                continue
            if self._rt_record_at_risk(rec, rt.stats.completions, now):
                risky.append(tid)
        for ev, rec, _pages in core.waiting:
            if slo_class_of(ev.meta, ev.program) != "rt":
                continue
            if self._rt_record_at_risk(rec, 0, now):
                risky.append(ev.program.task_id)
        return risky

    def pick_victim(self, core, now: float) -> Optional[int]:
        """The BE running task that pays: most recently admitted (least
        sunk prefix — the rebalancer's work-stealing heuristic),
        deterministic tie-break on task id."""
        best = None
        for tid, rt in core.tasks.items():
            rec = core.rec_by_tid.get(tid)
            if slo_class_of(rec.meta if rec else None, rt.prog) == "rt":
                continue
            admitted = rec.admitted_us if rec is not None else None
            key = (admitted if admitted is not None else 0.0, tid)
            if best is None or key > best[0]:
                best = (key, tid)
        return None if best is None else best[1]
