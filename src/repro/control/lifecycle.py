"""Task lifecycle state machine: the control plane's authoritative view of
every task the cluster has ever seen.

States follow the submit→finish graph from the ROADMAP's online-control-plane
item::

    SUBMITTED ──► ADMITTED ──► RUNNING ──► FINISHED
        │            │  ▲        │  ▲
        │            │  │        ├──┼──► MIGRATING ──► (RUNNING | SHED)
        │            │  │        ├──┼──► CHECKPOINTED ─► RUNNING
        │            ▼  │        ▼  │
        └─────────► SHED └── FAILED ┴──► ADMITTED   (re-placement)

plus CANCELLED, reachable from every non-terminal state (operator cancel).
``FINISHED``/``CANCELLED``/``SHED`` are terminal. Transitions are validated:
an illegal edge raises :class:`LifecycleError`, which subclasses
:class:`~repro.core.invariants.InvariantViolation` so auditing test
harnesses catch control-plane bugs with the same ``pytest.raises`` they use
for memory-accounting bugs.

The map itself is *coordinator-volatile*: a ``coordinator_crash`` wipes it,
and recovery rebuilds it — from the decision journal (journal mode) or by
scanning the surviving cores (cold mode, via :meth:`TaskLifecycle.assume`).
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.core.invariants import InvariantViolation

SUBMITTED = "SUBMITTED"
ADMITTED = "ADMITTED"
RUNNING = "RUNNING"
MIGRATING = "MIGRATING"
CHECKPOINTED = "CHECKPOINTED"
FAILED = "FAILED"
FINISHED = "FINISHED"
CANCELLED = "CANCELLED"
SHED = "SHED"

TASK_STATES = frozenset(
    {
        SUBMITTED,
        ADMITTED,
        RUNNING,
        MIGRATING,
        CHECKPOINTED,
        FAILED,
        FINISHED,
        CANCELLED,
        SHED,
    }
)
TERMINAL_STATES = frozenset({FINISHED, CANCELLED, SHED})

# every legal edge; anything else raises LifecycleError
LEGAL_EDGES: Dict[str, frozenset] = {
    SUBMITTED: frozenset({ADMITTED, CANCELLED, SHED}),
    ADMITTED: frozenset({RUNNING, FAILED, SHED, CANCELLED}),
    RUNNING: frozenset(
        {MIGRATING, CHECKPOINTED, FAILED, FINISHED, CANCELLED}
    ),
    MIGRATING: frozenset({RUNNING, ADMITTED, FAILED, SHED, CANCELLED}),
    CHECKPOINTED: frozenset({RUNNING, FAILED, FINISHED, CANCELLED}),
    FAILED: frozenset({ADMITTED, SHED, CANCELLED}),
    FINISHED: frozenset(),
    CANCELLED: frozenset(),
    SHED: frozenset(),
}


class LifecycleError(InvariantViolation):
    """An illegal lifecycle transition (or an event for a task the control
    plane never saw) — a control-plane wiring bug, never a recoverable
    runtime condition."""


class TaskLifecycle:
    """The per-task state map with validated transitions.

    ``submit`` registers a new task; ``transition`` moves it along a legal
    edge; ``assume`` registers a state *without* edge validation — only the
    cold-restart scan uses it (an amnesiac coordinator rediscovering the
    fleet has no history to validate against)."""

    def __init__(self):
        self._state: Dict[int, str] = {}
        self._since: Dict[int, float] = {}

    def __len__(self) -> int:
        return len(self._state)

    def submit(self, task_id: int, now: float) -> None:
        if task_id in self._state:
            raise LifecycleError(
                f"task {task_id} submitted twice (currently "
                f"{self._state[task_id]})"
            )
        self._state[task_id] = SUBMITTED
        self._since[task_id] = now

    def transition(self, task_id: int, new_state: str, now: float) -> None:
        if new_state not in TASK_STATES:
            raise LifecycleError(f"unknown lifecycle state {new_state!r}")
        cur = self._state.get(task_id)
        if cur is None:
            raise LifecycleError(
                f"transition to {new_state} for unknown task {task_id}"
            )
        if new_state not in LEGAL_EDGES[cur]:
            raise LifecycleError(
                f"illegal lifecycle edge {cur} -> {new_state} "
                f"for task {task_id}"
            )
        self._state[task_id] = new_state
        self._since[task_id] = now

    def assume(self, task_id: int, state: str, now: float) -> None:
        """Register ``state`` without edge validation (cold-restart
        rediscovery only)."""
        if state not in TASK_STATES:
            raise LifecycleError(f"unknown lifecycle state {state!r}")
        self._state[task_id] = state
        self._since[task_id] = now

    def state(self, task_id: int) -> Optional[str]:
        return self._state.get(task_id)

    def since(self, task_id: int) -> Optional[float]:
        return self._since.get(task_id)

    def states(self) -> Dict[int, str]:
        return dict(self._state)

    def count(self, state: str) -> int:
        return sum(1 for s in self._state.values() if s == state)


def apply_event(
    lc: TaskLifecycle, kind: str, task_id: Optional[int], now: float
) -> None:
    """Apply one journal record to a lifecycle map. This is the single
    mapping from decision kinds to state-machine edges — the live control
    plane, journal replay, and ``msctl``'s offline replay all go through
    it, so they cannot disagree about what a record means.

    ``crash``/``recover`` are markers and ``hold``/``strand``/``requeue``/
    ``release`` queue bookkeeping: neither moves lifecycle state.
    ``checkpoint`` is a transient double-step (RUNNING → CHECKPOINTED →
    RUNNING: the snapshot completes within the decision); ``reroute`` is
    state-preserving but still validated — rerouting a task that is not
    in flight is a wiring bug.
    """
    if kind == "submit":
        lc.submit(task_id, now)
        return
    if kind in ("crash", "recover", "hold", "strand", "requeue", "release"):
        return
    if task_id is None:
        raise LifecycleError(f"journal record {kind!r} without a task id")
    if kind == "place":
        lc.transition(task_id, ADMITTED, now)
    elif kind == "admit":
        lc.transition(task_id, RUNNING, now)
    elif kind == "finish":
        lc.transition(task_id, FINISHED, now)
    elif kind in ("reject", "shed"):
        lc.transition(task_id, SHED, now)
    elif kind in ("migrate", "preempt"):
        lc.transition(task_id, MIGRATING, now)
    elif kind == "fail":
        lc.transition(task_id, FAILED, now)
    elif kind == "recovery":
        lc.transition(task_id, ADMITTED, now)
    elif kind == "cancel":
        lc.transition(task_id, CANCELLED, now)
    elif kind == "checkpoint":
        lc.transition(task_id, CHECKPOINTED, now)
        lc.transition(task_id, RUNNING, now)
    elif kind == "reroute":
        cur = lc.state(task_id)
        if cur not in (ADMITTED, MIGRATING):
            raise LifecycleError(
                f"reroute of task {task_id} in state {cur} (must be in "
                "flight: ADMITTED or MIGRATING)"
            )
    else:
        raise LifecycleError(f"unknown journal kind {kind!r}")
