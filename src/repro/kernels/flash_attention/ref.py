"""Pure-jnp oracle: exact attention with causal/window masks + GQA."""
import jax.numpy as jnp
import numpy as np


def attention_ref(q, k, v, causal=True, window=0, sm_scale=None):
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qf * sm_scale, kf)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = qpos >= kpos
    if window > 0:
        mask = jnp.logical_and(mask, (qpos - kpos) < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, vf)
    return o.reshape(b, sq, h, d).astype(q.dtype)
