import functools

import jax

from repro.kernels.flash_attention import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_kv")
)
def flash_attention(q, k, v, causal=True, window=0, block_q=256, block_kv=256):
    return kernel.flash_attention(
        q, k, v, causal=causal, window=window, block_q=block_q,
        block_kv=block_kv, interpret=not _on_tpu(),
    )
