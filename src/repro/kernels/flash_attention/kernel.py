"""Tiled online-softmax attention (prefill hot spot).

Grid = (batch*kv_heads, q_groups, q_blocks); the kernel loops over KV blocks
with running max/denominator so the (Sq, Skv) score matrix never leaves
VMEM-tile granularity. Supports GQA (q heads grouped per kv head), causal
masking, and a sliding window (recurrentgemma's local attention).

BlockSpecs stage q/k/v tiles HBM->VMEM; the Pallas grid pipeline overlaps the
next tile's DMA with the current tile's MXU work — same proactive-staging
principle as the MSched migration pipeline (§6.3), applied at the VMEM tier.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(
    q_ref,  # (1, bq, g, d)
    k_ref,  # (1, skv, d)
    v_ref,  # (1, skv, d)
    o_ref,  # (1, bq, g, d)
    *,
    block_kv: int,
    causal: bool,
    window: int,
    sm_scale: float,
):
    bq = q_ref.shape[1]
    g = q_ref.shape[2]
    d = q_ref.shape[3]
    skv = k_ref.shape[1]
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * sm_scale  # (bq, g, d)
    q2 = q.reshape(bq * g, d)

    m = jnp.full((bq * g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((bq * g, 1), jnp.float32)
    acc = jnp.zeros((bq * g, d), jnp.float32)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, g), 0)
    q_pos = q_pos.reshape(bq * g, 1)

    n_kv = skv // block_kv

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.ds(i * block_kv, block_kv), :].astype(jnp.float32)
        v = v_ref[0, pl.ds(i * block_kv, block_kv), :].astype(jnp.float32)
        s = q2 @ k.T  # (bq*g, block_kv)
        kv_pos = i * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_kv), 1
        )
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask = q_pos >= kv_pos
        if window > 0:
            mask = jnp.logical_and(mask, (q_pos - kv_pos) < window)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, n_kv, body, (m, l, acc))
    out = acc / jnp.maximum(l, 1e-30)
    o_ref[0] = out.reshape(bq, g, d).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,  # 0 = unlimited
    block_q: int = 256,
    block_kv: int = 256,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, sq, h, d = q.shape
    _, skv, hkv, _ = k.shape
    assert h % hkv == 0
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    assert sq % bq == 0 and skv % bkv == 0

    # layout: fold q heads into (B*Hkv) batch; group dim g stays with q
    qg = q.reshape(b, sq, hkv, g, d).transpose(0, 2, 1, 3, 4).reshape(b * hkv, sq, g, d)
    kg = k.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)
    vg = v.transpose(0, 2, 1, 3).reshape(b * hkv, skv, d)

    grid = (b * hkv, sq // bq)
    out = pl.pallas_call(
        functools.partial(
            _fa_kernel,
            block_kv=bkv,
            causal=causal,
            window=window,
            sm_scale=sm_scale,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, g, d), lambda bh, qi: (bh, qi, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (bh, 0, 0)),
            pl.BlockSpec((1, skv, d), lambda bh, qi: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, g, d), lambda bh, qi: (bh, qi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hkv, sq, g, d), q.dtype),
        interpret=interpret,
    )(qg, kg, vg)
    return (
        out.reshape(b, hkv, sq, g, d).transpose(0, 2, 1, 3, 4).reshape(b, sq, h, d)
    )
