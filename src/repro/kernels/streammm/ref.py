"""Pure-jnp oracle for the weight-streaming matmul."""
import jax.numpy as jnp


def stream_matmul_ref(x, w, out_dtype=jnp.bfloat16):
    return (
        x.astype(jnp.float32) @ w.astype(jnp.float32)
    ).astype(out_dtype)


def stream_matmul_int8_ref(x, w_q, scales, block_k, out_dtype=jnp.bfloat16):
    wf = w_q.astype(jnp.float32) * jnp.repeat(scales, block_k, axis=0)
    return (x.astype(jnp.float32) @ wf).astype(out_dtype)
