"""Jitted public entry points; interpret mode auto-selected off-TPU."""
import functools

import jax

from repro.kernels.streammm import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def stream_matmul(x, w, block_m=256, block_n=256, block_k=512):
    return kernel.stream_matmul(
        x, w, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=not _on_tpu(),
    )


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def stream_matmul_int8(x, w_q, scales, block_m=256, block_n=256, block_k=512):
    return kernel.stream_matmul_int8(
        x, w_q, scales, block_m=block_m, block_n=block_n, block_k=block_k,
        interpret=not _on_tpu(),
    )
