"""Weight-streaming matmul kernel — the TPU-native embodiment of MSched's
pipelined migration + early execution (§6.3), one level down the memory
hierarchy.

On the GPU, MSched overlaps D2H eviction with H2D population on dual copy
engines and starts compute as soon as the first pages land. On TPU the same
insight maps to HBM->VMEM: weights live in the "slow" tier (HBM — or host
DRAM via the runtime's proactive scheduler) and are streamed tile-by-tile
into VMEM while the MXU consumes the previous tile. ``pl.pallas_call``'s
grid pipeline performs exactly this double buffering: BlockSpecs declare the
per-step working set (the "predicted pages" of the tile), and the compiler
overlaps the DMA for step i+1 with compute for step i — proactive, not
fault-driven.

Variants:
  * bf16 x bf16 -> f32 accumulate
  * int8 weights x bf16 activations with fused per-tile dequant (the paper's
    llama.cpp int8 workload): streaming quantized weights halves the
    slow-tier bandwidth demand, the §6.3 bottleneck.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """Grid = (M/bm, N/bn, K/bk); K is the innermost (fastest) dimension so
    the weight tile stream is sequential in K — the first-access order the
    migration pipeline wants."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stream_matmul(
    x: jax.Array,  # (M, K)
    w: jax.Array,  # (K, N)
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)


def _mm_int8_kernel(x_ref, w_ref, scale_ref, o_ref, acc_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # fused dequant: int8 tile -> f32 with per-(k-block, out-column) scale
    w_tile = w_ref[...].astype(jnp.float32) * scale_ref[...]
    acc_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.float32),
        w_tile,
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def stream_matmul_int8(
    x: jax.Array,  # (M, K) bf16/f32
    w_q: jax.Array,  # (K, N) int8
    scales: jax.Array,  # (K // block_k, N) f32 — per k-block column scales
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 512,
    out_dtype=jnp.bfloat16,
    interpret: bool = False,
) -> jax.Array:
    m, k = x.shape
    k2, n = w_q.shape
    assert k == k2
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0
    assert scales.shape == (k // bk, n), (scales.shape, (k // bk, n))
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_mm_int8_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_q, scales)
