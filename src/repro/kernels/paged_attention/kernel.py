"""Paged-KV decode attention: MSched page-granular memory applied to the KV
cache.

The KV cache lives in a page pool ``(n_pages, page_tokens, Hkv, D)``; each
sequence owns a page table ``(B, max_pages)`` of pool indices — exactly the
page abstraction MSched schedules between HBM and host DRAM, so a sequence's
resident working set is its page list and the runtime can predict it (T2:
linear in the current sequence length, §5.1's KV-cache example).

Grid = (B, Hkv). The page loop walks only the pages < current length,
accumulating online softmax. Pages are gathered from the pool via dynamic
indices (PrefetchScalarGridSpec-style scalar prefetch of the page table).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pa_kernel(
    ptab_ref,  # scalar-prefetch: (B, max_pages) int32
    lens_ref,  # scalar-prefetch: (B,) int32
    q_ref,  # (1, 1, g, d)
    pool_k_ref,  # (n_pages, pt, d)   [whole pool, ANY memory]
    pool_v_ref,
    o_ref,  # (1, 1, g, d)
    *,
    page_tokens: int,
    max_pages: int,
    sm_scale: float,
):
    b = pl.program_id(0)
    g, d = q_ref.shape[2], q_ref.shape[3]
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (g, d)
    seq_len = lens_ref[b]
    n_pages = (seq_len + page_tokens - 1) // page_tokens

    def body(p, carry):
        m, l, acc = carry
        page_id = ptab_ref[b, p]
        k = pool_k_ref[page_id].astype(jnp.float32)  # (pt, d)
        v = pool_v_ref[page_id].astype(jnp.float32)
        s = q @ k.T  # (g, pt)
        pos = p * page_tokens + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_tokens), 1
        )
        s = jnp.where(pos < seq_len, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        pr = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(pr, axis=-1, keepdims=True)
        acc = acc * alpha + pr @ v
        return m_new, l, acc

    m = jnp.full((g, 1), NEG_INF, jnp.float32)
    l = jnp.zeros((g, 1), jnp.float32)
    acc = jnp.zeros((g, d), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_pages, body, (m, l, acc))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def paged_attention(
    q: jax.Array,  # (B, H, D) — one decode token per sequence
    pool_k: jax.Array,  # (n_pages, page_tokens, Hkv, D)
    pool_v: jax.Array,
    page_table: jax.Array,  # (B, max_pages) int32
    lengths: jax.Array,  # (B,) int32 current sequence lengths
    *,
    sm_scale: float | None = None,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    n_pages_pool, pt, hkv, _ = pool_k.shape
    assert h % hkv == 0
    g = h // hkv
    max_pages = page_table.shape[1]
    sm_scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)

    qg = q.reshape(b, 1, hkv, g, d).transpose(0, 2, 1, 3, 4).reshape(b, hkv, 1, g, d)

    outs = []
    # one pallas call per kv head keeps the pool BlockSpec simple; heads are
    # data-parallel (the launcher vmaps/shards them in production)
    for kvh in range(hkv):
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b,),
            in_specs=[
                pl.BlockSpec((1, 1, g, d), lambda i, *_: (i, 0, 0, 0)),
                pl.BlockSpec(memory_space=pl.ANY),
                pl.BlockSpec(memory_space=pl.ANY),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d), lambda i, *_: (i, 0, 0, 0)),
        )
        out = pl.pallas_call(
            functools.partial(
                _pa_kernel,
                page_tokens=pt,
                max_pages=max_pages,
                sm_scale=sm_scale,
            ),
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((b, 1, g, d), q.dtype),
            interpret=interpret,
        )(page_table, lengths, qg[:, kvh], pool_k[:, :, kvh], pool_v[:, :, kvh])
        outs.append(out)
    out = jnp.stack(outs, axis=1)  # (b, hkv, 1, g, d)
    return out.reshape(b, hkv * g, d)
