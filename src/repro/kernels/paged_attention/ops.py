import functools

import jax

from repro.kernels.paged_attention import kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@jax.jit
def paged_attention(q, pool_k, pool_v, page_table, lengths):
    return kernel.paged_attention(
        q, pool_k, pool_v, page_table, lengths, interpret=not _on_tpu()
    )
