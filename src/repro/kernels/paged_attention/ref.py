"""Pure-jnp oracle: gather pages, run exact masked attention."""
import jax.numpy as jnp
import numpy as np


def paged_attention_ref(q, pool_k, pool_v, page_table, lengths, sm_scale=None):
    b, h, d = q.shape
    n_pages, pt, hkv, _ = pool_k.shape
    g = h // hkv
    sm_scale = sm_scale if sm_scale is not None else 1.0 / np.sqrt(d)
    max_pages = page_table.shape[1]
    k = pool_k[page_table]  # (b, max_pages, pt, hkv, d)
    v = pool_v[page_table]
    k = k.reshape(b, max_pages * pt, hkv, d).astype(jnp.float32)
    v = v.reshape(b, max_pages * pt, hkv, d).astype(jnp.float32)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    s = jnp.einsum("bkgd,btkd->bkgt", qf * sm_scale, k)
    pos = jnp.arange(max_pages * pt)[None, None, None, :]
    s = jnp.where(pos < lengths[:, None, None, None], s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgt,btkd->bkgd", p, v)
    return o.reshape(b, h, d).astype(q.dtype)
