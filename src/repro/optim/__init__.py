from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adafactor,
    adamw,
    sgd,
    get_optimizer,
)
from repro.optim.schedules import cosine_schedule, get_schedule, wsd_schedule  # noqa: F401
