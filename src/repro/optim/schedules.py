"""LR schedules: cosine and WSD (Warmup-Stable-Decay, MiniCPM arXiv:2404.06395)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(peak_lr: float, warmup: int, total: int, min_ratio: float = 0.1):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)

    return lr


def wsd_schedule(
    peak_lr: float,
    warmup: int,
    total: int,
    decay_frac: float = 0.1,
    min_ratio: float = 0.01,
):
    """Warmup → Stable (constant) → Decay (last ``decay_frac`` of steps).

    MiniCPM's schedule: the stable phase keeps peak LR; the decay phase drops
    exponentially/linearly to ``min_ratio * peak``. We use linear decay.
    """
    decay_steps = max(int(total * decay_frac), 1)
    decay_start = total - decay_steps

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup, 1)
        frac = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        decay = peak_lr * (1.0 - (1.0 - min_ratio) * frac)
        stable = jnp.full_like(step, peak_lr)
        out = jnp.where(step < warmup, warm, jnp.where(step < decay_start, stable, decay))
        return out

    return lr


def get_schedule(name: str, peak_lr: float, warmup: int, total: int):
    if name == "cosine":
        return cosine_schedule(peak_lr, warmup, total)
    if name == "wsd":
        return wsd_schedule(peak_lr, warmup, total)
    raise KeyError(name)
