"""Optimizers as pure (init, update) pairs over pytrees.

AdamW for most archs; Adafactor (factored second moment) for the ≥300 B MoEs
where AdamW state (12 B/param) would exceed 16 GB/chip on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    name: str
    init: Callable  # params -> opt_state
    update: Callable  # (grads, opt_state, params, lr) -> (new_params, new_state)


def _tree_zeros_like(tree, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), tree
    )


def sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum:
            return {"mu": _tree_zeros_like(params, jnp.float32)}
        return {}

    def update(grads, state, params, lr):
        if momentum:
            mu = jax.tree.map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
            )
            new = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
            return new, {"mu": mu}
        new = jax.tree.map(
            lambda p, g: (p - lr * g.astype(jnp.float32)).astype(p.dtype),
            params,
            grads,
        )
        return new, state

    return Optimizer("sgd", init, update)


def adamw(
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Optimizer:
    def init(params):
        return {
            "m": _tree_zeros_like(params, jnp.float32),
            "v": _tree_zeros_like(params, jnp.float32),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        bc1 = 1 - b1**c
        bc2 = 1 - b2**c

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / bc1
            vh = v / bc2
            step = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "count": count}

    return Optimizer("adamw", init, update)


def adafactor(
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
) -> Optimizer:
    """Factored second-moment estimator (Shazeer & Stern, 2018), no momentum."""

    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"vr": row, "vc": col}
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "state": jax.tree.map(per_leaf, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        count = state["count"] + 1
        c = count.astype(jnp.float32)
        beta = 1.0 - c ** (-decay)

        def per_leaf(p, g, s):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if _factored(p.shape):
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True) + eps
                )
                cfac = jax.lax.rsqrt(vc + eps)
                u = g * rfac[..., None] * cfac[..., None, :]
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps)
                new_s = {"v": v}
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p.astype(jnp.float32) - lr * u
            if weight_decay:
                newp = newp - lr * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state["state"])
        out = [per_leaf(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_s = treedef.unflatten([o[1] for o in out])
        return new_p, {"state": new_s, "count": count}

    return Optimizer("adafactor", init, update)


def get_optimizer(name: str) -> Optimizer:
    if name == "adamw":
        return adamw()
    if name == "adafactor":
        return adafactor()
    if name == "sgd":
        return sgd()
    raise KeyError(name)
