"""Sharded checkpointing: per-leaf .npy files + msgpack manifest.

Mesh-shape-agnostic: leaves are saved as full (addressable-assembled) arrays
and restored with ``jax.device_put`` against the *target* sharding, so a
checkpoint written on one mesh restores onto any other (elastic re-mesh).
Async save runs serialization on a background thread (compute/IO overlap);
``save`` is atomic via tmp-dir rename. Retention keeps the newest K steps.
"""
from __future__ import annotations

import os
import shutil
import threading
from typing import Any, Optional

import jax
import msgpack
import numpy as np

MANIFEST = "manifest.msgpack"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    paths = [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]
    return leaves, paths, treedef


def save(ckpt_dir: str, step: int, tree: Any, keep: int = 3) -> str:
    """Synchronous atomic save; returns the final directory."""
    leaves, paths, _ = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    meta = {"step": step, "leaves": []}
    for i, (leaf, path) in enumerate(zip(leaves, paths)):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        dtype_name = str(arr.dtype)
        # np.save round-trips ml_dtypes (bfloat16, float8…) as raw void —
        # store a uint view and reconstruct from the manifest dtype
        if arr.dtype.kind == "V" or dtype_name not in np.sctypeDict:
            view = {1: np.uint8, 2: np.uint16, 4: np.uint32}[arr.dtype.itemsize]
            np.save(os.path.join(tmp, fname), arr.view(view))
        else:
            np.save(os.path.join(tmp, fname), arr)
        meta["leaves"].append(
            {"path": path, "file": fname, "dtype": dtype_name, "shape": list(arr.shape)}
        )
    with open(os.path.join(tmp, MANIFEST), "wb") as f:
        f.write(msgpack.packb(meta))
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _retain(ckpt_dir, keep)
    return final


def _retain(ckpt_dir: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target: Any) -> Any:
    """Restore into the structure/shardings of ``target`` (abstract or
    concrete pytree with .sharding on leaves)."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, MANIFEST), "rb") as f:
        meta = msgpack.unpackb(f.read())
    leaves, paths, treedef = _flatten(target)
    by_path = {e["path"]: e for e in meta["leaves"]}
    out = []
    for leaf, path in zip(leaves, paths):
        entry = by_path[path]
        arr = np.load(os.path.join(d, entry["file"]))
        want = jax.numpy.dtype(entry["dtype"])
        if arr.dtype != want:
            arr = arr.view(want) if arr.dtype.itemsize == want.itemsize else arr.astype(want)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and not isinstance(
            sharding, jax.sharding.SingleDeviceSharding
        ):
            out.append(jax.device_put(arr, sharding))
        elif isinstance(leaf, (np.ndarray, np.generic)):
            # host target: stay on host — device_put would silently downcast
            # 64-bit leaves while jax_enable_x64 is off
            out.append(arr)
        else:
            # np.load preserves ml_dtypes (bfloat16 etc.); no cast needed
            out.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


class AsyncCheckpointer:
    """Overlaps checkpoint serialization with training compute."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        # device_get on the main thread (orderly with respect to donation),
        # file IO on the worker
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err
