"""Trace exporters and the trace validator.

Two formats:

  * **Chrome trace_event JSON** (:func:`chrome_trace` / :func:`write_chrome`)
    — the object form (``{"traceEvents": [...]}``), loadable in Perfetto and
    ``chrome://tracing``. Each simulation track (GPU, ``cluster``, ``host``,
    ``link:a<->b``) becomes one process (``pid``) named by a ``process_name``
    metadata event, so the viewer shows one swimlane per GPU plus counter
    tracks per link. Durations use ``B``/``E`` pairs (context switches) and
    ``X`` complete events; probes become ``C`` counter events. Extra
    top-level keys (``stallLedger``, ``probes``, ``summary``) ride along —
    trace viewers ignore unknown keys, and ``scripts/trace_report.py`` reads
    them back.
  * **JSONL** (:func:`write_jsonl`) — one self-describing JSON object per
    line (``{"type": "event" | "counter" | "ledger" | "meta", ...}``), for
    streaming consumers that don't want the whole document in memory.

:func:`validate_trace` is the shared checker behind
``scripts/trace_report.py --validate``: schema validity, globally monotone
timestamps, balanced begin/end pairs per track, and exact stall-ledger
conservation.
"""
from __future__ import annotations

import json
from typing import Dict, List

SCHEMA = "msched-trace-v1"

_CHROME_PHASES = frozenset({"M", "B", "E", "X", "i", "C"})

# conservation check tolerance when re-validating an exported ledger (µs)
_LEDGER_ATOL_US = 1e-3


def _track_order(tracks) -> List[str]:
    """GPU tracks first (trace viewers show pids in order), then cluster/
    host scope, then per-link counter tracks."""

    def key(tr: str):
        if tr.startswith("link:"):
            group = 2
        elif tr in ("cluster", "host"):
            group = 1
        else:
            group = 0
        return (group, tr)

    return sorted(tracks, key=key)


def chrome_trace(tel) -> dict:
    """Render a :class:`~repro.telemetry.hub.Telemetry` hub as a Chrome
    trace_event document (plain dict, ready for ``json.dump``)."""
    tracks = {ev.track for ev in tel.events}
    tracks.update(tr for tr, _name in tel.series)
    ordered = _track_order(tracks)
    pid_of = {tr: i + 1 for i, tr in enumerate(ordered)}

    trace_events: List[dict] = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid_of[tr],
            "tid": 0,
            "ts": 0.0,
            "args": {"name": tr},
        }
        for tr in ordered
    ]

    body: List[dict] = []
    for ev in tel.events:
        rec = {
            "name": ev.name,
            "ph": ev.ph,
            "pid": pid_of[ev.track],
            "tid": 0,
            "ts": ev.ts_us,
            "args": dict(ev.args),
        }
        if ev.task_id is not None:
            rec["args"]["task"] = ev.task_id
        if ev.ph == "X":
            rec["dur"] = ev.dur_us
        if ev.ph == "i":
            rec["s"] = "t"  # thread-scoped instant
        body.append(rec)
    for (tr, name), points in tel.series.items():
        pid = pid_of[tr]
        for t, v in points:
            body.append(
                {
                    "name": name,
                    "ph": "C",
                    "pid": pid,
                    "tid": 0,
                    "ts": t,
                    "args": {name: v},
                }
            )
    # cluster cores run sequentially per DES window, so raw emission order
    # interleaves clocks; a stable sort by timestamp restores the global
    # monotone order the validator (and any streaming viewer) expects,
    # while preserving same-instant emission order per track — which is
    # exactly what keeps B/E pairs properly nested.
    body.sort(key=lambda r: r["ts"])
    trace_events.extend(body)

    ledger = {}
    if tel._breakdown is not None:
        ledger = {str(tid): row for tid, row in tel._breakdown.items()}
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": {"schema": SCHEMA},
        "stallLedger": ledger,
        "probes": {
            f"{tr}/{name}": [[t, v] for t, v in points]
            for (tr, name), points in tel.series.items()
        },
        "summary": dict(tel.summary),
        "dropped_events": tel.dropped_events,
    }


def write_chrome(tel, path) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(tel), f)
        f.write("\n")


def write_jsonl(tel, path) -> None:
    with open(path, "w") as f:
        f.write(
            json.dumps(
                {
                    "type": "meta",
                    "schema": SCHEMA,
                    "summary": dict(tel.summary),
                    "dropped_events": tel.dropped_events,
                }
            )
            + "\n"
        )
        for ev in sorted(tel.events, key=lambda e: e.ts_us):
            rec = {
                "type": "event",
                "ts": ev.ts_us,
                "name": ev.name,
                "ph": ev.ph,
                "track": ev.track,
            }
            if ev.ph == "X":
                rec["dur"] = ev.dur_us
            if ev.task_id is not None:
                rec["task"] = ev.task_id
            if ev.args:
                rec["args"] = dict(ev.args)
            f.write(json.dumps(rec) + "\n")
        for (tr, name), points in tel.series.items():
            for t, v in points:
                f.write(
                    json.dumps(
                        {
                            "type": "counter",
                            "ts": t,
                            "track": tr,
                            "name": name,
                            "value": v,
                        }
                    )
                    + "\n"
                )
        if tel._breakdown is not None:
            for tid, row in tel._breakdown.items():
                f.write(
                    json.dumps({"type": "ledger", "task": tid, **row}) + "\n"
                )


# --------------------------------------------------------------------------
# Validation
# --------------------------------------------------------------------------


def validate_trace(doc) -> List[str]:
    """Check an exported Chrome-trace document. Returns a list of error
    strings (empty = valid). Checks:

      * schema: required keys and types on every event; known phases;
        ``X`` events carry a non-negative ``dur``;
      * monotone timestamps over the event stream (metadata excluded);
      * balanced ``B``/``E`` pairs per ``(pid, tid)`` with matching names;
      * stall-ledger conservation: per task, the six categories sum to the
        non-compute wall gap (to ``1e-3`` µs) and the queue-wait residual
        is not materially negative.
    """
    errors: List[str] = []
    if not isinstance(doc, dict):
        return ["document is not a JSON object"]
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["missing or non-list traceEvents"]

    prev_ts = None
    stacks: Dict[tuple, List[str]] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        name = ev.get("name")
        ph = ev.get("ph")
        ts = ev.get("ts")
        if not isinstance(name, str) or not name:
            errors.append(f"event {i}: missing name")
        if ph not in _CHROME_PHASES:
            errors.append(f"event {i} ({name}): unknown phase {ph!r}")
            continue
        if not isinstance(ev.get("pid"), int) or not isinstance(
            ev.get("tid"), int
        ):
            errors.append(f"event {i} ({name}): missing pid/tid")
            continue
        if ph == "M":
            # metadata events legally carry no timestamp (Chrome format);
            # checking ts first used to flag them as "bad ts None"
            continue
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"event {i} ({name}): bad ts {ts!r}")
            continue
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"event {i} ({name}): X without valid dur")
        if prev_ts is not None and ts < prev_ts:
            errors.append(
                f"event {i} ({name}): timestamp {ts} < previous {prev_ts} "
                "(not monotone)"
            )
        prev_ts = ts
        key = (ev["pid"], ev["tid"])
        if ph == "B":
            stacks.setdefault(key, []).append(name)
        elif ph == "E":
            stack = stacks.get(key)
            if not stack:
                errors.append(f"event {i} ({name}): E without matching B")
            elif stack[-1] != name:
                errors.append(
                    f"event {i}: E({name}) closes B({stack[-1]}) "
                    f"on track pid={key[0]}"
                )
                stack.pop()
            else:
                stack.pop()
    for key, stack in stacks.items():
        if stack:
            errors.append(
                f"track pid={key[0]}: {len(stack)} unclosed B event(s): "
                f"{stack[-3:]}"
            )

    ledger = doc.get("stallLedger", {})
    if not isinstance(ledger, dict):
        errors.append("stallLedger is not an object")
        ledger = {}
    for tid, row in ledger.items():
        if not isinstance(row, dict):
            errors.append(f"ledger task {tid}: not an object")
            continue
        try:
            cats = [
                row["fault-service"],
                row["migration-wait"],
                row["queue-wait"],
                row["link-contention"],
                row["recovery"],
                row["scheduler-control"],
            ]
            non_compute = row["non_compute_us"]
        except KeyError as missing:
            errors.append(f"ledger task {tid}: missing {missing}")
            continue
        tol = _LEDGER_ATOL_US + 1e-9 * max(abs(non_compute), 1.0)
        if abs(sum(cats) - non_compute) > tol:
            errors.append(
                f"ledger task {tid}: categories sum to {sum(cats):.4f}us, "
                f"non-compute wall is {non_compute:.4f}us"
            )
        if row["queue-wait"] < -tol:
            errors.append(
                f"ledger task {tid}: negative queue-wait residual "
                f"{row['queue-wait']:.4f}us (double-counted attribution)"
            )
    return errors
