"""Online prediction audit: score the paper's accuracy claim live.

MSched's thesis rests on template-based working-set prediction being
near-perfect (paper Table 1: F− ≤ 0.92%, F+ = 0.00%), but Table 1 is an
*offline* score over canned command windows
(``benchmarks/table1_prediction_accuracy.py``). The auditor turns that
headline into a continuously-measured invariant: hooked at every extended
context switch and fault-service boundary, it compares what the predictor
promised against what the task actually touched, at two granularities:

  * **per-command** (the Table 1 methodology, exactly): for every executed
    kernel command carrying an annotate-time prediction, compare
    ``cmd.predicted_page_runs`` against ``cmd.true_page_runs`` at page
    granularity. The fleet F−/F+ rates this produces reconcile with
    :func:`repro.core.predictor.evaluate_accuracy` on the same commands to
    float precision (pinned within 0.1 pp in the tests);
  * **per-quantum** (the populate plan): at each extended context switch the
    coordinator's predicted cut (``SwitchReport.predicted_runs``) and what
    it actually populated (``migration.populated_runs``) are held against
    the union of pages touched during the quantum. Populated-but-untouched
    pages are **over-fetch** (wasted link bytes); demand-paging stalls
    inside the quantum are **under-fetch** residue, cross-checked against
    the stall ledger's ``fault-service`` bucket via :meth:`reconcile_ledger`.

Per-template (kernel name) accumulators drive a drift gauge: the F− rate
over a recent window minus the lifetime rate, in percentage points — a
drifting template shows up here before it degrades placement or admission.

The auditor is an observer under the same contract as the hub: it only runs
when a :class:`~repro.telemetry.hub.Telemetry` hub with ``audit=True`` is
attached, reads simulation state without mutating it, and leaves traced
results bit-for-bit identical to untraced ones. Backends without
predictions (um/suv) produce no audited commands — the auditor simply
reports an empty sample rather than a fake score.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List, Optional, Tuple

from repro.core.commands import KERNEL
from repro.core.pages import (
    PageRun,
    intersect_runs,
    merge_runs,
    run_page_count,
    subtract_runs,
)

# recent-window length (audited kernel commands) for the drift gauge
_DRIFT_WINDOW = 256


class _Acc:
    """Page-count accumulator in Table 1 terms (true/pred/missed/wrong)."""

    __slots__ = ("true", "pred", "missed", "wrong", "commands")

    def __init__(self) -> None:
        self.true = 0
        self.pred = 0
        self.missed = 0
        self.wrong = 0
        self.commands = 0

    def add(self, true: int, pred: int, missed: int, wrong: int) -> None:
        self.true += true
        self.pred += pred
        self.missed += missed
        self.wrong += wrong
        self.commands += 1

    def fneg_pct(self) -> float:
        return 100.0 * self.missed / self.true if self.true else 0.0

    def fpos_pct(self) -> float:
        return 100.0 * self.wrong / self.pred if self.pred else 0.0

    def to_json(self) -> dict:
        return {
            "commands": self.commands,
            "true_pages": self.true,
            "pred_pages": self.pred,
            "missed_pages": self.missed,
            "wrong_pages": self.wrong,
            "false_negative_pct": self.fneg_pct(),
            "false_positive_pct": self.fpos_pct(),
        }


class _Template(_Acc):
    """Per-template accumulator + the recent window behind the drift gauge."""

    __slots__ = ("window",)

    def __init__(self) -> None:
        super().__init__()
        self.window: Deque[Tuple[int, int, int, int]] = deque(
            maxlen=_DRIFT_WINDOW
        )

    def add(self, true: int, pred: int, missed: int, wrong: int) -> None:
        super().add(true, pred, missed, wrong)
        self.window.append((true, pred, missed, wrong))

    def drift_pp(self) -> float:
        """Recent-window F− minus lifetime F−, in percentage points. ~0 for
        a stable template; grows when recent predictions degrade."""
        wt = sum(w[0] for w in self.window)
        wm = sum(w[2] for w in self.window)
        recent = 100.0 * wm / wt if wt else 0.0
        return recent - self.fneg_pct()


class _Quantum:
    """Open audit window for one track's current timeslice."""

    __slots__ = ("task_id", "predicted", "populated", "touched")

    def __init__(self, task_id: int, predicted, populated) -> None:
        self.task_id = task_id
        self.predicted = predicted  # merged runs: the plan's predicted cut
        self.populated = populated  # merged runs: what the switch moved in
        self.touched: List[PageRun] = []


class PredictionAuditor:
    """Fleet-wide prediction scorer (see module docstring).

    Attach via ``Telemetry(audit=True)``; ``SimCore`` drives the four hooks
    (:meth:`begin_quantum`, :meth:`observe_command`, :meth:`observe_fault`,
    :meth:`end_quantum`) from its existing telemetry emission sites.
    """

    def __init__(self, metrics=None, page_size: int = 0) -> None:
        self.metrics = metrics  # MetricsRegistry or None
        self.page_size = int(page_size)
        self.fleet = _Acc()
        self.per_task: Dict[int, _Acc] = {}
        self.per_template: Dict[str, _Template] = {}
        # per-quantum working-set audit (plan vs touched)
        self.quanta = 0
        self.ws_true_pages = 0
        self.ws_pred_pages = 0
        self.ws_missed_pages = 0
        self.ws_wrong_pages = 0
        self.overfetch_pages = 0
        self.overfetch_bytes = 0
        # under-fetch residue (fault-service stalls inside audited quanta)
        self.underfetch_stall_us = 0.0
        self.underfetch_stall_by_task: Dict[int, float] = {}
        self.underfetch_faults = 0
        self._open: Dict[str, _Quantum] = {}  # track -> current quantum

    # -- switch / command / fault hooks (SimCore emission sites) ------------
    def begin_quantum(
        self,
        track: str,
        task_id: int,
        predicted_runs,
        populated_runs,
        page_size: int,
    ) -> None:
        """An extended context switch opened a timeslice on ``track``. The
        runs come from the coordinator's :class:`SwitchReport` — empty for
        backends that plan nothing (um) or plans without a predicted cut
        (legacy planning)."""
        if not self.page_size:
            self.page_size = int(page_size)
        self._close(track)
        self._open[track] = _Quantum(
            task_id,
            merge_runs(predicted_runs or ()),
            merge_runs(populated_runs or ()),
        )

    def observe_command(self, track: str, cmd, space) -> None:
        """One command executed inside the current quantum. Kernel commands
        with an annotate-time prediction feed the Table 1 accumulators; all
        commands feed the quantum's touched set."""
        q = self._open.get(track)
        true_runs = cmd.true_page_runs(space)
        if q is not None:
            q.touched.extend(true_runs)
        pred_runs = cmd.predicted_page_runs
        if pred_runs is None or cmd.kind != KERNEL:
            return
        true_m = merge_runs(true_runs)
        pred_m = merge_runs(pred_runs)
        nt = run_page_count(true_m)
        np_ = run_page_count(pred_m)
        ni = run_page_count(intersect_runs(true_m, pred_m))
        missed = nt - ni
        wrong = np_ - ni
        self.fleet.add(nt, np_, missed, wrong)
        tid = cmd.task_id
        acc = self.per_task.get(tid)
        if acc is None:
            acc = self.per_task[tid] = _Acc()
        acc.add(nt, np_, missed, wrong)
        tpl = self.per_template.get(cmd.name)
        if tpl is None:
            tpl = self.per_template[cmd.name] = _Template()
        tpl.add(nt, np_, missed, wrong)

    def observe_fault(self, track: str, task_id: int, stall_us: float) -> None:
        """A demand-paging stall inside the quantum: pages the plan failed
        to cover (a false negative, or pressure-evicted residency) serviced
        by the fallback pager — the under-fetch residue."""
        self.underfetch_stall_us += stall_us
        self.underfetch_stall_by_task[task_id] = (
            self.underfetch_stall_by_task.get(task_id, 0.0) + stall_us
        )
        self.underfetch_faults += 1

    def end_quantum(self, track: str) -> None:
        self._close(track)
        self._open.pop(track, None)

    def _close(self, track: str) -> None:
        q = self._open.get(track)
        if q is None:
            return
        touched = merge_runs(q.touched)
        nt = run_page_count(touched)
        npred = run_page_count(q.predicted)
        self.quanta += 1
        self.ws_true_pages += nt
        self.ws_pred_pages += npred
        if q.predicted:
            self.ws_missed_pages += run_page_count(
                subtract_runs(touched, q.predicted)
            )
            self.ws_wrong_pages += npred - run_page_count(
                intersect_runs(q.predicted, touched)
            )
        if q.populated:
            over = run_page_count(subtract_runs(q.populated, touched))
            self.overfetch_pages += over
            self.overfetch_bytes += over * self.page_size

    # -- fleet health -------------------------------------------------------
    def fleet_fneg_pct(self) -> float:
        return self.fleet.fneg_pct()

    def fleet_fpos_pct(self) -> float:
        return self.fleet.fpos_pct()

    def fleet_drift_pp(self) -> float:
        """Worst absolute per-template drift (0 with no audited templates)."""
        return max(
            (abs(t.drift_pp()) for t in self.per_template.values()),
            default=0.0,
        )

    def health(self) -> dict:
        """The gauges `msctl` surfaces next to the deadline counters."""
        return {
            "audited_commands": self.fleet.commands,
            "audited_quanta": self.quanta,
            "false_negative_pct": self.fleet_fneg_pct(),
            "false_positive_pct": self.fleet_fpos_pct(),
            "template_drift_pp": self.fleet_drift_pp(),
            "overfetch_bytes": self.overfetch_bytes,
            "underfetch_stall_us": self.underfetch_stall_us,
        }

    def export_gauges(self, metrics, track: str = "fleet") -> None:
        """Bank the audit totals into a :class:`MetricsRegistry` (called by
        the hub at every rollup). Counters are re-set via gauge-free deltas:
        the registry keeps monotone counters, so we write absolute values
        through a read-modify-write."""
        if metrics is None:
            return
        ps = self.page_size
        for name, value in (
            ("audit_commands_total", self.fleet.commands),
            ("audit_quanta_total", self.quanta),
            ("audit_true_pages_total", self.fleet.true),
            ("audit_pred_pages_total", self.fleet.pred),
            ("audit_fneg_pages_total", self.fleet.missed),
            ("audit_fpos_pages_total", self.fleet.wrong),
            ("audit_overfetch_bytes_total", self.overfetch_bytes),
            ("audit_underfetch_stall_us_total", self.underfetch_stall_us),
        ):
            cur = metrics.counter_value(name, track)
            if value > cur:
                metrics.inc(name, track, value - cur)
        metrics.gauge("audit_fneg_page_pct", track, self.fleet_fneg_pct())
        metrics.gauge("audit_fpos_page_pct", track, self.fleet_fpos_pct())
        metrics.gauge("audit_fneg_bytes", track, self.fleet.missed * ps)
        metrics.gauge("audit_fpos_bytes", track, self.fleet.wrong * ps)
        metrics.gauge(
            "audit_template_drift_pp", track, self.fleet_drift_pp()
        )

    # -- reconciliation -----------------------------------------------------
    def reconcile_ledger(self, telemetry) -> dict:
        """Cross-check the under-fetch residue against the stall ledger's
        raw ``fault_service`` accumulators: for a predictive backend, every
        demand-paging stall the ledger attributes happened inside an audited
        quantum, so the totals must agree exactly. Returns both totals and
        their difference (µs) for the caller to assert on."""
        ledger_total = sum(
            telemetry.ledger.raw(tid).get("fault_service", 0.0)
            for tid in set(self.underfetch_stall_by_task)
            | set(telemetry.ledger._acc)
        )
        return {
            "audit_underfetch_stall_us": self.underfetch_stall_us,
            "ledger_fault_service_us": ledger_total,
            "diff_us": self.underfetch_stall_us - ledger_total,
        }

    # -- report -------------------------------------------------------------
    def summary(self) -> dict:
        """The ``audit`` section of a :class:`MetricsReport`."""
        ps = self.page_size
        fleet = self.fleet.to_json()
        fleet.update(
            missed_bytes=self.fleet.missed * ps,
            wrong_bytes=self.fleet.wrong * ps,
        )
        return {
            "fleet": fleet,
            "per_task": {
                str(tid): acc.to_json()
                for tid, acc in sorted(self.per_task.items())
            },
            "per_template": {
                name: dict(t.to_json(), drift_pp=t.drift_pp())
                for name, t in sorted(self.per_template.items())
            },
            "working_set": {
                "quanta": self.quanta,
                "touched_pages": self.ws_true_pages,
                "predicted_pages": self.ws_pred_pages,
                "missed_pages": self.ws_missed_pages,
                "wrong_pages": self.ws_wrong_pages,
                "overfetch_pages": self.overfetch_pages,
                "overfetch_bytes": self.overfetch_bytes,
            },
            "underfetch": {
                "faults": self.underfetch_faults,
                "stall_us": self.underfetch_stall_us,
            },
            "health": self.health(),
        }
