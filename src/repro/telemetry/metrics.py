"""Typed fleet metrics: counters, gauges, and fixed-bucket histograms.

The registry is the aggregated (vLLM/Prometheus-style) view of the same
emission stream the event trace records verbatim: the hub feeds every
``emit()``/``counter()`` through :meth:`MetricsRegistry.on_event` /
:meth:`MetricsRegistry.on_counter`, so metrics see the *true* totals even
when the event list is capped (`max_events` bounds trace memory, not
counter arithmetic). Like :data:`~repro.telemetry.hub.EVENT_TYPES`, the
metric-name taxonomy is closed — :data:`METRIC_TYPES` maps every legal name
to its kind, and the registry rejects unknown names or kind mismatches.

Three metric kinds, all keyed by ``(name, track)``:

  * **counter** — monotone totals (switches, faults, pages moved, audit
    page counts);
  * **gauge** — last-value samples (queue depths, HBM occupancy, the
    prediction-audit F−/F+ rates);
  * **histogram** — fixed-bucket distributions of span durations. Exact
    samples are retained (up to a cap) so percentiles use the repo-wide
    :func:`repro.core.simulator.percentile` nearest-rank convention —
    trace-, report-, and metrics-derived p50/p99 can never disagree.

``rollup()`` snapshots every counter/gauge; the hub banks one rollup per
rebalance tick and one at ``finalize()``, giving a coarse time series of
fleet health next to the fine-grained probes. :class:`MetricsReport` is the
versioned (``metrics-report-v1``) JSON artifact with two exporters:
``to_json`` and ``to_prometheus`` (text exposition format).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple

from repro.core.simulator import percentile

METRICS_SCHEMA = "metrics-report-v1"
_ACCEPTED_SCHEMAS = (METRICS_SCHEMA,)

COUNTER = "counter"
GAUGE = "gauge"
HISTOGRAM = "histogram"

# Closed metric taxonomy (the registry-side mirror of EVENT_TYPES): every
# name the simulator/cluster/audit layers may touch, with its kind. inc/
# gauge/observe reject names outside this table or used with the wrong kind.
METRIC_TYPES: Dict[str, str] = {
    # -- counters: event-stream totals --------------------------------------
    "switches_total": COUNTER,
    "faults_total": COUNTER,
    "fault_stall_us_total": COUNTER,
    "migration_plans_total": COUNTER,
    "migration_pages_total": COUNTER,
    "migration_lands_total": COUNTER,
    "evicted_pages_total": COUNTER,
    "peer_fetches_total": COUNTER,
    "peer_fetch_pages_total": COUNTER,
    "admissions_total": COUNTER,
    "sheds_total": COUNTER,
    "finishes_total": COUNTER,
    "checkpoints_total": COUNTER,
    "recoveries_total": COUNTER,
    "rebalance_ticks_total": COUNTER,
    "gpu_fails_total": COUNTER,
    "gpu_recovers_total": COUNTER,
    "coordinator_crashes_total": COUNTER,
    "coordinator_recovers_total": COUNTER,
    "journal_replays_total": COUNTER,
    "deadline_misses_total": COUNTER,
    "preempts_total": COUNTER,
    "cancels_total": COUNTER,
    # -- counters: prediction-audit totals (repro.telemetry.audit) ----------
    "audit_commands_total": COUNTER,
    "audit_quanta_total": COUNTER,
    "audit_true_pages_total": COUNTER,
    "audit_pred_pages_total": COUNTER,
    "audit_fneg_pages_total": COUNTER,
    "audit_fpos_pages_total": COUNTER,
    "audit_overfetch_bytes_total": COUNTER,
    "audit_underfetch_stall_us_total": COUNTER,
    # -- gauges: sampled state + audit health rates -------------------------
    "hbm_used_pages": GAUGE,
    "run_queue_depth": GAUGE,
    "wait_queue_depth": GAUGE,
    "inflight_bytes": GAUGE,
    "sharers": GAUGE,
    "staged_bytes": GAUGE,
    "bandwidth_factor": GAUGE,
    "audit_fneg_page_pct": GAUGE,
    "audit_fpos_page_pct": GAUGE,
    "audit_fneg_bytes": GAUGE,
    "audit_fpos_bytes": GAUGE,
    "audit_template_drift_pp": GAUGE,
    # -- histograms: span-duration distributions ----------------------------
    "switch_ctrl_us": HISTOGRAM,
    "fault_stall_us": HISTOGRAM,
    "migration_us": HISTOGRAM,
    "peer_fetch_us": HISTOGRAM,
    "checkpoint_bytes": HISTOGRAM,
}

# default log-ish bucket upper bounds (µs for the duration histograms; the
# byte histogram reuses them at byte scale — fixed buckets, not adaptive)
_DEFAULT_BUCKETS = (
    1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0,
    1e3, 2.5e3, 5e3, 1e4, 2.5e4, 5e4, 1e5, 2.5e5, 1e6, 1e7,
)

# exact-sample retention cap per histogram: enough for every CI-scale run;
# beyond it percentiles are computed over the first N samples (flagged in
# the report) while count/sum stay exact
_MAX_SAMPLES = 100_000


class Histogram:
    """Fixed-bucket histogram with exact-sample percentiles.

    ``buckets`` are cumulative-style upper bounds (a terminal +Inf bucket is
    implicit). ``p50()``/``p99()`` delegate to the repo-wide nearest-rank
    :func:`repro.core.simulator.percentile` over the retained raw samples —
    the pinned convention shared with ``SimResult`` and the cluster
    aggregation layer.
    """

    def __init__(self, bounds: Tuple[float, ...] = _DEFAULT_BUCKETS):
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)  # +1: the +Inf bucket
        self.count = 0
        self.sum = 0.0
        self.samples: List[float] = []
        self.samples_capped = False

    def observe(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.sum += v
        i = 0
        for i, le in enumerate(self.bounds):
            if v <= le:
                self.counts[i] += 1
                break
        else:
            self.counts[len(self.bounds)] += 1
        if len(self.samples) < _MAX_SAMPLES:
            self.samples.append(v)
        else:
            self.samples_capped = True

    def pct(self, p: float) -> float:
        return percentile(sorted(self.samples), p)

    def p50(self) -> float:
        return self.pct(50.0)

    def p99(self) -> float:
        return self.pct(99.0)


def _check(name: str, kind: str) -> None:
    actual = METRIC_TYPES.get(name)
    if actual is None:
        raise ValueError(f"unknown metric {name!r} (closed taxonomy)")
    if actual != kind:
        raise ValueError(f"metric {name!r} is a {actual}, used as a {kind}")


class MetricsRegistry:
    """Typed metric store keyed by ``(name, track)`` + rollup snapshots."""

    def __init__(self) -> None:
        self.counters: Dict[Tuple[str, str], float] = {}
        self.gauges: Dict[Tuple[str, str], float] = {}
        self.histograms: Dict[Tuple[str, str], Histogram] = {}
        self.rollups: List[dict] = []

    # -- typed writes -------------------------------------------------------
    def inc(self, name: str, track: str, v: float = 1.0) -> None:
        _check(name, COUNTER)
        if v < 0:
            raise ValueError(f"counter {name!r} cannot decrease (v={v})")
        key = (name, track)
        self.counters[key] = self.counters.get(key, 0.0) + v

    def gauge(self, name: str, track: str, v: float) -> None:
        _check(name, GAUGE)
        self.gauges[(name, track)] = float(v)

    def observe(self, name: str, track: str, v: float) -> None:
        _check(name, HISTOGRAM)
        h = self.histograms.get((name, track))
        if h is None:
            h = self.histograms[(name, track)] = Histogram()
        h.observe(v)

    # -- typed reads (tests / report assembly) ------------------------------
    def counter_value(self, name: str, track: str) -> float:
        return self.counters.get((name, track), 0.0)

    def gauge_value(self, name: str, track: str) -> Optional[float]:
        return self.gauges.get((name, track))

    def histogram(self, name: str, track: str) -> Optional[Histogram]:
        return self.histograms.get((name, track))

    def counter_total(self, name: str) -> float:
        """Sum of one counter over every track (the fleet total)."""
        return sum(
            v for (n, _tr), v in self.counters.items() if n == name
        )

    # -- event-stream feed (called by the hub, before the event cap) --------
    def on_event(
        self, name: str, ph: str, track: str,
        ts_us: float, dur_us: float, args: dict,
    ) -> None:
        if name == "switch":
            if ph == "B":
                self.inc("switches_total", track)
                self.observe(
                    "switch_ctrl_us", track, float(args.get("ctrl_us", 0.0))
                )
        elif name == "fault_service":
            self.inc("faults_total", track, float(args.get("faults", 1)))
            self.inc("fault_stall_us_total", track, dur_us)
            self.observe("fault_stall_us", track, dur_us)
        elif name == "migration_plan":
            self.inc("migration_plans_total", track)
            self.inc("migration_pages_total", track, float(args.get("pages", 0)))
            self.observe("migration_us", track, dur_us)
        elif name == "migration_land":
            self.inc("migration_lands_total", track)
        elif name == "eviction_batch":
            self.inc("evicted_pages_total", track, float(args.get("pages", 0)))
        elif name == "peer_fetch":
            self.inc("peer_fetches_total", track)
            self.inc("peer_fetch_pages_total", track, float(args.get("pages", 0)))
            self.observe("peer_fetch_us", track, dur_us)
        elif name == "checkpoint":
            self.inc("checkpoints_total", track)
            self.observe(
                "checkpoint_bytes", track, float(args.get("nbytes", 0))
            )
        elif name == "admission":
            self.inc("admissions_total", track)
        elif name == "shed":
            self.inc("sheds_total", track)
        elif name == "finish":
            self.inc("finishes_total", track)
        elif name == "recovery":
            self.inc("recoveries_total", track)
        elif name == "rebalance_tick":
            self.inc("rebalance_ticks_total", track)
        elif name == "gpu_fail":
            self.inc("gpu_fails_total", track)
        elif name == "gpu_recover":
            self.inc("gpu_recovers_total", track)
        elif name == "coordinator_crash":
            self.inc("coordinator_crashes_total", track)
        elif name == "coordinator_recover":
            self.inc("coordinator_recovers_total", track)
        elif name == "journal_replay":
            self.inc("journal_replays_total", track)
        elif name == "deadline_miss":
            self.inc("deadline_misses_total", track)
        elif name == "preempt":
            self.inc("preempts_total", track)
        elif name == "cancel":
            self.inc("cancels_total", track)

    def on_counter(self, track: str, name: str, value: float) -> None:
        """Probe-series feed: sampled series whose names are also gauges in
        the taxonomy become last-value gauges (others stay trace-only)."""
        if METRIC_TYPES.get(name) == GAUGE:
            self.gauges[(name, track)] = float(value)

    # -- rollups ------------------------------------------------------------
    def rollup(self, ts_us: float) -> dict:
        """Snapshot every counter and gauge at ``ts_us`` (histograms
        contribute their running count). One row per rebalance tick plus a
        terminal row at finalize — the coarse fleet-health time series."""
        values: Dict[str, float] = {}
        for (name, track), v in sorted(self.counters.items()):
            values[f"{track}/{name}"] = v
        for (name, track), v in sorted(self.gauges.items()):
            values[f"{track}/{name}"] = v
        for (name, track), h in sorted(self.histograms.items()):
            values[f"{track}/{name}_count"] = float(h.count)
        row = {"ts_us": float(ts_us), "values": values}
        self.rollups.append(row)
        return row

    # -- report assembly ----------------------------------------------------
    def report(
        self, generated_us: float = 0.0, audit: Optional[dict] = None
    ) -> "MetricsReport":
        rows: List[dict] = []
        for (name, track), v in sorted(self.counters.items()):
            rows.append(
                {"name": name, "track": track, "kind": COUNTER, "value": v}
            )
        for (name, track), v in sorted(self.gauges.items()):
            rows.append(
                {"name": name, "track": track, "kind": GAUGE, "value": v}
            )
        for (name, track), h in sorted(self.histograms.items()):
            rows.append(
                {
                    "name": name,
                    "track": track,
                    "kind": HISTOGRAM,
                    "count": h.count,
                    "sum": h.sum,
                    "p50": h.p50(),
                    "p99": h.p99(),
                    "samples_capped": h.samples_capped,
                    "buckets": [
                        [le, c] for le, c in zip(h.bounds, h.counts)
                    ] + [["+Inf", h.counts[-1]]],
                }
            )
        return MetricsReport(
            generated_us=float(generated_us),
            metrics=rows,
            rollups=list(self.rollups),
            audit=audit,
        )


@dataclasses.dataclass
class MetricsReport:
    """Versioned metrics artifact (``metrics-report-v1``): the registry's
    full state, the rollup time series, and (when the prediction auditor
    ran) its fleet/per-task/per-template accuracy summary."""

    generated_us: float
    metrics: List[dict]
    rollups: List[dict]
    audit: Optional[dict] = None

    def to_json(self) -> dict:
        return {
            "schema": METRICS_SCHEMA,
            "generated_us": self.generated_us,
            "metrics": self.metrics,
            "rollups": self.rollups,
            "audit": self.audit,
        }

    @classmethod
    def from_json(cls, doc: dict) -> "MetricsReport":
        schema = doc.get("schema")
        if schema not in _ACCEPTED_SCHEMAS:
            raise ValueError(
                f"unknown metrics schema {schema!r} "
                f"(accepted: {', '.join(_ACCEPTED_SCHEMAS)})"
            )
        return cls(
            generated_us=float(doc.get("generated_us", 0.0)),
            metrics=list(doc.get("metrics", [])),
            rollups=list(doc.get("rollups", [])),
            audit=doc.get("audit"),
        )

    def write(self, path) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2)
            f.write("\n")

    # -- Prometheus text exposition format ----------------------------------
    def to_prometheus(self, prefix: str = "msched_") -> str:
        """Render as the Prometheus text format (one scrape body). Counters
        keep their ``_total`` suffix; histograms expand to ``_bucket``
        (cumulative ``le`` counts), ``_sum``, and ``_count`` series."""
        by_name: Dict[str, List[dict]] = {}
        for row in self.metrics:
            by_name.setdefault(row["name"], []).append(row)
        out: List[str] = []
        for name in sorted(by_name):
            rows = by_name[name]
            kind = rows[0]["kind"]
            out.append(f"# TYPE {prefix}{name} {kind}")
            for row in rows:
                label = f'{{track="{row["track"]}"}}'
                if kind == HISTOGRAM:
                    cum = 0
                    for le, c in row["buckets"]:
                        cum += c
                        le_s = le if isinstance(le, str) else f"{le:g}"
                        out.append(
                            f'{prefix}{name}_bucket'
                            f'{{track="{row["track"]}",le="{le_s}"}} {cum}'
                        )
                    out.append(f"{prefix}{name}_sum{label} {row['sum']:g}")
                    out.append(f"{prefix}{name}_count{label} {row['count']}")
                else:
                    out.append(f"{prefix}{name}{label} {row['value']:g}")
        return "\n".join(out) + "\n"
