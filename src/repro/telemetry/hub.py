"""The telemetry hub: typed structured events, sampled time-series probes,
and the stall-attribution ledger.

One :class:`Telemetry` instance observes a whole run — a single
``simulate()`` core or an N-GPU ``simulate_cluster`` fleet (every core
shares the hub; events carry their originating track). The hub is strictly
an *observer*: emission never mutates simulation state, and every emission
site in the simulator/cluster layers is guarded by ``telemetry is not None``
— a run with ``telemetry=None`` takes exactly today's code paths, which is
the same structural bit-for-bit guarantee the peer-prefetch fabric and the
fault runtime already follow (machinery that is off is never constructed).

Three data planes:

  * **events** — timestamped, typed (:data:`EVENT_TYPES`) records with a
    Chrome ``trace_event`` phase (``B``/``E`` duration pairs for context
    switches, ``X`` complete spans for fault service / migrations /
    checkpoints, ``i`` instants for admissions / sheds / failures);
  * **series** — ``(track, name) -> [(t, value), ...]`` counters sampled at
    quantum boundaries (per-GPU HBM occupancy, queue depths — strided by
    ``sample_stride``) and rebalance ticks (per-link in-flight bytes and
    sharer counts, host staging usage);
  * **ledger** — the :class:`StallLedger`, accumulating per-task stall
    micro-seconds by cause as the simulation attributes them, and resolving
    them into a conservation-checked breakdown at :meth:`Telemetry.finalize`.

Long fault-thrashing runs can emit millions of ``fault_service`` spans;
``max_events`` caps the event list (never silently: drops are counted in
``dropped_events`` and exported). ``E`` events are exempt from the cap so
begin/end pairs stay balanced for the trace validator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

# Every structured event type the simulator and cluster layers emit. The
# context switch is one logical type emitted as a B/E pair ("switch_begin/
# end" in the docs); everything else is a complete span or an instant.
EVENT_TYPES = frozenset(
    {
        "switch",  # B/E pair around one timeslice (ctrl + commands)
        "fault_service",  # X: demand-paging stall on one command
        "migration_plan",  # X: proactive population / cluster move transit
        "migration_land",  # i: a migrated continuation arrives on dst
        "peer_fetch",  # X: NVLink peer-HBM fetch in flight
        "eviction_batch",  # i: batched eviction at a context switch
        "admission",  # i: a queued request is admitted
        "shed",  # i: admission reject or graceful-degradation shed
        "checkpoint",  # X: periodic D2H working-set snapshot
        "gpu_fail",  # i: device failure boundary
        "gpu_recover",  # i: device back up
        "rebalance_tick",  # i: one rebalancer tick on the cluster track
        "transfer_plan",  # X: one planner window (admission -> makespan)
        "recovery",  # i: one recovery decision for a fault victim
        "finish",  # i: a task retires
        "coordinator_crash",  # i: control plane lost its volatile state
        "coordinator_recover",  # i: control plane back up (journal or cold)
        "journal_replay",  # i: decision-journal replay at recovery
        "deadline_miss",  # i: an RT task projected to miss its deadline
        "preempt",  # i: deadline enforcement preempted a BE task
        "cancel",  # i: operator cancel through the control plane
    }
)

_PHASES = frozenset({"B", "E", "X", "i"})

# Cluster-scope events (rebalance ticks) live on this track; link counters
# live on "link:<a><-><b>" tracks and host staging on "host".
TRACK_CLUSTER = "cluster"


@dataclasses.dataclass
class TelemetryEvent:
    """One structured event. ``ts_us``/``dur_us`` are simulation
    micro-seconds (the Chrome trace_event native unit)."""

    ts_us: float
    name: str
    ph: str  # "B" | "E" | "X" | "i"
    track: str  # GPU name, "cluster", "host", or "link:a<->b"
    dur_us: float = 0.0
    task_id: Optional[int] = None
    args: Dict[str, object] = dataclasses.field(default_factory=dict)


class LedgerConservationError(AssertionError):
    """A task's attributed stall time exceeds its non-compute wall gap —
    some source double-counted. Raised by :meth:`StallLedger.breakdown`."""


# Public attribution taxonomy (docs/observability.md): every µs of a
# finished task's non-compute wall time lands in exactly one bucket.
STALL_CATEGORIES = (
    "fault-service",
    "migration-wait",
    "queue-wait",
    "link-contention",
    "recovery",
    "scheduler-control",
)

# Internal accumulator keys. migration-wait has two components with
# different conservation roles: ready-view delays *inside* a timeslice
# (counted within TaskStats.busy_us, so they must be subtracted to recover
# pure compute) and inter-GPU transit *outside* any timeslice.
_ACC_KEYS = (
    "fault_service",
    "mig_wait_exec",
    "mig_wait_transit",
    "link_contention",
    "recovery",
    "scheduler_control",
)

# float tolerance for the conservation assertion, in µs per µs of wall
_CONSERVATION_RTOL = 1e-6


class StallLedger:
    """Per-task stall accumulator + conservation-checked resolver.

    The simulator attributes stalls as they happen (``add``); at the end of
    a run :meth:`breakdown` resolves each *finished* request's accumulators
    against its merged record and task stats:

    ``wall = finished_us - arrival_us``
    ``compute = busy_us - fault_service - mig_wait_exec``  (busy includes
    in-slice stalls, so pure compute is recovered by subtraction)
    ``queue-wait = wall - compute - (all directly-attributed buckets)``

    queue-wait is the residual by construction, which is what makes the
    conservation *exact*: the six categories sum to ``wall - compute`` to
    float precision. The assertion with teeth is the sign check — a
    materially negative residual means a source double-counted, and
    :class:`LedgerConservationError` is raised.

    One carve-out: the DES simulates timeslices atomically, so a task
    interrupted by a GPU failure mid-slice banks the *whole* slice's
    ``busy_us`` even though the fault boundary cut it short — the victim's
    banked compute can overlap its recovered continuation's timeline and
    exceed the wall gap. For records marked fault-interrupted (``failed_us``
    / ``crashed_us`` / ``recovered_from`` / ``redispatched_from`` in their
    meta) compute is clamped to what the wall can hold and the excess is
    reported as ``overlap_us`` — conservation over the six categories stays
    exact against the clamped compute, and the sign check still fires when
    the directly-attributed buckets alone exceed the wall.
    """

    _INTERRUPTED_META = (
        "failed_us", "crashed_us", "recovered_from", "redispatched_from",
    )

    def __init__(self) -> None:
        self._acc: Dict[int, Dict[str, float]] = {}

    def add(self, task_id: int, key: str, us: float) -> None:
        if key not in _ACC_KEYS:
            raise ValueError(f"unknown stall key {key!r}")
        if us <= 0.0:
            return
        acc = self._acc.get(task_id)
        if acc is None:
            acc = self._acc[task_id] = {}
        acc[key] = acc.get(key, 0.0) + us

    def raw(self, task_id: int) -> Dict[str, float]:
        """The unresolved accumulator (tests / debugging)."""
        return dict(self._acc.get(task_id, {}))

    def breakdown(self, result) -> Dict[int, Dict[str, float]]:
        """Resolve the ledger against a (merged) ``SimResult``. Only
        finished requests resolve — a task with no record (static mode) or
        no completion has no well-defined wall gap. Returns
        ``{task_id: {category: µs, "compute_us": .., "wall_us": ..,
        "non_compute_us": ..}}``."""
        out: Dict[int, Dict[str, float]] = {}
        for rec in result.requests:
            if rec.finished_us is None or rec.rejected:
                continue
            tid = rec.task_id
            st = result.per_task.get(tid)
            if st is None:
                continue
            acc = self._acc.get(tid, {})
            fault = acc.get("fault_service", 0.0)
            mw_exec = acc.get("mig_wait_exec", 0.0)
            mw_transit = acc.get("mig_wait_transit", 0.0)
            link = acc.get("link_contention", 0.0)
            recov = acc.get("recovery", 0.0)
            ctrl = acc.get("scheduler_control", 0.0)
            wall = rec.finished_us - rec.arrival_us
            compute = st.busy_us - fault - mw_exec
            attributed = fault + mw_exec + mw_transit + link + recov + ctrl
            overlap = 0.0
            if any(k in rec.meta for k in self._INTERRUPTED_META):
                # fault-interrupted slice: banked busy may overshoot the
                # failure boundary (see class docstring) — clamp
                ceiling = max(0.0, wall - attributed)
                if compute > ceiling:
                    overlap = compute - ceiling
                    compute = ceiling
            non_compute = wall - compute
            queue = non_compute - attributed
            tol = _CONSERVATION_RTOL * max(1.0, wall)
            if queue < -tol:
                raise LedgerConservationError(
                    f"task {tid}: attributed stall {attributed:.3f}us "
                    f"exceeds non-compute wall {non_compute:.3f}us "
                    f"(residual {queue:.3f}us) — a source double-counted"
                )
            out[tid] = {
                "fault-service": fault,
                "migration-wait": mw_exec + mw_transit,
                "queue-wait": queue,
                "link-contention": link,
                "recovery": recov,
                "scheduler-control": ctrl,
                "compute_us": compute,
                "wall_us": wall,
                "non_compute_us": non_compute,
                "overlap_us": overlap,
            }
        return out


class Telemetry:
    """The hub every instrumented layer emits into.

    ``sample_stride`` thins the per-quantum probes (1 = every context
    switch); rebalance-tick probes are never strided. ``max_events`` bounds
    the event list — see the module docstring.
    """

    def __init__(
        self,
        sample_stride: int = 8,
        max_events: int = 500_000,
        metrics=False,
        audit=False,
    ):
        if sample_stride < 1:
            raise ValueError("sample_stride must be >= 1")
        self.sample_stride = int(sample_stride)
        self.max_events = int(max_events)
        self.events: List[TelemetryEvent] = []
        self.series: Dict[Tuple[str, str], List[Tuple[float, float]]] = {}
        self.ledger = StallLedger()
        self.dropped_events = 0
        self.summary: Dict[str, object] = {}
        self._breakdown: Optional[Dict[int, Dict[str, float]]] = None
        # per-(track, name) count of B events dropped at the cap whose E is
        # still pending — those Es are dropped too, keeping pairs balanced
        self._dropped_open: Dict[Tuple[str, str], int] = {}
        # optional aggregation planes (off by default — the hub alone is the
        # PR-7 surface): a typed MetricsRegistry fed from the same emission
        # stream, and the online prediction auditor. Both are observers; a
        # run with them attached is bit-for-bit identical to one without.
        if metrics is True:
            from repro.telemetry.metrics import MetricsRegistry

            metrics = MetricsRegistry()
        self.metrics = metrics or None
        if audit is True:
            from repro.telemetry.audit import PredictionAuditor

            audit = PredictionAuditor(metrics=self.metrics)
        self.audit = audit or None

    # -- emission -----------------------------------------------------------
    def emit(
        self,
        name: str,
        ph: str,
        track: str,
        ts_us: float,
        dur_us: float = 0.0,
        task_id: Optional[int] = None,
        **args,
    ) -> None:
        if name not in EVENT_TYPES:
            raise ValueError(f"unknown telemetry event {name!r}")
        if ph not in _PHASES:
            raise ValueError(f"unknown trace phase {ph!r}")
        # metrics see every emission *before* the cap: the cap bounds trace
        # memory, not counter arithmetic — capped runs keep true totals
        if self.metrics is not None:
            self.metrics.on_event(name, ph, track, ts_us, dur_us, args)
            if name == "rebalance_tick":
                self._bank_rollup(ts_us)
        # "E" is exempt from the cap so B/E pairs stay balanced — but an E
        # whose own B was dropped must be dropped too, or the validator sees
        # an unmatched E (per-(track, name) bookkeeping below)
        if ph == "E":
            key = (track, name)
            pending = self._dropped_open.get(key, 0)
            if pending:
                self._dropped_open[key] = pending - 1
                self.dropped_events += 1
                return
        elif len(self.events) >= self.max_events:
            self.dropped_events += 1
            if ph == "B":
                key = (track, name)
                self._dropped_open[key] = self._dropped_open.get(key, 0) + 1
            return
        self.events.append(
            TelemetryEvent(ts_us, name, ph, track, dur_us, task_id, args)
        )

    def begin(self, name, track, ts_us, task_id=None, **args) -> None:
        self.emit(name, "B", track, ts_us, task_id=task_id, **args)

    def end(self, name, track, ts_us, task_id=None, **args) -> None:
        self.emit(name, "E", track, ts_us, task_id=task_id, **args)

    def span(self, name, track, ts_us, dur_us, task_id=None, **args) -> None:
        self.emit(
            name, "X", track, ts_us, dur_us=max(0.0, dur_us),
            task_id=task_id, **args,
        )

    def instant(self, name, track, ts_us, task_id=None, **args) -> None:
        self.emit(name, "i", track, ts_us, task_id=task_id, **args)

    def counter(self, track: str, name: str, ts_us: float, value) -> None:
        self.series.setdefault((track, name), []).append(
            (ts_us, float(value))
        )
        if self.metrics is not None:
            self.metrics.on_counter(track, name, value)

    def stall(self, task_id: int, key: str, us: float) -> None:
        self.ledger.add(task_id, key, us)

    # -- finalization -------------------------------------------------------
    def finalize(self, result) -> Dict[int, Dict[str, float]]:
        """Resolve the stall ledger against a finished run's (merged)
        ``SimResult`` and bank the run summary. Called automatically by
        ``simulate()`` / ``simulate_cluster()`` when a hub is attached."""
        self._breakdown = self.ledger.breakdown(result)
        self.summary.update(
            sim_us=result.sim_us,
            faults=result.faults,
            migrated_bytes=result.migrated_bytes,
            switches=result.switches,
            control_us=result.control_us,
            dropped_events=self.dropped_events,
        )
        if self.metrics is not None:
            self._bank_rollup(result.sim_us)
        if self.audit is not None:
            self.summary["prediction_audit"] = self.audit.health()
        return self._breakdown

    def finalize_cluster(self, report) -> Dict[int, Dict[str, float]]:
        """Cluster variant: resolves against the merged fleet result and
        adds fleet-level counters to the summary."""
        bd = self.finalize(report.merged)
        self.summary.update(
            n_gpus=report.n_gpus,
            migrations=len(report.migrations),
            peer_fetch_bytes=report.peer_fetch_bytes,
            recoveries=len(report.recoveries),
            checkpoints=report.checkpoints,
            faults_applied=report.faults_applied,
        )
        return bd

    def stall_breakdown(self) -> Dict[int, Dict[str, float]]:
        if self._breakdown is None:
            raise RuntimeError(
                "stall ledger not resolved; finalize(result) runs "
                "automatically at the end of simulate()/simulate_cluster()"
            )
        return self._breakdown

    def stall_totals(self) -> Dict[str, float]:
        """Fleet-wide µs per category, summed over finished tasks."""
        totals = {cat: 0.0 for cat in STALL_CATEGORIES}
        totals["compute_us"] = 0.0
        totals["non_compute_us"] = 0.0
        for row in self.stall_breakdown().values():
            for cat in STALL_CATEGORIES:
                totals[cat] += row[cat]
            totals["compute_us"] += row["compute_us"]
            totals["non_compute_us"] += row["non_compute_us"]
        return totals

    # -- metrics plane ------------------------------------------------------
    def _bank_rollup(self, ts_us: float) -> None:
        """One metrics snapshot: audit gauges refreshed first, so the rollup
        row carries current prediction health. Called at every rebalance
        tick and once at finalize — the finalize stamp (merged sim_us) can
        precede the last drain-window tick, so clamp to keep the rollup
        time series monotone."""
        if self.audit is not None:
            self.audit.export_gauges(self.metrics)
        if self.metrics.rollups:
            ts_us = max(ts_us, self.metrics.rollups[-1]["ts_us"])
        self.metrics.rollup(ts_us)

    def metrics_report(self, generated_us: Optional[float] = None):
        """Assemble the versioned :class:`~repro.telemetry.metrics.
        MetricsReport` (registry state + rollups + audit summary). Requires
        ``Telemetry(metrics=True)``."""
        if self.metrics is None:
            raise RuntimeError(
                "no metrics registry attached; construct the hub with "
                "Telemetry(metrics=True)"
            )
        if self.audit is not None:
            self.audit.export_gauges(self.metrics)
        if generated_us is None:
            generated_us = float(self.summary.get("sim_us", 0.0))
        return self.metrics.report(
            generated_us=generated_us,
            audit=self.audit.summary() if self.audit is not None else None,
        )

    # -- export (delegates to repro.telemetry.export) -----------------------
    def chrome_trace(self) -> dict:
        from repro.telemetry.export import chrome_trace

        return chrome_trace(self)

    def write_chrome(self, path) -> None:
        from repro.telemetry.export import write_chrome

        write_chrome(self, path)

    def write_jsonl(self, path) -> None:
        from repro.telemetry.export import write_jsonl

        write_jsonl(self, path)
