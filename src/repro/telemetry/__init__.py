"""Fleet-wide observability: structured event tracing, sampled time-series
probes, and causally-attributed stall accounting.

The subsystem is zero-overhead when off: every hook in the simulator and
cluster layers is nullable (``telemetry=None`` — the default — emits
nothing and constructs nothing), so untraced runs are bit-for-bit identical
to a tree without this package. See ``docs/observability.md`` for the event
schema, the attribution taxonomy, and the Perfetto walkthrough.

  * :class:`~repro.telemetry.hub.Telemetry` — the hub cores/cluster emit
    into; owns events, counter series, and the stall ledger;
  * :class:`~repro.telemetry.hub.StallLedger` — classifies every µs of
    per-task non-compute wall time into {fault-service, migration-wait,
    queue-wait, link-contention, recovery, scheduler-control}, with exact
    conservation asserted;
  * :mod:`~repro.telemetry.export` — Chrome trace_event JSON (Perfetto /
    ``chrome://tracing``) and JSONL exporters, plus the validator behind
    ``scripts/trace_report.py --validate``;
  * :mod:`~repro.telemetry.metrics` — the typed metrics registry (counters /
    gauges / fixed-bucket histograms keyed by ``(name, track)``, closed
    taxonomy) and the versioned ``metrics-report-v1`` artifact with
    Prometheus + JSON exporters (``Telemetry(metrics=True)``);
  * :mod:`~repro.telemetry.audit` — the online prediction auditor scoring
    the paper's Table 1 accuracy claim live at every extended context
    switch and fault-service boundary (``Telemetry(audit=True)``).
"""
from repro.telemetry.audit import PredictionAuditor  # noqa: F401
from repro.telemetry.export import (  # noqa: F401
    SCHEMA,
    chrome_trace,
    validate_trace,
    write_chrome,
    write_jsonl,
)
from repro.telemetry.metrics import (  # noqa: F401
    METRIC_TYPES,
    METRICS_SCHEMA,
    Histogram,
    MetricsRegistry,
    MetricsReport,
)
from repro.telemetry.hub import (  # noqa: F401
    EVENT_TYPES,
    STALL_CATEGORIES,
    TRACK_CLUSTER,
    LedgerConservationError,
    StallLedger,
    Telemetry,
    TelemetryEvent,
)
