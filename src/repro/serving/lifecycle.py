"""Request lifecycle: one served request as a finite GPU task.

``ServedRequestTask`` layers the serving request lifecycle on top of
``LLMDecodeTask`` (llama.cpp-style: one process per session, disjoint address
space — the paper's MultiLLM regime):

  * **prefill** — iteration 0 processes the whole prompt: the attention
    kernel covers the prompt-length KV slice and the weight-bound kernels are
    scaled by the prefill compute factor;
  * **per-request KV allocation** — the KV cache is sized to exactly
    ``prompt_tokens + output_tokens`` (not the model's max context), so KV
    footprint tracks the request, not the worst case;
  * **decode-to-EOS** — iterations 1..N-1 each decode one token against the
    growing KV slice; ``total_iterations = output_tokens`` makes the
    simulator retire the task at EOS;
  * **KV free on completion** — ``release()`` frees the KV buffers and tears
    down the address space so the HBM pool reclaims every page.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.commands import Command
from repro.core.workloads import LLMDecodeTask
from repro.serving.traces import Request

# Prefill cost model: below this many prompt tokens one prefill pass is
# weight-bandwidth-bound (costs one decode step); above it, compute scales
# linearly with prompt length (paper Fig. 2: a decode step streams the whole
# model, so prefill amortizes weight reads over the batch of prompt tokens).
PREFILL_TOKENS_PER_WEIGHT_PASS = 128


class ServedRequestTask(LLMDecodeTask):
    """A single request's command stream; retires at EOS."""

    name = "served_request"

    def __init__(
        self,
        task_id: int,
        request: Request,
        page_size: int = 1 << 20,
        bytes_per_weight: float = 1.0,
        kv_headroom_tokens: int = 0,
    ):
        if request.output_tokens < 1 or request.prompt_tokens < 1:
            raise ValueError(
                f"request {request.req_id}: prompt/output token counts must "
                f"be >= 1, got {request.prompt_tokens}/{request.output_tokens}"
            )
        ctx = request.prompt_tokens + request.output_tokens + kv_headroom_tokens
        super().__init__(
            task_id,
            arch=request.tenant,
            max_context=ctx,
            start_len=request.prompt_tokens,
            bytes_per_weight=bytes_per_weight,
            page_size=page_size,
        )
        self.request = request
        self.slo_class = request.slo_class  # graceful-degradation class
        self.name = f"req{request.req_id}_{request.tenant}"
        self.total_iterations = request.output_tokens
        self._prefill_factor = max(
            1.0, request.prompt_tokens / PREFILL_TOKENS_PER_WEIGHT_PASS
        )

    def iteration(self, it: int) -> List[Command]:
        cmds = super().iteration(it)
        if it == 0 and self._prefill_factor > 1.0:
            # prefill: the weight-bound kernels process the whole prompt in
            # one pass; the attention command already covers the prompt-length
            # KV slice via start_len
            for c in cmds:
                if c.name != "llm_attn":
                    c.latency_us *= self._prefill_factor
        return cmds

    def kv_bytes(self) -> int:
        return sum(b.size for b in self.kv)

    def free_kv(self) -> int:
        """Free the per-request KV cache buffers (EOS); returns bytes freed."""
        freed = 0
        for buf in self.kv:
            if buf.buf_id in self.space.buffers:
                freed += buf.size
                self.space.free(buf)
        return freed

    def release(self):
        """EOS teardown: KV first (the per-request state), then the space."""
        self.free_kv()
        return super().release()
