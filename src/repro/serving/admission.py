"""Admission control for the dynamic serving regime.

``AlwaysAdmit`` is the naive baseline: every request becomes a task the
moment it arrives, so concurrency — and with it memory pressure — is
unbounded (demand paging's thrashing regime).

``MSchedAdmission`` is MSched-aware: it reconstructs the *per-schedule-cycle
HBM demand* from exactly the state the memory manager already maintains —
each admitted task's predicted working set (the helper's annotated future,
cut to one scheduling quantum, i.e. what the planner would migrate on that
task's next switch) — and admits a candidate only while that demand plus the
candidate's *full footprint* (no helper exists yet, so the conservative
bound) fits within a headroom fraction of HBM.
Otherwise the request queues; the queue head is re-evaluated at every
scheduler step — so capacity freed by a retirement is picked up at the next
context switch — in FIFO order with no overtaking. A wait deadline turns
starvation into an explicit rejection.
"""
from __future__ import annotations

from typing import Dict, Optional

# predicted_working_set_pages / footprint_pages / active_demand_pages moved
# into core (repro.core.memory_manager / workloads / simulator) so the
# cluster placement bin-packer can share them; re-exported here for
# backwards compatibility.
from repro.core.memory_manager import predicted_working_set_pages  # noqa: F401
from repro.core.simulator import (
    AdmissionController,
    SimState,
    active_demand_pages,
)
from repro.core.workloads import TaskProgram, footprint_pages  # noqa: F401
from repro.control.deadline import slo_class_of


class AlwaysAdmit(AdmissionController):
    """Naive baseline: unbounded concurrency."""

    def decide(self, prog, arrival_us, state):
        return "admit"


class MSchedAdmission(AdmissionController):
    """Admit while predicted per-cycle working sets fit in HBM headroom.

    ``headroom`` is the fraction of HBM capacity the admitted working sets
    may claim (< 1 reserves slack for mispredictions and the control plane;
    > 1 deliberately oversubscribes the *working sets*, betting on MSched's
    proactive swap). ``max_wait_us`` rejects requests queued longer than the
    deadline (callers surface this as load shedding). ``be_headroom``
    optionally holds best-effort ("be" SLO class) candidates to a tighter
    budget than real-time ones, reserving slack for "rt" work under
    degraded capacity.
    """

    def __init__(
        self,
        headroom: float = 0.9,
        max_wait_us: Optional[float] = None,
        quantum_us: Optional[float] = None,
        be_headroom: Optional[float] = None,
    ):
        assert headroom > 0
        assert be_headroom is None or 0 < be_headroom <= headroom
        self.headroom = headroom
        self.be_headroom = be_headroom
        self.max_wait_us = max_wait_us
        self.quantum_us = quantum_us
        # diagnostics (per request, not per decide() call — queued candidates
        # are re-evaluated on every scheduler step)
        self.admitted = 0
        self.queued = 0
        self.rejected = 0
        self._queued_ids: set = set()

    def _demand_pages(self, state: SimState, quantum_us: float) -> int:
        """Per-cycle HBM demand: every active task runs once per round-robin
        cycle of the scheduler timeline, so the cycle demand is the sum of
        the predicted per-quantum working sets of all admitted tasks (see
        :func:`repro.core.simulator.active_demand_pages`)."""
        return active_demand_pages(state, quantum_us)

    def decide(self, prog, arrival_us, state):
        if (
            self.max_wait_us is not None
            and state.now - arrival_us > self.max_wait_us
        ):
            self.rejected += 1
            self._queued_ids.discard(prog.task_id)
            return "reject"
        if not state.active:
            self.admitted += 1
            self._queued_ids.discard(prog.task_id)
            return "admit"  # an idle device always takes work
        quantum = self.quantum_us or getattr(state.policy, "quantum_us", 5_000.0)
        demand = self._demand_pages(state, quantum)
        candidate = footprint_pages(prog, state.page_size)
        # best-effort work admits against the tighter be_headroom budget so
        # that degraded fleets keep slack for real-time requests; classify
        # with the control plane's rule so admission, deadline enforcement,
        # and shedding all agree on what counts as "rt"
        headroom = self.headroom
        if (
            self.be_headroom is not None
            and slo_class_of(getattr(prog, "meta", None), prog) == "be"
        ):
            headroom = self.be_headroom
        if demand + candidate <= headroom * state.pool.capacity:
            self.admitted += 1
            self._queued_ids.discard(prog.task_id)
            return "admit"
        if prog.task_id not in self._queued_ids:
            self._queued_ids.add(prog.task_id)
            self.queued += 1
        return "queue"
