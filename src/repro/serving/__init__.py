"""Trace-driven multi-tenant LLM serving on top of the MSched simulator.

The serving subsystem turns the static multitasking simulator into an
open-loop serving engine: request traces (Poisson / bursty / diurnal) become
dynamic task arrivals, each request runs a prefill→decode→EOS lifecycle as a
finite task, and an MSched-aware admission controller decides admit/queue/
reject from the predicted working sets and the scheduler timeline.
"""
from repro.serving.admission import (  # noqa: F401
    AlwaysAdmit,
    MSchedAdmission,
    footprint_pages,
    predicted_working_set_pages,
)
from repro.serving.engine import (  # noqa: F401
    SLOSpec,
    ServeReport,
    build_events,
    serve_trace,
)
from repro.serving.lifecycle import ServedRequestTask  # noqa: F401
from repro.serving.traces import (  # noqa: F401
    GENERATORS,
    Request,
    Trace,
    bursty_trace,
    diurnal_trace,
    poisson_trace,
)
