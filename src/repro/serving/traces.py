"""Request trace generation and the replayable ``Trace`` format.

A trace is the serving-side input to the simulator: a time-ordered list of
requests, each with an arrival timestamp, a tenant (model arch), and sampled
prompt/output lengths. Three arrival processes cover the regimes the serving
literature sweeps:

  * ``poisson_trace``  — memoryless arrivals at a fixed rate (the classic
    open-loop load generator);
  * ``bursty_trace``   — Gamma-distributed inter-arrivals with a coefficient
    of variation > 1 (micro-bursts; production LLM traffic is bursty);
  * ``diurnal_trace``  — a sinusoidal rate profile replayed via Poisson
    thinning (a scaled day: peak/trough load in one window).

All generators are deterministic under a fixed seed (``random.Random``; no
global RNG state), and every trace round-trips through JSON so benchmark runs
are replayable byte-for-byte.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

import random

DEFAULT_TENANT = "paper-llama3-8b"


@dataclasses.dataclass(frozen=True)
class Request:
    req_id: int
    tenant: str  # model arch served for this request
    arrival_us: float
    prompt_tokens: int
    output_tokens: int
    # SLO class: "rt" (real-time, shed last under degraded capacity) or
    # "be" (best-effort, shed first). Default keeps old traces replayable.
    slo_class: str = "be"


@dataclasses.dataclass
class Trace:
    requests: List[Request]
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.requests)

    def __iter__(self):
        return iter(self.requests)

    def duration_us(self) -> float:
        return self.requests[-1].arrival_us if self.requests else 0.0

    def offered_rate_rps(self) -> float:
        d = self.duration_us()
        return len(self.requests) / (d * 1e-6) if d else 0.0

    def total_output_tokens(self) -> int:
        return sum(r.output_tokens for r in self.requests)

    # -- replayable serialization -------------------------------------------
    def to_json(self) -> str:
        return json.dumps(
            {
                "meta": self.meta,
                "requests": [dataclasses.asdict(r) for r in self.requests],
            },
            indent=2,
        )

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        obj = json.loads(text)
        return cls(
            requests=[Request(**r) for r in obj.get("requests", [])],
            meta=obj.get("meta", {}),
        )

    def save(self, path: Union[str, Path]) -> None:
        Path(path).write_text(self.to_json() + "\n")

    @classmethod
    def load(cls, path: Union[str, Path]) -> "Trace":
        return cls.from_json(Path(path).read_text())


# --------------------------------------------------------------------------
# Length sampling
# --------------------------------------------------------------------------


def _sample_lengths(
    rnd: random.Random,
    prompt_mean: int,
    output_mean: int,
    max_prompt: int,
    max_output: int,
) -> Tuple[int, int]:
    """Lognormal prompts (long-tailed, like real chat prompts) and
    exponential-ish output lengths (decode-to-EOS is geometric)."""
    prompt = int(rnd.lognormvariate(math.log(max(prompt_mean, 1)), 0.6))
    output = int(rnd.expovariate(1.0 / max(output_mean, 1))) + 1
    return (
        max(1, min(prompt, max_prompt)),
        max(1, min(output, max_output)),
    )


def _finish(
    arrivals_us: List[float],
    rnd: random.Random,
    tenants: Sequence[str],
    prompt_mean: int,
    output_mean: int,
    max_prompt: int,
    max_output: int,
    meta: Dict[str, object],
    rt_fraction: float = 0.0,
) -> Trace:
    reqs = []
    for i, t_us in enumerate(arrivals_us):
        p, o = _sample_lengths(rnd, prompt_mean, output_mean, max_prompt, max_output)
        # draw the class only when classes are in play: the extra RNG pull
        # would otherwise shift every later length sample and break golden
        # pins on class-free traces
        klass = (
            "rt" if rt_fraction > 0.0 and rnd.random() < rt_fraction else "be"
        )
        reqs.append(
            Request(i, tenants[i % len(tenants)], t_us, p, o, klass)
        )
    return Trace(reqs, meta)


# --------------------------------------------------------------------------
# Arrival processes
# --------------------------------------------------------------------------


def poisson_trace(
    rate_rps: float,
    duration_s: float,
    seed: int = 0,
    tenants: Sequence[str] = (DEFAULT_TENANT,),
    prompt_mean: int = 256,
    output_mean: int = 32,
    max_prompt: int = 2048,
    max_output: int = 256,
    rt_fraction: float = 0.0,
) -> Trace:
    """Memoryless arrivals: exponential inter-arrival times at ``rate_rps``.
    ``rt_fraction`` tags that share of requests real-time ("rt" SLO class,
    protected by graceful degradation); 0 keeps the trace identical to
    class-free generation."""
    rnd = random.Random(seed)
    arrivals: List[float] = []
    t = 0.0
    horizon_us = duration_s * 1e6
    while True:
        t += rnd.expovariate(rate_rps) * 1e6
        if t >= horizon_us:
            break
        arrivals.append(t)
    return _finish(
        arrivals, rnd, tenants, prompt_mean, output_mean, max_prompt, max_output,
        {"process": "poisson", "rate_rps": rate_rps, "duration_s": duration_s,
         "seed": seed},
        rt_fraction=rt_fraction,
    )


def bursty_trace(
    rate_rps: float,
    duration_s: float,
    seed: int = 0,
    cv: float = 3.0,
    tenants: Sequence[str] = (DEFAULT_TENANT,),
    prompt_mean: int = 256,
    output_mean: int = 32,
    max_prompt: int = 2048,
    max_output: int = 256,
    rt_fraction: float = 0.0,
) -> Trace:
    """Gamma inter-arrivals with coefficient of variation ``cv`` (> 1 means
    burstier than Poisson at the same mean rate)."""
    assert cv > 0
    rnd = random.Random(seed)
    shape = 1.0 / (cv * cv)  # CV of Gamma(k, θ) is 1/sqrt(k)
    scale_us = (1.0 / rate_rps) / shape * 1e6  # mean = k·θ = 1/rate
    arrivals: List[float] = []
    t = 0.0
    horizon_us = duration_s * 1e6
    while True:
        t += rnd.gammavariate(shape, scale_us)
        if t >= horizon_us:
            break
        arrivals.append(t)
    return _finish(
        arrivals, rnd, tenants, prompt_mean, output_mean, max_prompt, max_output,
        {"process": "bursty", "rate_rps": rate_rps, "duration_s": duration_s,
         "cv": cv, "seed": seed},
        rt_fraction=rt_fraction,
    )


def diurnal_trace(
    mean_rate_rps: float,
    duration_s: float,
    seed: int = 0,
    amplitude: float = 0.8,
    period_s: Optional[float] = None,
    tenants: Sequence[str] = (DEFAULT_TENANT,),
    prompt_mean: int = 256,
    output_mean: int = 32,
    max_prompt: int = 2048,
    max_output: int = 256,
    rt_fraction: float = 0.0,
) -> Trace:
    """A scaled-day replay: sinusoidal rate profile
    ``rate(t) = mean·(1 + amplitude·sin(2πt/period))`` realized by thinning a
    Poisson process at the peak rate (so the output is a true inhomogeneous
    Poisson process)."""
    assert 0.0 <= amplitude < 1.0
    rnd = random.Random(seed)
    period_us = (period_s or duration_s) * 1e6
    peak = mean_rate_rps * (1.0 + amplitude)
    arrivals: List[float] = []
    t = 0.0
    horizon_us = duration_s * 1e6
    while True:
        t += rnd.expovariate(peak) * 1e6
        if t >= horizon_us:
            break
        rate = mean_rate_rps * (1.0 + amplitude * math.sin(2 * math.pi * t / period_us))
        if rnd.random() < rate / peak:  # thinning
            arrivals.append(t)
    return _finish(
        arrivals, rnd, tenants, prompt_mean, output_mean, max_prompt, max_output,
        {"process": "diurnal", "mean_rate_rps": mean_rate_rps,
         "duration_s": duration_s, "amplitude": amplitude,
         "period_s": period_s or duration_s, "seed": seed},
        rt_fraction=rt_fraction,
    )


GENERATORS = {
    "poisson": poisson_trace,
    "bursty": bursty_trace,
    "diurnal": diurnal_trace,
}
