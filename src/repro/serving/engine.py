"""Trace-driven serving engine: replay a request trace through the dynamic
simulator and report SLO metrics.

``serve_trace`` is the one-call entry point: it turns each trace request into
a :class:`ServedRequestTask` arrival event, runs the dynamic simulator under
the chosen memory backend and admission controller, and condenses the
per-request lifecycle records into serving metrics:

  * **TTFT** — arrival → end of prefill + first decode step (queueing and
    admission delay included);
  * **TPOT** — decode-phase time per output token;
  * **p99 latency** — arrival → EOS, tail;
  * **goodput** — completed requests/s that met both the TTFT and TPOT SLOs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.cluster.aggregate import RequestStats, peak_concurrent_bytes
from repro.core.hardware import Platform
from repro.core.scheduler import Policy, RoundRobinPolicy
from repro.core.simulator import (
    AdmissionController,
    SimResult,
    TaskArrival,
    simulate,
)
from repro.serving.admission import AlwaysAdmit, MSchedAdmission
from repro.serving.lifecycle import ServedRequestTask
from repro.serving.traces import Request, Trace


@dataclasses.dataclass
class SLOSpec:
    """Latency targets a request must meet to count toward goodput."""

    ttft_us: float = 3_000_000.0  # 3 s to first token
    tpot_us: float = 100_000.0  # 100 ms per output token


@dataclasses.dataclass
class ServeReport:
    backend: str
    capacity_bytes: int
    oversubscription: float  # peak admitted-demand bytes / HBM capacity
    slo: SLOSpec
    offered_rps: float
    n_requests: int
    n_finished: int
    n_rejected: int
    ttft_p50_us: float
    ttft_p99_us: float
    tpot_p50_us: float
    tpot_p99_us: float
    latency_p99_us: float
    # goodput/throughput are per second of *offered-load window* (the trace
    # duration), a denominator shared by every backend replaying the trace
    goodput_per_s: float
    throughput_per_s: float  # finished requests/s, SLO-blind
    faults: int
    migrated_bytes: int
    result: SimResult

    def to_row(self) -> Dict[str, object]:
        # shallow field filter: asdict() would deep-copy the whole SimResult
        # (every RequestRecord and latency list) just to be discarded
        row = {
            f.name: getattr(self, f.name)
            for f in dataclasses.fields(self)
            if f.name not in ("result", "slo")
        }
        row["ttft_slo_us"] = self.slo.ttft_us
        row["tpot_slo_us"] = self.slo.tpot_us
        return row


def build_events(
    trace: Trace,
    page_size: int = 1 << 20,
    bytes_per_weight: float = 1.0,
) -> List[TaskArrival]:
    """One finite task per request; task ids are the (unique) request ids."""
    return [
        TaskArrival(
            req.arrival_us,
            ServedRequestTask(
                req.req_id, req, page_size=page_size,
                bytes_per_weight=bytes_per_weight,
            ),
            meta={"tenant": req.tenant, "prompt": req.prompt_tokens,
                  "output": req.output_tokens, "slo_class": req.slo_class},
        )
        for req in trace
    ]


def representative_requests(trace: Trace, page_size: int = 1 << 20) -> List[ServedRequestTask]:
    """One synthetic program per tenant, used only for offline template
    profiling (the real MSched flow profiles each application once)."""
    seen: Dict[str, Request] = {}
    for req in trace:
        seen.setdefault(req.tenant, req)
    return [
        ServedRequestTask(10_000_000 + i, req, page_size=page_size)
        for i, req in enumerate(seen.values())
    ]


def serve_trace(
    trace: Trace,
    platform: Platform,
    backend: str = "msched",
    capacity_bytes: Optional[int] = None,
    admission: Optional[AdmissionController] = None,
    policy: Optional[Policy] = None,
    page_size: int = 1 << 20,
    predictor_kind: str = "template",
    slo: Optional[SLOSpec] = None,
    sim_us: Optional[float] = None,
    drain_factor: float = 8.0,
    pool: str = "run",
    telemetry=None,
) -> ServeReport:
    """Replay ``trace`` and measure serving quality.

    ``sim_us`` defaults to ``drain_factor`` × the trace duration so admitted
    requests get a chance to drain; requests still unfinished at the horizon
    count against goodput (they missed every SLO). ``pool`` selects the HBM
    residency implementation (``"run"`` default; ``"paged"`` is the per-page
    equivalence reference — long traces are intractable on it).
    """
    slo = slo or SLOSpec()
    events = build_events(trace, page_size=page_size)
    # capture before the run: retirement releases the address spaces
    footprints = {
        ev.program.task_id: ev.program.footprint_bytes() for ev in events
    }
    cap = capacity_bytes or platform.hbm_bytes
    horizon = sim_us or max(1.0, trace.duration_us()) * drain_factor
    res = simulate(
        [],
        platform,
        backend,
        capacity_bytes=cap,
        sim_us=horizon,
        policy=policy or RoundRobinPolicy(),
        predictor_kind=predictor_kind,
        task_events=events,
        admission=admission,
        profile_set=representative_requests(trace, page_size=page_size),
        page_size=page_size,
        prepopulate=False,
        pool=pool,
        telemetry=telemetry,
    )
    # peak concurrent admitted footprint = the oversubscription actually hit
    peak_bytes = peak_concurrent_bytes(footprints, res.requests)
    # metrics are normalized by the *offered-load window* (identical across
    # backends replaying the same trace), not each run's own makespan —
    # otherwise a slow-draining baseline deflates its own denominator; the
    # scoreboard itself comes from the shared cluster aggregation helpers
    window_us = max(trace.duration_us(), 1.0)
    stats = RequestStats.from_records(
        res.requests, slo.ttft_us, slo.tpot_us, window_us
    )
    return ServeReport(
        backend=backend,
        capacity_bytes=cap,
        oversubscription=peak_bytes / cap if cap else 0.0,
        slo=slo,
        offered_rps=trace.offered_rate_rps(),
        n_requests=stats.n_requests,
        n_finished=stats.n_finished,
        n_rejected=stats.n_rejected,
        ttft_p50_us=stats.ttft_p50_us,
        ttft_p99_us=stats.ttft_p99_us,
        tpot_p50_us=stats.tpot_p50_us,
        tpot_p99_us=stats.tpot_p99_us,
        latency_p99_us=stats.latency_p99_us,
        goodput_per_s=stats.goodput_per_s,
        throughput_per_s=stats.throughput_per_s,
        faults=res.faults,
        migrated_bytes=res.migrated_bytes,
        result=res,
    )
