"""Mamba2 / SSD (state-space duality) block — chunked parallel form + decode.

Faithful to arXiv:2405.21060: within-chunk quadratic ("attention-like") term +
across-chunk recurrent state passing, which is the SSD algorithm. Single
group (B/C shared across heads), depthwise causal conv over (x, B, C).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init, rms_norm


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim
    return d_inner, n_heads, conv_dim


def init_ssm(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, n_heads, conv_dim = dims(cfg)
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * s.state_dim + n_heads  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d, proj_out), dt),
        "conv_w": dense_init(ks[1], (s.conv_width, conv_dim), dt, fan_in=s.conv_width),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "gate_norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_inner, d), dt),
    }


def _split_proj(proj, cfg: ModelConfig):
    s = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : d_inner + d_inner + 2 * s.state_dim]
    dt = proj[..., -n_heads:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv along S. xbc: (B, S, C); w: (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):  # width is tiny (4); unrolled taps
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i]
    return out + b


def ssm_apply(p, x, cfg: ModelConfig):
    out, _ = _ssm_core(p, x, cfg)
    return out


def ssm_apply_with_state(p, x, cfg: ModelConfig):
    """Like ``ssm_apply`` but also returns the decode-continuation cache
    {'state': (B,H,hd,ns) f32, 'conv': (B,width-1,conv_dim)}."""
    return _ssm_core(p, x, cfg)


def _ssm_core(p, x, cfg: ModelConfig):
    """Full-sequence SSD. x: (B, S, D) -> (B, S, D). S % chunk == 0.

    Scans over chunks (carrying the inter-chunk state) so only one chunk's
    quadratic (Q × Q × heads) decay tensor is live at a time — the fully
    vectorized form would materialize (B, S/Q, Q, Q, H) and blow past
    per-device HBM at the assigned train_4k scale.
    """
    s_cfg = cfg.ssm
    d_inner, n_heads, _ = dims(cfg)
    hd, ns, q = s_cfg.head_dim, s_cfg.state_dim, s_cfg.chunk
    b, s_orig, _ = x.shape
    # pad S to a chunk multiple; padded positions get dt == 0 so they neither
    # decay nor update the carried state (prefill cache stays exact)
    pad = (-s_orig) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    s = s_orig + pad
    nc = s // q

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_raw, dt = _split_proj(proj, cfg)
    conv_tail = xbc_raw[:, : s_orig, :][:, -(s_cfg.conv_width - 1) :, :]
    xbc = jax.nn.silu(_causal_conv(xbc_raw, p["conv_w"], p["conv_b"]))
    xs = xbc[..., :d_inner]
    Bm = xbc[..., d_inner : d_inner + ns].astype(jnp.float32)
    Cm = xbc[..., d_inner + ns :].astype(jnp.float32)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    if pad:
        valid = (jnp.arange(s) < s_orig)[None, :, None]
        dt = jnp.where(valid, dt, 0.0)
    A = -jnp.exp(p["A_log"])  # (H,)
    dA = dt * A  # (B,S,H)

    xh = xs.reshape(b, s, n_heads, hd).astype(jnp.float32)
    # chunked views, chunk-major for the scan
    dAc = dA.reshape(b, nc, q, n_heads).transpose(1, 0, 2, 3)
    dtc = dt.reshape(b, nc, q, n_heads).transpose(1, 0, 2, 3)
    xc = xh.reshape(b, nc, q, n_heads, hd).transpose(1, 0, 2, 3, 4)
    Bc = Bm.reshape(b, nc, q, ns).transpose(1, 0, 2, 3)
    Cc = Cm.reshape(b, nc, q, ns).transpose(1, 0, 2, 3)

    tri = jnp.tril(jnp.ones((q, q), bool))

    def chunk_step(state, inp):
        dac, dtk, xk, bk, ck = inp  # per-chunk slices (B, Q, ...)
        cum = jnp.cumsum(dac, axis=1)  # (B,Q,H) inclusive
        # intra-chunk: M[i,j] = exp(cum_i - cum_j) for i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Qi,Qj,H)
        decay = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        cb = jnp.einsum("bis,bjs->bij", ck, bk)  # (B,Qi,Qj)
        m = cb[..., None] * decay  # (B,Qi,Qj,H)
        y_intra = jnp.einsum("bijh,bjh,bjhp->bihp", m, dtk, xk)
        # inter-chunk: contribution of the entering state
        y_inter = jnp.einsum("bis,bih,bhps->bihp", ck, jnp.exp(cum), state)
        # state update to chunk exit
        seg = jnp.exp(cum[:, -1:, :] - cum)  # decay from j to chunk end
        st_new = jnp.einsum("bjs,bjh,bjh,bjhp->bhps", bk, dtk, seg, xk)
        state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + st_new
        return state, y_intra + y_inter

    init = jnp.zeros((b, n_heads, hd, ns), jnp.float32)
    final_state, ys = jax.lax.scan(chunk_step, init, (dAc, dtc, xc, Bc, Cc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, n_heads, hd)

    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    if pad:
        out = out[:, :s_orig, :]
    return out, {"state": final_state, "conv": conv_tail}


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    return {
        "state": jnp.zeros((batch, n_heads, s.head_dim, s.state_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, conv_dim), dtype),
    }


def ssm_decode(p, x, cache, cfg: ModelConfig):
    """Single-token recurrence. x: (B, 1, D)."""
    s_cfg = cfg.ssm
    d_inner, n_heads, conv_dim = dims(cfg)
    hd, ns = s_cfg.head_dim, s_cfg.state_dim
    b = x.shape[0]

    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt = _split_proj(proj, cfg)  # xbc: (B,1,conv_dim)
    conv_hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    new_conv = conv_hist[:, 1:, :]
    conv_out = jnp.einsum("bwc,wc->bc", conv_hist, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(conv_out)[:, None, :]

    xs = xbc[..., :d_inner]
    Bm = xbc[..., d_inner : d_inner + ns].astype(jnp.float32)[:, 0]  # (B,ns)
    Cm = xbc[..., d_inner + ns :].astype(jnp.float32)[:, 0]

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["A_log"])
    da = jnp.exp(dt * A)  # (B,H)

    xh = xs.reshape(b, n_heads, hd).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bs->bhps", dt, xh, Bm)
    state = cache["state"] * da[:, :, None, None] + upd
    y = jnp.einsum("bs,bhps->bhp", Cm, state) + p["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"state": state, "conv": new_conv}
