"""Model assembly: init / forward / prefill / decode_step / loss per family.

All families share the same outer contract so the launcher, dry-run, MSched
workload generators, and tests are arch-agnostic:

  init(rng)                         -> params
  forward(params, batch)            -> logits (B, S, V)       [train shapes]
  loss(params, batch)               -> (scalar, aux)
  prefill(params, batch)            -> (last_logits, cache)
  decode_step(params, cache, batch) -> (logits, cache)        [one token]
  init_cache(batch, max_seq)        -> cache pytree

Layer stacks run under ``jax.lax.scan`` (+ optional remat) so that the HLO is
layer-count-independent: essential for compiling 80 dry-run cells on CPU.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, layers, rglru, ssm
from repro.sharding.act import constrain
from repro.models.common import rms_norm
from repro.models.layers import (
    attention_apply,
    attention_decode,
    init_attention,
    init_mlp,
    init_moe,
    init_norm,
    mlp_apply,
    moe_apply,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ModelFns:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


# --------------------------------------------------------------------------
# Vocab / embedding heads
# --------------------------------------------------------------------------


def _init_head(key, cfg: ModelConfig):
    dt = common.dtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embed": common.embed_init(k1, (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = common.dense_init(k2, (cfg.d_model, cfg.vocab_size), dt)
    p["final_norm"] = init_norm(cfg)
    return p


def _logits(p, x, cfg: ModelConfig):
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, p["embed"])
    else:
        out = jnp.einsum("bsd,dv->bsv", x, p["lm_head"])
    return constrain(out, "dp", None, "tp")


def cross_entropy(logits, labels, ignore: int = -1):
    """Token-mean CE; labels == ignore are masked.

    Written to stay vocab-shard-friendly under GSPMD: the label pick uses an
    iota-compare-select reduction (fuses, shards over V with a small
    all-reduce) instead of take_along_axis, whose gather would all-gather the
    (B, S, V) logits across the model axis.
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, len(logits.shape) - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    nll = lse - ll
    mask = (labels != ignore).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# --------------------------------------------------------------------------
# Uniform attention transformer (dense / moe / vlm / audio)
# --------------------------------------------------------------------------


def _init_attn_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p = {
        "attn_norm": init_norm(cfg),
        "attn": init_attention(k1, cfg),
        "mlp_norm": init_norm(cfg),
    }
    if cfg.moe is not None:
        p["moe"] = init_moe(k2, cfg)
    else:
        p["mlp"] = init_mlp(k2, cfg)
    return p


def _mix_mlp(lp, x, cfg: ModelConfig):
    h = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
    if cfg.moe is not None:
        out, router_logits = moe_apply(lp["moe"], h, cfg)
        aux = _load_balance_loss(router_logits, cfg)
        return x + out, aux
    return x + mlp_apply(lp["mlp"], h), jnp.float32(0.0)


def _load_balance_loss(router_logits, cfg: ModelConfig):
    m = cfg.moe
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.num_experts, dtype=jnp.float32), axis=0)
    mean_p = jnp.mean(probs, axis=0)
    return m.num_experts * jnp.sum(frac * mean_p)


def _remat(fn):
    """Full layer remat (save only the scan carry; recompute everything).

    Note for dry-run memory numbers: the CPU backend has no native bf16, so
    XLA hoists a whole-stack bf16->f32 convert of the saved carries out of
    the backward loop — an f32 copy of the residual stack that would NOT
    exist on TPU. memory_analysis() therefore overstates training temps by
    ~2x the carry stack; see EXPERIMENTS.md §Dry-run methodology.
    """
    return jax.checkpoint(fn, prevent_cse=False)


def _transformer_fns(cfg: ModelConfig) -> ModelFns:
    L = cfg.num_layers
    hd = cfg.resolved_head_dim()

    def init(rng):
        kh, kl = jax.random.split(rng)
        lkeys = jax.random.split(kl, L)
        return {
            "head": _init_head(kh, cfg),
            "layers": jax.vmap(lambda k: _init_attn_layer(k, cfg))(lkeys),
        }

    def _embed_inputs(params, batch):
        """Returns (x, positions, positions3)."""
        if cfg.family == "audio":
            # frontend stub: precomputed frame embeddings (per assignment)
            x = batch["frames"].astype(common.dtype_of(cfg))
            if "frame_mask" in batch:
                mask_emb = params["head"]["embed"][0]  # id 0 = mask embedding
                x = jnp.where(batch["frame_mask"][..., None], mask_emb, x)
            b, s = x.shape[:2]
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
            return x, positions, None
        tokens = batch["tokens"]
        x = params["head"]["embed"][tokens]
        b = tokens.shape[0]
        if cfg.family == "vlm":
            # frontend stub: precomputed patch embeddings (per assignment)
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
            positions3 = batch["positions3"]  # (3, B, S_total)
            positions = positions3[0]
            return x, positions, positions3
        s = tokens.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        return x, positions, None

    def _run_layers(params, x, positions, positions3, collect_kv: bool):
        # sequence-parallel TP: the residual stream lives seq-sharded over the
        # model axis between blocks; GSPMD turns the per-block collective pair
        # from (all-reduce fwd + all-reduce bwd) into (all-gather + reduce-
        # scatter), halving collective volume (see EXPERIMENTS.md §Perf).
        moe = cfg.moe is not None
        seq_spec = ("dp", "tp", None)
        x = constrain(x, *seq_spec)

        def layer_fn(carry, lp):
            # bare bf16 carry for dense models: a tuple carry makes XLA save
            # an extra f32 copy of the residual stream per layer (measured
            # +14 GB/device at train_4k scale)
            x, aux = carry if moe else (carry, None)
            x = constrain(x, *seq_spec)
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            attn_out, kv = attention_apply(
                lp["attn"], h, cfg, positions=positions, positions3=positions3
            )
            x = x + attn_out
            x, aux_l = _mix_mlp(lp, x, cfg)
            ys = kv if collect_kv else None
            return ((x, aux + aux_l) if moe else x), ys

        f = _remat(layer_fn) if cfg.remat else layer_fn
        init = (x, jnp.float32(0.0)) if moe else x
        out, kvs = jax.lax.scan(f, init, params["layers"])
        x, aux = out if moe else (out, jnp.float32(0.0))
        return x, aux / L, kvs

    def forward(params, batch):
        x, positions, positions3 = _embed_inputs(params, batch)
        x, _, _ = _run_layers(params, x, positions, positions3, collect_kv=False)
        return _logits(params["head"], x, cfg)

    def loss(params, batch):
        x, positions, positions3 = _embed_inputs(params, batch)
        x, aux, _ = _run_layers(params, x, positions, positions3, collect_kv=False)
        if cfg.family == "vlm":
            # only text positions carry labels; vision prefix is unsupervised
            s_vis = batch["vision_embeds"].shape[1]
            x = x[:, s_vis:, :]
        logits = _logits(params["head"], x, cfg)
        labels = batch["labels"]
        if cfg.family == "audio" and "frame_mask" in batch:
            labels = jnp.where(batch["frame_mask"], labels, -1)
        ce = cross_entropy(logits, labels)
        total = ce + 0.01 * aux
        return total, {"ce": ce, "aux": aux}

    def init_cache(batch_size: int, max_seq: int):
        dt = common.dtype_of(cfg)
        shape = (L, batch_size, max_seq, cfg.num_kv_heads, hd)
        return {
            "k": jnp.zeros(shape, dt),
            "v": jnp.zeros(shape, dt),
            "index": jnp.zeros((), jnp.int32),
        }

    def prefill(params, batch, max_seq: Optional[int] = None):
        x, positions, positions3 = _embed_inputs(params, batch)
        x, _, kvs = _run_layers(params, x, positions, positions3, collect_kv=True)
        logits = _logits(params["head"], x[:, -1:, :], cfg)
        k, v = kvs
        s = x.shape[1]
        if max_seq is not None and max_seq > s:
            pad = [(0, 0), (0, 0), (0, max_seq - s), (0, 0), (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache = {"k": k, "v": v, "index": jnp.asarray(s, jnp.int32)}
        return logits, cache

    def decode_step(params, cache, batch):
        tokens = batch["tokens"]  # (B, 1)
        x = params["head"]["embed"][tokens]
        b = tokens.shape[0]
        index = cache["index"]
        positions = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
        positions3 = None
        if cfg.family == "vlm":
            positions3 = jnp.broadcast_to(index[None, None, None], (3, b, 1)).astype(
                jnp.int32
            )

        def layer_fn(x, inp):
            lp, kc, vc = inp
            x = constrain(x, "dp", None, None)
            h = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
            attn_out, (kc, vc) = attention_decode(
                lp["attn"],
                h,
                cfg,
                k_cache=kc,
                v_cache=vc,
                index=index,
                positions=positions,
                positions3=positions3,
            )
            x = x + attn_out
            x, _ = _mix_mlp(lp, x, cfg)
            return x, (kc, vc)

        x, (ks, vs) = jax.lax.scan(
            layer_fn, x, (params["layers"], cache["k"], cache["v"])
        )
        logits = _logits(params["head"], x, cfg)
        return logits, {"k": ks, "v": vs, "index": index + 1}

    return ModelFns(cfg, init, forward, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# SSM (mamba2)
# --------------------------------------------------------------------------


def _ssm_fns(cfg: ModelConfig) -> ModelFns:
    L = cfg.num_layers

    def _init_layer(key, _cfg=cfg):
        return {"norm": init_norm(_cfg), "mixer": ssm.init_ssm(key, _cfg)}

    def init(rng):
        kh, kl = jax.random.split(rng)
        lkeys = jax.random.split(kl, L)
        return {
            "head": _init_head(kh, cfg),
            "layers": jax.vmap(_init_layer)(lkeys),
        }

    def _run(params, x):
        x = constrain(x, "dp", None, None)

        def layer_fn(x, lp):
            x = constrain(x, "dp", None, None)
            h = rms_norm(x, lp["norm"], cfg.norm_eps)
            x = x + ssm.ssm_apply(lp["mixer"], h, cfg)
            return x, None

        f = _remat(layer_fn) if cfg.remat else layer_fn
        x, _ = jax.lax.scan(f, x, params["layers"])
        return x

    def forward(params, batch):
        x = params["head"]["embed"][batch["tokens"]]
        x = _run(params, x)
        return _logits(params["head"], x, cfg)

    def loss(params, batch):
        logits = forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.float32(0.0)}

    def init_cache(batch_size: int, max_seq: int):
        dt = common.dtype_of(cfg)
        one = ssm.init_ssm_cache(cfg, batch_size, dt)
        return {
            "state": jnp.zeros((L,) + one["state"].shape, one["state"].dtype),
            "conv": jnp.zeros((L,) + one["conv"].shape, one["conv"].dtype),
            "index": jnp.zeros((), jnp.int32),
        }

    def prefill(params, batch, max_seq: Optional[int] = None):
        # Constant-size decode state: max_seq is irrelevant (O(1) cache).
        tokens = batch["tokens"]
        b, s = tokens.shape
        x = params["head"]["embed"][tokens]

        def layer_fn(x, lp):
            h = rms_norm(x, lp["norm"], cfg.norm_eps)
            out, st = ssm.ssm_apply_with_state(lp["mixer"], h, cfg)
            return x + out, st

        x, states = jax.lax.scan(layer_fn, x, params["layers"])
        logits = _logits(params["head"], x[:, -1:, :], cfg)
        cache = {
            "state": states["state"],
            "conv": states["conv"],
            "index": jnp.asarray(s, jnp.int32),
        }
        return logits, cache

    def decode_step(params, cache, batch):
        x = params["head"]["embed"][batch["tokens"]]

        def layer_fn(x, inp):
            lp, st, cv = inp
            x = constrain(x, "dp", None, None)
            h = rms_norm(x, lp["norm"], cfg.norm_eps)
            out, new = ssm.ssm_decode(lp["mixer"], h, {"state": st, "conv": cv}, cfg)
            return x + out, (new["state"], new["conv"])

        x, (sts, cvs) = jax.lax.scan(
            layer_fn, x, (params["layers"], cache["state"], cache["conv"])
        )
        logits = _logits(params["head"], x, cfg)
        return logits, {"state": sts, "conv": cvs, "index": cache["index"] + 1}

    return ModelFns(cfg, init, forward, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Hybrid (recurrentgemma): (rec, rec, attn) pattern blocks
# --------------------------------------------------------------------------


def _hybrid_counts(cfg: ModelConfig):
    kinds = cfg.layer_kinds()
    n_rec = sum(1 for k in kinds if k == "rec")
    n_attn = sum(1 for k in kinds if k == "attn")
    pat = cfg.rglru.pattern
    n_groups = cfg.num_layers // len(pat)
    n_rem = cfg.num_layers - n_groups * len(pat)
    return kinds, n_rec, n_attn, n_groups, n_rem


def _hybrid_fns(cfg: ModelConfig) -> ModelFns:
    kinds, n_rec, n_attn, n_groups, n_rem = _hybrid_counts(cfg)
    assert cfg.rglru.pattern == ("rec", "rec", "attn")
    # trailing remainder layers are recurrent blocks (pattern truncation)
    window = cfg.rglru.window
    hd = cfg.resolved_head_dim()

    def _init_rec(key, _cfg=cfg):
        k1, k2 = jax.random.split(key)
        return {
            "norm": init_norm(_cfg),
            "rec": rglru.init_rec_block(k1, _cfg),
            "mlp_norm": init_norm(_cfg),
            "mlp": init_mlp(k2, _cfg),
        }

    def _init_attn(key, _cfg=cfg):
        k1, k2 = jax.random.split(key)
        return {
            "norm": init_norm(_cfg),
            "attn": init_attention(k1, _cfg),
            "mlp_norm": init_norm(_cfg),
            "mlp": init_mlp(k2, _cfg),
        }

    def init(rng):
        kh, kr, ka = jax.random.split(rng, 3)
        return {
            "head": _init_head(kh, cfg),
            "rec_layers": jax.vmap(_init_rec)(jax.random.split(kr, n_rec)),
            "attn_layers": jax.vmap(_init_attn)(jax.random.split(ka, n_attn)),
        }

    def _split_groups(params):
        """rec stack -> (groups of 2, remainder); attn stack used per group."""
        rec = params["rec_layers"]
        grouped = jax.tree.map(
            lambda a: a[: 2 * n_groups].reshape((n_groups, 2) + a.shape[1:]), rec
        )
        rem = jax.tree.map(lambda a: a[2 * n_groups :], rec)
        return grouped, rem

    def _rec_apply(lp, x, h0=None, conv0=None, decode=False, positions=None):
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        if decode:
            out, h_new, conv_new = rglru.rec_block_decode(
                lp["rec"], h, h0, cfg, conv0
            )
        else:
            out, h_new = rglru.rec_block_apply(lp["rec"], h, cfg, h0)
            conv_new = None
        x = x + out
        m = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], m)
        return x, h_new, conv_new

    def _attn_apply_full(lp, x, positions, collect_kv=False):
        h = rms_norm(x, lp["norm"], cfg.norm_eps)
        out, kv = attention_apply(
            lp["attn"], h, cfg, positions=positions, window=window
        )
        x = x + out
        m = rms_norm(x, lp["mlp_norm"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], m)
        return x, kv

    def _run_full(params, x, positions, collect: bool):
        grouped, rem = _split_groups(params)
        x = constrain(x, "dp", None, None)

        def group_fn(x, inp):
            x = constrain(x, "dp", None, None)
            recs, attn = inp
            r0 = jax.tree.map(lambda a: a[0], recs)
            r1 = jax.tree.map(lambda a: a[1], recs)
            x, h0, _ = _rec_apply(r0, x)
            x, h1, _ = _rec_apply(r1, x)
            x, kv = _attn_apply_full(attn, x, positions)
            ys = (jnp.stack([h0, h1]), kv) if collect else None
            return x, ys

        f = _remat(group_fn) if cfg.remat else group_fn
        x, ys = jax.lax.scan(f, x, (grouped, params["attn_layers"]))
        rem_states = []
        for i in range(n_rem):
            lp = jax.tree.map(lambda a: a[i], rem)
            x, h, _ = _rec_apply(lp, x)
            rem_states.append(h)
        return x, ys, rem_states

    def forward(params, batch):
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = params["head"]["embed"][tokens]
        x, _, _ = _run_full(params, x, positions, collect=False)
        return _logits(params["head"], x, cfg)

    def loss(params, batch):
        logits = forward(params, batch)
        ce = cross_entropy(logits, batch["labels"])
        return ce, {"ce": ce, "aux": jnp.float32(0.0)}

    def init_cache(batch_size: int, max_seq: int):
        dt = common.dtype_of(cfg)
        w = min(window, max_seq)
        cw = cfg.rglru.conv_width
        return {
            "h": jnp.zeros((n_rec, batch_size, cfg.d_model), jnp.float32),
            "conv": jnp.zeros((n_rec, batch_size, cw - 1, cfg.d_model), dt),
            "k": jnp.zeros((n_attn, batch_size, w, cfg.num_kv_heads, hd), dt),
            "v": jnp.zeros((n_attn, batch_size, w, cfg.num_kv_heads, hd), dt),
            "index": jnp.zeros((), jnp.int32),
        }

    def prefill(params, batch, max_seq: Optional[int] = None):
        # Bounded decode state: ring buffer of `window` slots (max_seq only
        # matters when the prefill is shorter than the window).
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x = params["head"]["embed"][tokens]
        grouped, rem = _split_groups(params)
        w = min(window, s)

        def seed_ring(arr):  # (B, S, Hkv, hd) -> ring-ordered last w slots
            tail = arr[:, -w:]
            return jnp.roll(tail, (s - w) % w, axis=1)

        def group_fn(x, inp):
            recs, attn = inp
            r0 = jax.tree.map(lambda a: a[0], recs)
            r1 = jax.tree.map(lambda a: a[1], recs)
            # also collect conv tails for decode continuation
            h_in0 = rms_norm(x, r0["norm"], cfg.norm_eps)
            conv0 = rglru.conv_tail(r0["rec"], h_in0)
            x, h0, _ = _rec_apply(r0, x)
            h_in1 = rms_norm(x, r1["norm"], cfg.norm_eps)
            conv1 = rglru.conv_tail(r1["rec"], h_in1)
            x, h1, _ = _rec_apply(r1, x)
            x, (k, v) = _attn_apply_full(attn, x, positions)
            ys = (
                jnp.stack([h0, h1]),
                jnp.stack([conv0, conv1]),
                seed_ring(k),
                seed_ring(v),
            )
            return x, ys

        x, (hs, convs, ks, vs) = jax.lax.scan(
            group_fn, x, (grouped, params["attn_layers"])
        )
        rem_h, rem_conv = [], []
        for i in range(n_rem):
            lp = jax.tree.map(lambda a: a[i], rem)
            h_in = rms_norm(x, lp["norm"], cfg.norm_eps)
            rem_conv.append(rglru.conv_tail(lp["rec"], h_in))
            x, h, _ = _rec_apply(lp, x)
            rem_h.append(h)
        h_parts = [hs.reshape((-1,) + hs.shape[2:])]
        conv_parts = [convs.reshape((-1,) + convs.shape[2:])]
        if n_rem:
            h_parts.append(jnp.stack(rem_h))
            conv_parts.append(jnp.stack(rem_conv))
        h_all = jnp.concatenate(h_parts)
        conv_all = jnp.concatenate(conv_parts)
        logits = _logits(params["head"], x[:, -1:, :], cfg)
        cache = {
            "h": h_all,
            "conv": conv_all,
            "k": ks,
            "v": vs,
            "index": jnp.asarray(s, jnp.int32),
        }
        return logits, cache

    def decode_step(params, cache, batch):
        x = params["head"]["embed"][batch["tokens"]]
        b = x.shape[0]
        index = cache["index"]
        positions = jnp.broadcast_to(index[None, None], (b, 1)).astype(jnp.int32)
        grouped, rem = _split_groups(params)
        h_g = cache["h"][: 2 * n_groups].reshape(
            (n_groups, 2) + cache["h"].shape[1:]
        )
        conv_g = cache["conv"][: 2 * n_groups].reshape(
            (n_groups, 2) + cache["conv"].shape[1:]
        )

        def group_fn(x, inp):
            recs, attn, hs, cvs, kc, vc = inp
            r0 = jax.tree.map(lambda a: a[0], recs)
            r1 = jax.tree.map(lambda a: a[1], recs)
            x, h0, c0 = _rec_apply(r0, x, hs[0], cvs[0], decode=True)
            x, h1, c1 = _rec_apply(r1, x, hs[1], cvs[1], decode=True)
            h = rms_norm(x, attn["norm"], cfg.norm_eps)
            out, (kc, vc) = attention_decode(
                attn["attn"],
                h,
                cfg,
                k_cache=kc,
                v_cache=vc,
                index=index,
                positions=positions,
                window=window,
                ring=True,
            )
            x = x + out
            m = rms_norm(x, attn["mlp_norm"], cfg.norm_eps)
            x = x + mlp_apply(attn["mlp"], m)
            return x, (jnp.stack([h0, h1]), jnp.stack([c0, c1]), kc, vc)

        x, (hs, cvs, ks, vs) = jax.lax.scan(
            group_fn,
            x,
            (grouped, params["attn_layers"], h_g, conv_g, cache["k"], cache["v"]),
        )
        new_h = [hs.reshape((-1,) + hs.shape[2:])]
        new_conv = [cvs.reshape((-1,) + cvs.shape[2:])]
        for i in range(n_rem):
            lp = jax.tree.map(lambda a: a[i], rem)
            x, h, c = _rec_apply(
                lp, x, cache["h"][2 * n_groups + i], cache["conv"][2 * n_groups + i],
                decode=True,
            )
            new_h.append(h[None])
            new_conv.append(c[None])
        logits = _logits(params["head"], x, cfg)
        cache = {
            "h": jnp.concatenate(new_h),
            "conv": jnp.concatenate(new_conv),
            "k": ks,
            "v": vs,
            "index": index + 1,
        }
        return logits, cache

    return ModelFns(cfg, init, forward, loss, prefill, decode_step, init_cache)


# --------------------------------------------------------------------------
# Dispatch
# --------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def build_model(cfg: ModelConfig) -> ModelFns:
    if cfg.family == "ssm":
        return _ssm_fns(cfg)
    if cfg.family == "hybrid":
        return _hybrid_fns(cfg)
    return _transformer_fns(cfg)
