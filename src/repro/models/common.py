"""Shared model building blocks (pure-functional, pytree params)."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

PyTree = object


def dtype_of(cfg: ModelConfig):
    # int8 configs (paper llama.cpp workload) still compute in bf16; int8 is
    # the storage dtype handled by the quantized kernels / workload model.
    return jnp.bfloat16 if cfg.dtype in ("bfloat16", "int8") else jnp.dtype(cfg.dtype)


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------


def dense_init(key, shape, dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# Normalization
# --------------------------------------------------------------------------


def rms_norm(x, weight, eps: float):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + weight.astype(jnp.float32))).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# --------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, D); positions3: (3, B, S) — (temporal, height, width) position
    ids; sections: per-axis rotary section sizes (sum == D // 2).
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)  # (half,)
    # build per-frequency angles by selecting the positional axis per section
    angle_parts = []
    start = 0
    for axis, sec in enumerate(sections):
        f = freqs[start : start + sec]
        pos = positions3[axis]  # (B, S)
        angle_parts.append(pos[..., None].astype(jnp.float32) * f)
        start += sec
    angles = jnp.concatenate(angle_parts, axis=-1)  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# Attention core (exact, memory-bounded via query-block scan)
# --------------------------------------------------------------------------


def gqa_scores_einsum(q, k):
    """q: (B, S, H, D), k: (B, T, Hkv, D) -> scores (B, H, S, T) for GQA."""
    b, s, h, d = q.shape
    hkv = k.shape[2]
    groups = h // hkv
    qg = q.reshape(b, s, hkv, groups, d)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k)
    return scores.reshape(b, h, s, k.shape[1])


def gqa_values_einsum(probs, v):
    """probs: (B, H, S, T), v: (B, T, Hkv, D) -> (B, S, H, D)."""
    b, h, s, t = probs.shape
    hkv = v.shape[2]
    groups = h // hkv
    pg = probs.reshape(b, hkv, groups, s, t)
    out = jnp.einsum("bkgst,btkd->bskgd", pg, v)
    return out.reshape(b, s, h, out.shape[-1])


def masked_softmax(scores, mask):
    scores = scores.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    scores = jnp.where(mask, scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    e = jnp.where(mask, e, 0.0)
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)


def attend(
    q,
    k,
    v,
    *,
    q_positions,
    kv_positions,
    causal: bool,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_block: int = 1024,
):
    """Exact attention; scans over query blocks when S_q is large so the
    (B, H, Sq, Skv) score tensor never materializes in full.

    q: (B, Sq, H, D); k/v: (B, Skv, Hkv, D)
    q_positions: (B, Sq) int32; kv_positions: (B, Skv) int32 (−1 = invalid slot)
    """
    b, sq, h, d = q.shape
    scale = scale if scale is not None else 1.0 / np.sqrt(d)

    def block(qb, qpos_b):
        scores = gqa_scores_einsum(qb * scale, k)  # (B, H, sb, Skv)
        valid = (kv_positions >= 0)[:, None, None, :]
        if causal:
            mask = qpos_b[:, None, :, None] >= kv_positions[:, None, None, :]
        else:
            mask = jnp.ones(
                (b, 1, qb.shape[1], kv_positions.shape[1]), dtype=bool
            )
        if window is not None:
            near = (
                qpos_b[:, None, :, None] - kv_positions[:, None, None, :]
            ) < window
            mask = jnp.logical_and(mask, near)
        mask = jnp.logical_and(mask, valid)
        probs = masked_softmax(scores, mask).astype(v.dtype)
        return gqa_values_einsum(probs, v)

    if sq <= q_block:
        return block(q, q_positions)

    assert sq % q_block == 0, (sq, q_block)
    nb = sq // q_block
    qs = q.reshape(b, nb, q_block, h, d).transpose(1, 0, 2, 3, 4)
    ps = q_positions.reshape(b, nb, q_block).transpose(1, 0, 2)

    def body(_, xs):
        qb, pb = xs
        return None, block(qb, pb)

    _, outs = jax.lax.scan(body, None, (qs, ps))
    # outs: (nb, B, q_block, H, D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
