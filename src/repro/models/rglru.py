"""RecurrentGemma / Griffin recurrent block: conv + RG-LRU gated recurrence.

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)            (recurrence gate)
    i_t = sigmoid(W_x x_t)            (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form uses ``jax.lax.associative_scan`` over (a, b) pairs for
parallel-in-time execution.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import dense_init

RGLRU_C = 8.0


def init_rec_block(key, cfg: ModelConfig):
    d = cfg.d_model
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "w_in": dense_init(ks[0], (d, d), dt),  # recurrent branch input proj
        "w_gate_branch": dense_init(ks[1], (d, d), dt),  # multiplicative branch
        "conv_w": dense_init(ks[2], (cfg.rglru.conv_width, d), dt, fan_in=4),
        "conv_b": jnp.zeros((d,), dt),
        "w_a": dense_init(ks[3], (d, d), dt),
        "w_x": dense_init(ks[4], (d, d), dt),
        "lam": jnp.full((d,), 0.65, jnp.float32),  # Lambda (softplus-domain)
        "w_out": dense_init(ks[5], (d, d), dt),
    }


def _gates(p, xb):
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xb, p["w_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xb, p["w_x"]).astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(p["lam"]) * r  # (B,S,D) float32
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (
        i * xb.astype(jnp.float32)
    )
    return a, gated_in


def rglru_scan(a, b, h0=None):
    """h_t = a_t h_{t-1} + b_t via associative scan. a/b: (B, S, D) f32."""
    if h0 is not None:
        # fold the initial state into the first step
        b = b.at[:, 0, :].add(a[:, 0, :] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rec_block_apply(p, x, cfg: ModelConfig, h0=None):
    """x: (B, S, D) -> (out, h_last)."""
    xr = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xg = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate_branch"]))
    # causal depthwise conv on the recurrent branch
    width = p["conv_w"].shape[0]
    pad = jnp.pad(xr, ((0, 0), (width - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + xr.shape[1], :] * p["conv_w"][i] for i in range(width)
    )
    xb = conv + p["conv_b"]
    a, gated_in = _gates(p, xb)
    h = rglru_scan(a, gated_in, h0)
    out = (h.astype(x.dtype) * xg).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["w_out"])
    return out, h[:, -1, :]


def conv_tail(p, x):
    """Last (width-1) pre-conv recurrent-branch inputs, for decode carry-over.

    x: the *normed* block input (B, S, D).
    """
    width = p["conv_w"].shape[0]
    xr = jnp.einsum("bsd,de->bse", x, p["w_in"])
    return xr[:, -(width - 1) :, :]


def rec_block_decode(p, x, h_prev, cfg: ModelConfig, conv_state=None):
    """One-token step. x: (B, 1, D); h_prev: (B, D) f32.

    conv_state: (B, width-1, D) trailing conv inputs (or None for width-1
    zeros, e.g. at sequence start).
    """
    xr = jnp.einsum("bsd,de->bse", x, p["w_in"])
    xg = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate_branch"]))
    width = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], width - 1, xr.shape[-1]), xr.dtype)
    hist = jnp.concatenate([conv_state, xr], axis=1)  # (B, width, D)
    new_conv = hist[:, 1:, :]
    xb = (jnp.einsum("bwd,wd->bd", hist, p["conv_w"]) + p["conv_b"])[:, None, :]
    a, gated_in = _gates(p, xb)
    h = a[:, 0] * h_prev + gated_in[:, 0]  # (B, D)
    out = (h[:, None, :].astype(x.dtype) * xg).astype(x.dtype)
    out = jnp.einsum("bse,ed->bsd", out, p["w_out"])
    return out, h, new_conv
