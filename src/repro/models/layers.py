"""Attention / MLP / MoE layers: init + apply (pure functions over pytrees).

Layer params are plain dicts so they can be stacked with a leading layer axis
and driven by ``jax.lax.scan`` (keeps HLO small — critical for the 80-cell
CPU dry-run compiles).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import common
from repro.models.common import apply_mrope, apply_rope, attend, dense_init, rms_norm
from repro.sharding.act import axis_size, constrain

# --------------------------------------------------------------------------
# Attention layer
# --------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig):
    d = cfg.d_model
    hd = cfg.resolved_head_dim()
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * hd), dt),
        "wk": dense_init(ks[1], (d, hkv * hd), dt),
        "wv": dense_init(ks[2], (d, hkv * hd), dt),
        "wo": dense_init(ks[3], (h * hd, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(p, x, cfg: ModelConfig, positions, positions3):
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim()
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.num_heads, hd)
    k = k.reshape(b, s, cfg.num_kv_heads, hd)
    v = v.reshape(b, s, cfg.num_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.mrope_sections is not None:
        assert positions3 is not None
        q = apply_mrope(q, positions3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.causal:  # encoder (hubert) backbone: no rope on bidirectional attn
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_apply(
    p,
    x,
    cfg: ModelConfig,
    *,
    positions,
    positions3=None,
    window: Optional[int] = None,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, x, cfg, positions, positions3)
    out = attend(
        q,
        k,
        v,
        q_positions=positions,
        kv_positions=positions,
        causal=cfg.causal,
        window=window,
    )
    b, s, _, _ = out.shape
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, s, -1), p["wo"])
    return out, (k, v)


def attention_decode(
    p,
    x,
    cfg: ModelConfig,
    *,
    k_cache,
    v_cache,
    index,
    positions,
    positions3=None,
    window: Optional[int] = None,
    ring: bool = False,
):
    """One-token decode against a KV cache.

    k_cache/v_cache: (B, Smax, Hkv, Dh); index: scalar int32 (current position)
    positions: (B, 1) current absolute position. With ``ring=True`` the cache
    is a ring buffer of size Smax (sliding-window layers).
    """
    q, k, v = _project_qkv(p, x, cfg, positions, positions3)
    smax = k_cache.shape[1]
    slot = (index % smax) if ring else index
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, slot, 0, 0))
    b = x.shape[0]
    iota = jnp.arange(smax, dtype=jnp.int32)[None, :]
    if ring:
        # absolute position stored in slot j: largest p <= index with p % smax == j
        kv_pos = index - ((index - iota) % smax)
        kv_pos = jnp.where(kv_pos < 0, -1, kv_pos)
    else:
        kv_pos = jnp.where(iota <= index, iota, -1)
    kv_pos = jnp.broadcast_to(kv_pos, (b, smax)).astype(jnp.int32)
    out = attend(
        q,
        k_cache,
        v_cache,
        q_positions=positions,
        kv_positions=kv_pos,
        causal=True,
        window=window,
    )
    out = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, -1), p["wo"])
    return out, (k_cache, v_cache)


# --------------------------------------------------------------------------
# Dense (gated) MLP
# --------------------------------------------------------------------------


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 3)
    return {
        "w1": dense_init(ks[0], (d, f), dt),
        "w3": dense_init(ks[1], (d, f), dt),
        "w2": dense_init(ks[2], (f, d), dt),
    }


def mlp_apply(p, x):
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"]))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w3"])
    return jnp.einsum("bsf,fd->bsd", h, p["w2"])


# --------------------------------------------------------------------------
# Mixture of Experts (capacity-based, scatter/gather dispatch)
# --------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    assert cfg.moe is not None
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    dt = common.dtype_of(cfg)
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, m.num_experts), jnp.float32),
        "w1": dense_init(ks[1], (m.num_experts, d, m.d_ff), dt),
        "w3": dense_init(ks[2], (m.num_experts, d, m.d_ff), dt),
        "w2": dense_init(ks[3], (m.num_experts, m.d_ff, d), dt),
    }
    if m.dense_residual:
        p["dense"] = init_mlp(ks[4], cfg, d_ff=m.dense_d_ff)
    return p


def moe_capacity(m: MoEConfig, n_tokens: int) -> int:
    c = int(math.ceil(m.top_k * n_tokens / m.num_experts * m.capacity_factor))
    return max(c, m.top_k)


def moe_apply(p, x, cfg: ModelConfig):
    """Capacity-based top-k MoE.

    Dispatch/combine use scatter/gather (token sort into expert slots) rather
    than dense one-hot einsums: the (tokens × experts × capacity) einsum would
    dominate compiled FLOPs by >100× and destroy the roofline useful-compute
    ratio (see DESIGN.md).
    """
    m = cfg.moe
    b, s, d = x.shape
    n = b * s
    tokens = x.reshape(n, d)
    router_logits = jnp.einsum(
        "nd,de->ne", tokens.astype(jnp.float32), p["router"]
    )
    probs = jax.nn.softmax(router_logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)  # (n, k)
    gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)

    cap = moe_capacity(m, n)
    # choice-major flattening: (k*n,) assignments
    flat_e = idx.T.reshape(-1)
    flat_g = gates.T.reshape(-1)
    oh = jax.nn.one_hot(flat_e, m.num_experts, dtype=jnp.int32)  # (kn, E)
    pos_in_e = jnp.cumsum(oh, axis=0) - oh
    posn = jnp.sum(pos_in_e * oh, axis=-1)  # (kn,)
    keep = posn < cap
    slot = flat_e * cap + jnp.where(keep, posn, 0)  # (kn,)

    token_rep = jnp.tile(tokens, (m.top_k, 1))  # (kn, d)
    token_rep = constrain(token_rep, "dp", None)
    buf = jnp.zeros((m.num_experts * cap, d), tokens.dtype)
    buf = buf.at[slot].add(
        jnp.where(keep[:, None], token_rep, 0), mode="drop"
    )
    # expert-shard the dispatch buffer (EP mode only, E % |model| == 0):
    # without this GSPMD replicates it and all-reduces ~2x its global size
    # per layer (94 s collective term on arctic prefill_32k, §Perf It. 4).
    # In per-expert-TP mode (grok: 8 experts on a 16-way axis) the flat
    # constraint mis-shards across expert boundaries and inflates compiled
    # FLOPs 8.8x — leave GSPMD free there.
    tp_n = axis_size("tp")
    ep_mode = tp_n > 0 and m.num_experts % tp_n == 0
    if ep_mode:
        buf = constrain(buf, "tp", None)
    expert_in = buf.reshape(m.num_experts, cap, d)
    if ep_mode:
        expert_in = constrain(expert_in, "tp", None, None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, p["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, p["w3"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w2"])  # (E, C, d)
    if ep_mode:
        expert_out = constrain(expert_out, "tp", None, None)

    out_rep = expert_out.reshape(m.num_experts * cap, d)[slot]
    out_rep = constrain(out_rep, "dp", None)
    out_rep = jnp.where(keep[:, None], out_rep, 0) * flat_g[:, None].astype(
        out_rep.dtype
    )
    out = jnp.sum(out_rep.reshape(m.top_k, n, d), axis=0)

    if m.dense_residual:
        out = out + mlp_apply(p["dense"], x).reshape(n, d)
    return out.reshape(b, s, d), router_logits


# --------------------------------------------------------------------------
# Norm params
# --------------------------------------------------------------------------


def init_norm(cfg: ModelConfig):
    return jnp.zeros((cfg.d_model,), jnp.float32)
