"""Workload generators mirroring the paper's evaluation applications.

Each generator emits the task's GPU command stream with ground-truth touched
extents and deterministic latencies (paper §6: kernel latencies are stable).
The generators reproduce the memory-behavior archetypes the paper measures:

  * vecadd / matmul            — §7.1 microbenchmarks (streaming vs compute)
  * Rodinia-like (dwt2d, hotspot, cfd, nn) — SciComp combo A; `nn` includes a
    small indirect-gather region (the <1% "Others" of Table 2 → the only
    source of template false negatives)
  * DNN inference/training     — PyTorch-style: one pooled allocation sliced
    per layer (the aggregated-allocation pathology of §5.1)
  * LLM decode                 — llama.cpp-style: monolithic weight buffer +
    per-layer slices + KV cache allocated at max context but touched only up
    to the current sequence length (sparse-access pathology of §5.1).
    LLM streams are derived from the real model configs in repro.configs.

Latencies are derived from a simple device model (memory-bound: bytes / HBM
bandwidth; compute-bound: flops / peak), calibrated so an int8 Llama3-8B
decode step touches ~8.5 GB in ~12.7 ms as in paper Fig. 2.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.commands import KERNEL, Command, kernel
from repro.core.pages import AddressSpace, Buffer, Extent

# device compute model (RTX-5080-class), used only for latency synthesis
GPU_HBM_GBPS = 900.0
GPU_PEAK_TFLOPS = 80.0


def _mem_us(nbytes: float, efficiency: float = 0.75) -> float:
    return nbytes / (GPU_HBM_GBPS * efficiency * 1e3)


def _flop_us(flops: float, efficiency: float = 0.5) -> float:
    return flops / (GPU_PEAK_TFLOPS * efficiency * 1e6)


class TaskProgram:
    """A task's repeating command stream (one iteration = one completion).

    ``total_iterations`` is ``None`` for the classic long-running combos; a
    finite value makes the task *retire* after that many completed iterations
    (the dynamic-lifecycle serving regime), at which point the simulator calls
    :meth:`release` and reclaims the task's HBM pages.
    """

    name: str = "task"
    total_iterations: Optional[int] = None

    def __init__(self, task_id: int, page_size: int = 4096):
        self.task_id = task_id
        self.space = AddressSpace(page_size=page_size, base=(task_id + 1) << 44)

    def iteration(self, it: int) -> List[Command]:
        raise NotImplementedError

    def footprint_bytes(self) -> int:
        return sum(b.size for b in self.space.buffers.values())

    def release(self):
        """Task exit: tear down the address space; returns its page span."""
        return self.space.release()


def footprint_pages(prog: TaskProgram, page_size: int) -> int:
    """Whole-footprint page count — the conservative demand bound admission
    and placement use for tasks that have no predictor helper yet."""
    return sum(
        (b.size + page_size - 1) // page_size
        for b in prog.space.buffers.values()
    )


# --------------------------------------------------------------------------
# §7.1 microbenchmarks
# --------------------------------------------------------------------------


class VecAddTask(TaskProgram):
    """Streams 3N bytes per kernel — large working set, zero reuse."""

    name = "vecadd"

    def __init__(self, task_id: int, n_bytes: int, kernels_per_iter: int = 4, **kw):
        super().__init__(task_id, **kw)
        self.n = n_bytes
        self.k = kernels_per_iter
        self.bufs = [
            [
                self.space.malloc(n_bytes, f"vec{j}_{w}")
                for w in ("a", "b", "c")
            ]
            for j in range(kernels_per_iter)
        ]

    def iteration(self, it):
        cmds = []
        for a, b, c in self.bufs:
            n_elems = self.n // 4
            ext = [(a.base, self.n), (b.base, self.n), (c.base, self.n)]
            cmds.append(
                kernel(
                    "vector_add",
                    (a.base, b.base, c.base, n_elems, n_elems // 256, 256),
                    _mem_us(3 * self.n),
                    ext,
                )
            )
        return cmds


class MatMulTask(TaskProgram):
    """Compute-bound GEMMs over a set of weight matrices."""

    name = "matmul"

    def __init__(self, task_id: int, dim: int, n_matrices: int = 8, **kw):
        super().__init__(task_id, **kw)
        self.dim = dim
        self.sz = dim * dim * 2  # fp16
        self.a = self.space.malloc(self.sz, "act_a")
        self.c = self.space.malloc(self.sz, "act_c")
        self.ws = [self.space.malloc(self.sz, f"w{i}") for i in range(n_matrices)]

    def iteration(self, it):
        cmds = []
        d = self.dim
        for w in self.ws:
            ext = [(self.a.base, self.sz), (w.base, self.sz), (self.c.base, self.sz)]
            cmds.append(
                kernel(
                    "matmul",
                    (self.a.base, w.base, self.c.base, d, d, d),
                    _flop_us(2.0 * d * d * d),
                    ext,
                )
            )
        return cmds


# --------------------------------------------------------------------------
# Rodinia-like SciComp (combo A)
# --------------------------------------------------------------------------


class Dwt2dTask(TaskProgram):
    """2-D DWT: per level a strided access over image rows (T3)."""

    name = "dwt2d"

    def __init__(self, task_id: int, side: int = 8192, levels: int = 3, **kw):
        super().__init__(task_id, **kw)
        self.side = side
        self.levels = levels
        self.img = self.space.malloc(side * side * 4, "image")
        self.out = self.space.malloc(side * side * 4, "coeffs")

    def iteration(self, it):
        cmds = []
        for lvl in range(self.levels):
            rows = self.side >> lvl
            row_bytes = (self.side >> lvl) * 4
            stride = self.side * 4
            ext = [
                (self.img.base + r * stride, row_bytes) for r in range(rows)
            ] + [(self.out.base, rows * row_bytes)]
            cmds.append(
                kernel(
                    "dwt2d_level",
                    (self.img.base, self.out.base, rows, row_bytes, stride),
                    _mem_us(2 * rows * row_bytes),
                    ext,
                )
            )
        return cmds


class HotspotTask(TaskProgram):
    name = "hotspot"

    def __init__(self, task_id: int, cells: int = 64 << 20, steps: int = 4, **kw):
        super().__init__(task_id, **kw)
        self.steps = steps
        self.sz = cells * 4
        self.temp = self.space.malloc(self.sz, "temp")
        self.power = self.space.malloc(self.sz, "power")
        self.out = self.space.malloc(self.sz, "temp_out")

    def iteration(self, it):
        cmds = []
        for _ in range(self.steps):
            ext = [
                (self.temp.base, self.sz),
                (self.power.base, self.sz),
                (self.out.base, self.sz),
            ]
            cmds.append(
                kernel(
                    "hotspot_step",
                    (self.temp.base, self.power.base, self.out.base, self.sz // 4),
                    _mem_us(3 * self.sz),
                    ext,
                )
            )
        return cmds


class CfdTask(TaskProgram):
    name = "cfd"

    def __init__(self, task_id: int, elems: int = 24 << 20, **kw):
        super().__init__(task_id, **kw)
        self.sz = elems * 4
        self.arrays = [self.space.malloc(self.sz, f"cfd{i}") for i in range(5)]

    def iteration(self, it):
        cmds = []
        for phase in range(3):
            ext = [(a.base, self.sz) for a in self.arrays]
            cmds.append(
                kernel(
                    "cfd_flux",
                    tuple(a.base for a in self.arrays) + (self.sz // 4, phase),
                    _mem_us(5 * self.sz),
                    ext,
                )
            )
        return cmds


class NnTask(TaskProgram):
    """Nearest-neighbor search with a small *indirect* candidate gather —
    the pointer-chasing residue the templates cannot cover (Table 1's 0.92%
    Rodinia false negatives)."""

    name = "nn"

    def __init__(self, task_id: int, records: int = 48 << 20, **kw):
        super().__init__(task_id, **kw)
        self.sz = records
        self.db = self.space.malloc(records, "records")
        self.out = self.space.malloc(4 << 20, "results")
        # candidate table reached via pointers stored *in* the records
        # (pointer-chasing): its base is never passed as a kernel argument
        self.cand = self.space.malloc(16 << 20, "candidates")

    def iteration(self, it):
        ext = [(self.db.base, self.sz), (self.out.base, self.out.size)]
        # indirect gather: a data-dependent window not derivable from args
        win = 512 << 10
        widx = (it * 2654435761) % (self.cand.size - win)
        ext.append((self.cand.base + widx, win))
        return [
            kernel(
                "nn_search",
                (self.db.base, self.out.base, self.sz, 64),
                _mem_us(self.sz),
                ext,
            )
        ]


# --------------------------------------------------------------------------
# DNN inference / training (PyTorch-style pooled allocations)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class _DNNSpec:
    name: str
    layer_mbytes: Sequence[float]  # per-layer weight sizes
    act_mbytes: float


DNN_SPECS = {
    # crude per-layer weight profiles (MB) — shapes only need to be *plausible*
    "resnet152": _DNNSpec("resnet152", [1.0] * 40 + [4.0] * 60 + [9.0] * 16, 256.0),
    "vgg19": _DNNSpec("vgg19", [2.0] * 8 + [9.0] * 8 + [392.0, 64.0, 16.0], 320.0),
    "inceptionv3": _DNNSpec("inceptionv3", [0.5] * 60 + [3.0] * 30 + [8.0] * 5, 192.0),
    "densenet201": _DNNSpec("densenet201", [0.3] * 120 + [2.0] * 60, 288.0),
}


class DNNInferTask(TaskProgram):
    """One pooled weight allocation sliced per layer (aggregated allocation)."""

    name = "dnn_infer"

    def __init__(self, task_id: int, model: str = "resnet152", batch: int = 8, **kw):
        super().__init__(task_id, **kw)
        spec = DNN_SPECS[model]
        self.name = f"{model}_infer"
        self.model = model
        self.batch = batch
        self.layer_sizes = [int(m * (1 << 20)) for m in spec.layer_mbytes]
        self.wpool = self.space.malloc(sum(self.layer_sizes), "weight_pool")
        self.apool = self.space.malloc(int(spec.act_mbytes * (1 << 20)), "act_pool")
        # per-layer slice offsets inside the pool
        self.offsets = []
        off = 0
        for sz in self.layer_sizes:
            self.offsets.append(off)
            off += sz

    def iteration(self, it):
        cmds = []
        act_half = self.apool.size // 2
        for li, (off, sz) in enumerate(zip(self.offsets, self.layer_sizes)):
            w_ptr = self.wpool.base + off
            x_ptr = self.apool.base + (li % 2) * act_half
            y_ptr = self.apool.base + ((li + 1) % 2) * act_half
            act_bytes = act_half * self.batch // 8  # scales with batch
            act_bytes = min(act_bytes, act_half)
            ext = [(w_ptr, sz), (x_ptr, act_bytes), (y_ptr, act_bytes)]
            flops = 2.0 * sz / 2 * self.batch * 24  # conv reuse factor
            cmds.append(
                kernel(
                    f"{self.model}_conv{li}",
                    (x_ptr, w_ptr, y_ptr, self.batch, sz, act_bytes),
                    max(_flop_us(flops), _mem_us(sz + 2 * act_bytes)),
                    ext,
                )
            )
        return cmds


class DNNTrainTask(DNNInferTask):
    """Forward + backward + optimizer step: weights touched twice, plus
    gradient and optimizer-state pools (intermittent command launching)."""

    name = "dnn_train"

    def __init__(self, task_id: int, model: str = "resnet152", batch: int = 8, **kw):
        super().__init__(task_id, model, batch, **kw)
        self.name = f"{model}_train"
        self.gpool = self.space.malloc(self.wpool.size, "grad_pool")
        self.opool = self.space.malloc(2 * self.wpool.size, "adam_pool")

    def iteration(self, it):
        fwd = super().iteration(it)
        bwd = []
        act_half = self.apool.size // 2
        for li in reversed(range(len(self.layer_sizes))):
            off, sz = self.offsets[li], self.layer_sizes[li]
            ext = [
                (self.wpool.base + off, sz),
                (self.gpool.base + off, sz),
                (self.apool.base, act_half),
            ]
            bwd.append(
                kernel(
                    f"{self.model}_bwd{li}",
                    (
                        self.apool.base,
                        self.wpool.base + off,
                        self.gpool.base + off,
                        self.batch,
                        sz,
                    ),
                    max(_flop_us(2 * sz * self.batch * 24), _mem_us(2 * sz + act_half)),
                    ext,
                )
            )
        opt = kernel(
            f"{self.model}_adam",
            (self.wpool.base, self.gpool.base, self.opool.base, self.wpool.size),
            _mem_us(self.wpool.size * 4),
            [
                (self.wpool.base, self.wpool.size),
                (self.gpool.base, self.gpool.size),
                (self.opool.base, self.opool.size),
            ],
        )
        return fwd + bwd + [opt]


# --------------------------------------------------------------------------
# LLM decode (llama.cpp-style) — derived from the real model configs
# --------------------------------------------------------------------------


class LLMDecodeTask(TaskProgram):
    """Autoregressive decode of a configs-defined LM.

    Weight layout mirrors llama.cpp: ONE monolithic buffer for all weights,
    sliced per layer/matrix. KV caches are allocated at ``max_context`` but
    the attention kernel touches only ``seq_len(t)`` tokens — the two §5.1
    pathologies. Per-step byte volume ≈ whole model (Fig. 2).
    """

    name = "llm_decode"

    def __init__(
        self,
        task_id: int,
        arch: str = "paper-llama3-8b",
        max_context: int = 4096,
        start_len: int = 256,
        bytes_per_weight: float = 1.0,  # int8
        **kw,
    ):
        super().__init__(task_id, **kw)
        self.cfg: ModelConfig = get_config(arch)
        self.name = f"llm_{arch}"
        self.max_context = max_context
        self.start_len = start_len
        c = self.cfg
        hd = c.resolved_head_dim()
        self.wq = int(c.d_model * c.num_heads * hd * bytes_per_weight)
        self.wkv = int(c.d_model * c.num_kv_heads * hd * bytes_per_weight)
        self.wo = self.wq
        self.wffn = int(c.d_model * c.d_ff * bytes_per_weight)
        per_layer = self.wq + 2 * self.wkv + self.wo + 3 * self.wffn
        embed = int(c.vocab_size * c.d_model * bytes_per_weight)
        self.layer_bytes = per_layer
        self.embed_bytes = embed
        self.wpool = self.space.malloc(
            per_layer * c.num_layers + 2 * embed, "weights"
        )
        self.apool = self.space.malloc(256 << 20, "activations")
        self.kv_token_bytes = 2 * c.num_kv_heads * hd * 2  # k+v, fp16
        self.kv = [
            self.space.malloc(self.kv_token_bytes * max_context, f"kv{l}")
            for l in range(c.num_layers)
        ]
        # precompute per-layer static command templates (args tuples, extents
        # lists, latencies); only the attention command varies with seq_len.
        # Extents lists are shared across iterations — commands never mutate
        # them, and the run-decode memo keys on their content.
        act = (self.apool.base, 8 << 20)
        self._act = act
        qkvo_sz = self.wq + 2 * self.wkv + self.wo
        self._layers = []
        for li in range(c.num_layers):
            base = self.wpool.base + li * self.layer_bytes
            ffn_base = base + qkvo_sz
            # int8 dequant scales: one scale block per quant group — a
            # strided read over the ffn weights (T3, llama.cpp-style)
            n_blocks = 64
            blk_stride = (3 * self.wffn) // n_blocks
            scale_sz = 4 << 10
            self._layers.append(
                (
                    # llm_qkvo
                    (
                        (act[0], base, qkvo_sz, c.d_model, li),
                        _mem_us(qkvo_sz),
                        [(base, qkvo_sz), act],
                    ),
                    # llm_attn statics
                    self.kv[li].base,
                    # llm_dequant_scales
                    (
                        (ffn_base, n_blocks, scale_sz, blk_stride),
                        _mem_us(n_blocks * scale_sz),
                        [(ffn_base + i * blk_stride, scale_sz) for i in range(n_blocks)],
                    ),
                    # llm_ffn
                    (
                        (act[0], ffn_base, 3 * self.wffn, c.d_ff, li),
                        _mem_us(3 * self.wffn),
                        [(ffn_base, 3 * self.wffn), act],
                    ),
                )
            )
        head_base = self.wpool.base + c.num_layers * self.layer_bytes
        self._head = (
            (act[0], head_base, 2 * self.embed_bytes, c.vocab_size),
            _mem_us(2 * self.embed_bytes),
            [(head_base, 2 * self.embed_bytes), act],
        )

    def seq_len(self, it: int) -> int:
        return min(self.start_len + it, self.max_context)

    def iteration(self, it):
        s = self.seq_len(it)
        act = self._act
        kv_bytes = s * self.kv_token_bytes
        attn_lat = _mem_us(kv_bytes)
        cmds: List[Command] = []
        for li, (qkvo, kv_base, scales, ffn) in enumerate(self._layers):
            cmds.append(Command(KERNEL, "llm_qkvo", qkvo[0], qkvo[1], qkvo[2]))
            cmds.append(
                Command(
                    KERNEL,
                    "llm_attn",
                    (kv_base, act[0], s, self.kv_token_bytes, li),
                    attn_lat,
                    [(kv_base, kv_bytes), act],
                )
            )
            cmds.append(
                Command(KERNEL, "llm_dequant_scales", scales[0], scales[1], scales[2])
            )
            cmds.append(Command(KERNEL, "llm_ffn", ffn[0], ffn[1], ffn[2]))
        cmds.append(
            Command(KERNEL, "llm_head", self._head[0], self._head[1], self._head[2])
        )
        return cmds


# --------------------------------------------------------------------------
# Paper task combinations (Table 3)
# --------------------------------------------------------------------------


def combo(
    name: str, page_size: int, scale: float = 1.0
) -> List[TaskProgram]:
    """Builds the paper's combos A–D. ``scale`` stretches footprints to hit a
    target oversubscription ratio (the paper scales problem/batch sizes)."""
    mk = lambda cls, tid, **kw: cls(tid, page_size=page_size, **kw)
    s = scale
    if name == "A":  # SciComp
        return [
            mk(Dwt2dTask, 0, side=int(8192 * s**0.5)),
            mk(HotspotTask, 1, cells=int((64 << 20) * s)),
            mk(CfdTask, 2, elems=int((24 << 20) * s)),
            mk(NnTask, 3, records=int((48 << 20) * s)),
        ]
    if name == "B":  # MultiDNN
        return [
            mk(DNNInferTask, 0, model="resnet152", batch=int(8 * s)),
            mk(DNNInferTask, 1, model="vgg19", batch=int(8 * s)),
            mk(DNNInferTask, 2, model="inceptionv3", batch=int(8 * s)),
            mk(DNNInferTask, 3, model="densenet201", batch=int(8 * s)),
        ]
    if name == "C":  # HybridDL
        return combo("B", page_size, s) + [
            mk(LLMDecodeTask, 4, arch="paper-llama3-8b")
        ]
    if name == "D":  # MultiLLM
        n = max(2, int(round(2 * s)))
        return [
            mk(LLMDecodeTask, i, arch="paper-llama3-8b") for i in range(n)
        ]
    raise KeyError(name)
