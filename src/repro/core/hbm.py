"""HBM residency pool with an explicit eviction list.

This is the library form of the paper's modified kernel-mode driver state:
an LRU-ordered eviction list over resident pages, with two new operations —

  madvise(pages)  — move pages to the list *tail*, protecting them (the new
                    ioctl MSched adds to the KMD, §6.2);
  migrate(pages)  — evict from the list *head* until there is room, then
                    populate the given pages (the new migrate engine).

Under demand paging, faults evict from the head (standard driver behavior).
Page keys are global integers (task address spaces are disjoint).

Two implementations share one interface:

``HBMPool`` (default) is *run-native*: residency is a doubly-linked chain of
page-run segments (contiguous in page space AND adjacent in list order, with
intra-segment order ascending) plus a sorted start-index for point/range
lookups. Every driver op — ``madvise_runs``/``migrate_runs``/``touch_runs``/
``populate_runs``/``drop_runs``/``free_task`` — costs O(segments touched +
log n) instead of O(pages), which is what lets 4 KiB simulation pages and
GiB-scale working sets stream through the simulator. The per-page semantics
are preserved exactly: visiting a run's pages in ascending order and moving
each to the OrderedDict tail yields the same list as splicing the run's
resident fragments to the chain tail in ascending order, so the eviction
order (and therefore every downstream SimResult) is bit-for-bit identical.

``HBMPoolPaged`` is the original per-page ``OrderedDict`` implementation,
selectable with ``simulate(..., pool="paged")`` and kept as the equivalence
reference for the randomized op-sequence suite.
"""
from __future__ import annotations

from bisect import bisect_left, bisect_right
from collections import OrderedDict
from typing import Dict, Iterable, Iterator, List, Sequence, Set, Tuple

from repro.core.pages import PageRun, pages_to_runs


class _Seg:
    """One eviction-list segment: a half-open page run whose pages occupy
    consecutive list positions in ascending page order."""

    __slots__ = ("start", "stop", "prev", "nxt")

    def __init__(self, start: int, stop: int):
        self.start = start
        self.stop = stop
        self.prev: "_Seg | None" = None
        self.nxt: "_Seg | None" = None


class HBMPool:
    """Run-native eviction list (sorted disjoint segments + LRU chain)."""

    RUN_NATIVE = True

    def __init__(self, capacity_pages: int):
        assert capacity_pages > 0
        self.capacity = capacity_pages
        # LRU chain sentinels: head.nxt = next eviction victim segment
        self._h = _Seg(-1, -1)
        self._t = _Seg(-1, -1)
        self._h.nxt = self._t
        self._t.prev = self._h
        # sorted-by-start index over live segments (disjoint -> unique starts)
        self._starts: List[int] = []
        self._segs: List[_Seg] = []
        self._count = 0
        # task_id -> page span, registered so free_task() can find a retired
        # task's resident pages without scanning the whole list
        self._task_spans: Dict[int, PageRun] = {}
        # counters
        self.evictions = 0
        self.populations = 0
        self.freed_pages = 0

    # -- queries -------------------------------------------------------------
    def resident(self, page: int) -> bool:
        i = bisect_right(self._starts, page) - 1
        return i >= 0 and page < self._segs[i].stop

    def resident_count(self) -> int:
        return self._count

    @property
    def used(self) -> int:
        """Resident page count (alias of :meth:`resident_count`)."""
        return self._count

    def free_pages(self) -> int:
        return self.capacity - self._count

    def eviction_order(self) -> List[int]:
        """Full page list in eviction order. O(pages) — tests/debug only;
        hot paths use :meth:`eviction_runs` / :meth:`iter_eviction`."""
        return [p for s, e in self.eviction_runs() for p in range(s, e)]

    def eviction_runs(self) -> List[PageRun]:
        """Eviction order as segments (head first), without expansion."""
        out: List[PageRun] = []
        seg = self._h.nxt
        while seg is not self._t:
            out.append((seg.start, seg.stop))
            seg = seg.nxt
        return out

    def iter_eviction(self) -> Iterator[int]:
        """Lazy page iterator in eviction order (no list materialization)."""
        seg = self._h.nxt
        while seg is not self._t:
            yield from range(seg.start, seg.stop)
            seg = seg.nxt

    def resident_stretch_end(self, page: int) -> int:
        """Stop of the contiguous resident stretch containing ``page``
        (``page`` itself must be resident)."""
        i = bisect_right(self._starts, page) - 1
        return self._segs[i].stop

    # -- chain/index plumbing ------------------------------------------------
    def _index_remove(self, seg: _Seg) -> None:
        i = bisect_left(self._starts, seg.start)
        del self._starts[i]
        del self._segs[i]

    def _index_insert(self, seg: _Seg) -> None:
        i = bisect_left(self._starts, seg.start)
        self._starts.insert(i, seg.start)
        self._segs.insert(i, seg)

    @staticmethod
    def _unlink(seg: _Seg) -> None:
        seg.prev.nxt = seg.nxt
        seg.nxt.prev = seg.prev

    @staticmethod
    def _link_after(seg: _Seg, after: _Seg) -> None:
        seg.prev = after
        seg.nxt = after.nxt
        after.nxt.prev = seg
        after.nxt = seg

    def _append_tail(self, start: int, stop: int) -> None:
        """Place run ``[start, stop)`` at the chain tail (most-recent end),
        merging with the tail segment when it continues it ascending."""
        last = self._t.prev
        if last is not self._h and last.stop == start:
            last.stop = stop  # index start unchanged; no gap can exist inside
            return
        seg = _Seg(start, stop)
        self._link_after(seg, last)
        self._index_insert(seg)

    def _extract(self, a: int, b: int) -> List[PageRun]:
        """Detach the resident sub-runs of ``[a, b)`` from the chain (keeping
        any non-overlapping remainders at their list positions) and return
        them in ascending page order."""
        starts, segs = self._starts, self._segs
        i = bisect_right(starts, a) - 1
        if i < 0 or segs[i].stop <= a:
            i += 1
        out: List[PageRun] = []
        while i < len(starts) and starts[i] < b:
            seg = segs[i]
            lo = seg.start if seg.start > a else a
            hi = seg.stop if seg.stop < b else b
            out.append((lo, hi))
            if seg.start < lo and hi < seg.stop:
                # middle extraction: left keeps seg, right is a new segment
                right = _Seg(hi, seg.stop)
                seg.stop = lo
                self._link_after(right, seg)
                self._index_insert(right)
                i += 2
            elif seg.start < lo:
                seg.stop = lo
                i += 1
            elif hi < seg.stop:
                seg.start = hi
                starts[i] = hi
                i += 1
            else:
                self._unlink(seg)
                del starts[i]
                del segs[i]
        return out

    # -- driver ops ----------------------------------------------------------
    def touch(self, page: int) -> None:
        """LRU update on access (demand-paging behavior)."""
        i = bisect_right(self._starts, page) - 1
        if i < 0 or page >= self._segs[i].stop:
            return
        seg = self._segs[i]
        if seg.nxt is self._t and seg.stop == page + 1:
            return  # already the most-recent page
        for lo, hi in self._extract(page, page + 1):
            self._append_tail(lo, hi)

    def touch_runs(self, runs: Iterable[PageRun]) -> None:
        """LRU-update every *resident* page of ``runs``, in run order (the
        run-level form of per-page ``touch`` over a command's access order)."""
        for a, b in runs:
            for lo, hi in self._extract(a, b):
                self._append_tail(lo, hi)

    def madvise(self, pages: Iterable[int]) -> int:
        """Move resident pages to the tail (protect). Returns #moved."""
        n = 0
        for p in pages:
            if self.resident(p):
                self.touch(p)
                n += 1
        return n

    def madvise_runs(self, runs: Iterable[PageRun]) -> int:
        """``madvise`` over half-open page runs. Visits resident fragments in
        ascending order within each run — the same final list order as the
        per-page walk — at O(fragments) cost. Returns #pages moved."""
        n = 0
        for a, b in runs:
            for lo, hi in self._extract(a, b):
                self._append_tail(lo, hi)
                n += hi - lo
        return n

    def demote_runs(self, runs: Iterable[PageRun]) -> int:
        """Move the resident pages of ``runs`` (which must be disjoint) to
        the eviction-list *head* — the next victims. The inverse of
        ``madvise``: demoted pages are scavengeable, reclaimed before any
        protected page the moment the pool needs room. Pages end up at the
        head in ascending run order (the same order the per-page reference —
        ``move_to_front`` in reverse page order — produces). The cluster
        layer demotes a migrated-away task's lingering working set so a peer
        can prefetch it over NVLink while the local GPU loses nothing.
        Returns #pages moved."""
        frags: List[PageRun] = []
        for a, b in runs:
            frags.extend(self._extract(a, b))
        for lo, hi in reversed(frags):
            seg = _Seg(lo, hi)
            self._link_after(seg, self._h)
            self._index_insert(seg)
        return sum(hi - lo for lo, hi in frags)

    def evict_head(self) -> int:
        seg = self._h.nxt
        if seg is self._t:
            raise KeyError("pool is empty")
        page = seg.start
        if seg.stop - seg.start == 1:
            self._unlink(seg)
            self._index_remove(seg)
        else:
            i = bisect_left(self._starts, page)
            seg.start = page + 1
            self._starts[i] = page + 1
        self.evictions += 1
        self._count -= 1
        return page

    def _evict_head_run(self, n: int) -> List[PageRun]:
        """Evict ``n`` pages from the head as whole segments; returns the
        victim runs in eviction order."""
        out: List[PageRun] = []
        while n > 0:
            seg = self._h.nxt
            if seg is self._t:
                raise KeyError("pool is empty")
            size = seg.stop - seg.start
            if size <= n:
                out.append((seg.start, seg.stop))
                self._unlink(seg)
                self._index_remove(seg)
                self.evictions += size
                self._count -= size
                n -= size
            else:
                out.append((seg.start, seg.start + n))
                i = bisect_left(self._starts, seg.start)
                seg.start += n
                self._starts[i] = seg.start
                self.evictions += n
                self._count -= n
                n = 0
        return out

    def populate(self, page: int) -> List[int]:
        """Make one page resident (at the tail); returns evicted victims."""
        if self.resident(page):
            self.touch(page)
            return []
        victims = []
        while self._count >= self.capacity:
            victims.append(self.evict_head())
        self._append_tail(page, page + 1)
        self._count += 1
        self.populations += 1
        return victims

    def populate_runs(self, runs: Iterable[PageRun]) -> List[PageRun]:
        """Make every page of the (non-resident) ``runs`` resident at the
        tail, evicting from the head for room. Victims are returned as runs
        in eviction order. Closed-form equivalent of per-page ``populate``
        over each run: victims are the first ``max(0, count + L - capacity)``
        pages of the concatenated order [current list, run]; when a run
        exceeds capacity, its own leading pages count as populated *and*
        evicted without ever materializing (exactly what the per-page loop
        does to them)."""
        victims: List[PageRun] = []
        for a, b in runs:
            victims.extend(self._populate_run(a, b))
        return victims

    def _populate_run(self, a: int, b: int) -> List[PageRun]:
        need = self._count + (b - a) - self.capacity
        self.populations += b - a
        victims: List[PageRun] = []
        if need > 0:
            if need > self._count:
                overflow = need - self._count
                victims.extend(self._evict_head_run(self._count))
                # leading run pages: populated then immediately evicted
                victims.append((a, a + overflow))
                self.evictions += overflow
                a += overflow
            else:
                victims.extend(self._evict_head_run(need))
        self._append_tail(a, b)
        self._count += b - a
        return victims

    def migrate(self, pages: Iterable[int]) -> Tuple[List[int], List[int]]:
        """Proactively populate ``pages`` (in order), evicting from the head.

        Returns (populated, evicted) — only pages that actually moved.
        Per-page API preserved for callers holding explicit lists."""
        populated: List[int] = []
        evicted: List[int] = []
        for p in pages:
            if self.resident(p):
                self.touch(p)
                continue
            evicted.extend(self.populate(p))
            populated.append(p)
        return populated, evicted

    def migrate_runs(
        self, runs: Iterable[PageRun]
    ) -> Tuple[List[PageRun], List[PageRun]]:
        """``migrate`` over half-open page runs (first-access order), fully
        run-native: resident stretches are spliced to the tail, missing
        stretches are populated with batched head eviction. Returns
        (populated_runs, evicted_runs) — ``expand_runs`` of each equals the
        page lists the per-page path produces."""
        populated: List[PageRun] = []
        evicted: List[PageRun] = []
        starts, segs = self._starts, self._segs
        for a, b in runs:
            cur = a
            while cur < b:
                i = bisect_right(starts, cur) - 1
                if i >= 0 and cur < segs[i].stop:
                    # resident stretch: protect (splice to tail)
                    hi = min(segs[i].stop, b)
                    for lo, h2 in self._extract(cur, hi):
                        self._append_tail(lo, h2)
                    cur = hi
                else:
                    # missing stretch up to the next resident segment
                    j = bisect_right(starts, cur)
                    hi = min(b, starts[j]) if j < len(starts) else b
                    evicted.extend(self._populate_run(cur, hi))
                    if populated and populated[-1][1] == cur:
                        populated[-1] = (populated[-1][0], hi)
                    else:
                        populated.append((cur, hi))
                    cur = hi
        return populated, evicted

    def all_resident_runs(self, runs: Iterable[PageRun]) -> bool:
        starts, segs = self._starts, self._segs
        for a, b in runs:
            cur = a
            while cur < b:
                i = bisect_right(starts, cur) - 1
                if i < 0 or cur >= segs[i].stop:
                    return False
                cur = segs[i].stop
        return True

    def missing_runs(self, runs: Iterable[PageRun]) -> List[PageRun]:
        """Non-resident sub-runs of ``runs``, in run order — the run-level
        complement query the fault path is built on."""
        out: List[PageRun] = []
        starts, segs = self._starts, self._segs
        for a, b in runs:
            cur = a
            while cur < b:
                i = bisect_right(starts, cur) - 1
                if i >= 0 and cur < segs[i].stop:
                    cur = min(segs[i].stop, b)
                    continue
                j = bisect_right(starts, cur)
                hi = min(b, starts[j]) if j < len(starts) else b
                out.append((cur, hi))
                cur = hi
        return out

    def missing_pages(self, pages: Sequence[int]) -> List[int]:
        """Non-resident subset of ``pages``, in order (compat API)."""
        return [p for p in pages if not self.resident(p)]

    def drop(self, pages: Iterable[int]) -> None:
        """Remove pages without counting an eviction (task exit/free)."""
        for p in pages:
            self._count -= sum(hi - lo for lo, hi in self._discard(p, p + 1))

    def drop_runs(self, runs: Iterable[PageRun]) -> None:
        for a, b in runs:
            self._count -= sum(hi - lo for lo, hi in self._discard(a, b))

    def _discard(self, a: int, b: int) -> List[PageRun]:
        """Remove the resident sub-runs of ``[a, b)`` outright: ``_extract``
        already detaches every overlapping piece from the chain and index, so
        simply not re-appending them deletes them. Returns what was removed."""
        return self._extract(a, b)

    # -- task lifecycle ------------------------------------------------------
    def register_task(self, task_id: int, span: PageRun) -> None:
        """Declare the page span a task's address space occupies, so its
        residual pages can be reclaimed when the task retires."""
        self._task_spans[task_id] = span

    def free_task(self, task_id: int) -> int:
        """Reclaim a retired task's resident pages (process exit: the driver
        frees the whole address space). Freed pages don't count as evictions.
        Returns the number of pages actually reclaimed."""
        span = self._task_spans.pop(task_id, None)
        if span is None:
            return 0
        freed = sum(hi - lo for lo, hi in self._discard(span[0], span[1]))
        self._count -= freed
        self.freed_pages += freed
        return freed

    def wipe(self) -> int:
        """Release every resident page and every task registration at once
        (device failure: HBM contents are gone). Counts as freed pages, not
        evictions. Returns the number of pages released."""
        freed = self._count
        self._h.nxt = self._t
        self._t.prev = self._h
        self._starts.clear()
        self._segs.clear()
        self._count = 0
        self._task_spans.clear()
        self.freed_pages += freed
        return freed


class HBMPoolPaged:
    """Original per-page ``OrderedDict`` pool (the straightforward reference
    implementation). Selectable with ``simulate(..., pool="paged")``; the
    randomized equivalence suite drives it against :class:`HBMPool`."""

    RUN_NATIVE = False

    def __init__(self, capacity_pages: int):
        assert capacity_pages > 0
        self.capacity = capacity_pages
        # insertion order == eviction order; first item = next eviction victim
        self._list: "OrderedDict[int, None]" = OrderedDict()
        self._task_spans: Dict[int, PageRun] = {}
        self.evictions = 0
        self.populations = 0
        self.freed_pages = 0

    # -- queries -------------------------------------------------------------
    def resident(self, page: int) -> bool:
        return page in self._list

    def resident_count(self) -> int:
        return len(self._list)

    @property
    def used(self) -> int:
        return self.resident_count()

    def free_pages(self) -> int:
        return self.capacity - len(self._list)

    def eviction_order(self) -> List[int]:
        return list(self._list.keys())

    def eviction_runs(self) -> List[PageRun]:
        return list(pages_to_runs(self.eviction_order()))

    def iter_eviction(self) -> Iterator[int]:
        return iter(self._list.keys())

    # -- driver ops ----------------------------------------------------------
    def touch(self, page: int) -> None:
        if page in self._list:
            self._list.move_to_end(page)

    def touch_runs(self, runs: Iterable[PageRun]) -> None:
        lst = self._list
        for start, stop in runs:
            for p in range(start, stop):
                if p in lst:
                    lst.move_to_end(p)

    def madvise(self, pages: Iterable[int]) -> int:
        n = 0
        for p in pages:
            if p in self._list:
                self._list.move_to_end(p)
                n += 1
        return n

    def madvise_runs(self, runs: Iterable[PageRun]) -> int:
        n = 0
        lst = self._list
        for start, stop in runs:
            for p in range(start, stop):
                if p in lst:
                    lst.move_to_end(p)
                    n += 1
        return n

    def demote_runs(self, runs: Iterable[PageRun]) -> int:
        """Per-page reference of :meth:`HBMPool.demote_runs`: walking the
        disjoint runs' pages in reverse and moving each to the front leaves
        the demoted pages at the head in ascending run order."""
        n = 0
        lst = self._list
        for start, stop in reversed(list(runs)):
            for p in reversed(range(start, stop)):
                if p in lst:
                    lst.move_to_end(p, last=False)
                    n += 1
        return n

    def evict_head(self) -> int:
        page, _ = self._list.popitem(last=False)
        self.evictions += 1
        return page

    def populate(self, page: int) -> List[int]:
        if page in self._list:
            self._list.move_to_end(page)
            return []
        victims = []
        while len(self._list) >= self.capacity:
            victims.append(self.evict_head())
        self._list[page] = None
        self.populations += 1
        return victims

    def populate_runs(self, runs: Iterable[PageRun]) -> List[PageRun]:
        victims: List[int] = []
        for start, stop in runs:
            for p in range(start, stop):
                victims.extend(self.populate(p))
        return list(pages_to_runs(victims))

    def migrate(self, pages: Iterable[int]) -> Tuple[List[int], List[int]]:
        populated: List[int] = []
        evicted: List[int] = []
        for p in pages:
            if p in self._list:
                self._list.move_to_end(p)
                continue
            evicted.extend(self.populate(p))
            populated.append(p)
        return populated, evicted

    def migrate_runs(
        self, runs: Iterable[PageRun]
    ) -> Tuple[List[PageRun], List[PageRun]]:
        populated, evicted = self.migrate(
            p for start, stop in runs for p in range(start, stop)
        )
        return list(pages_to_runs(populated)), list(pages_to_runs(evicted))

    def all_resident_runs(self, runs: Iterable[PageRun]) -> bool:
        lst = self._list
        return all(p in lst for start, stop in runs for p in range(start, stop))

    def missing_runs(self, runs: Iterable[PageRun]) -> List[PageRun]:
        return list(
            pages_to_runs(
                [
                    p
                    for start, stop in runs
                    for p in range(start, stop)
                    if p not in self._list
                ]
            )
        )

    def missing_pages(self, pages: Sequence[int]) -> List[int]:
        lst = self._list
        return [p for p in pages if p not in lst]

    def drop(self, pages: Iterable[int]) -> None:
        for p in pages:
            self._list.pop(p, None)

    def drop_runs(self, runs: Iterable[PageRun]) -> None:
        for start, stop in runs:
            for p in range(start, stop):
                self._list.pop(p, None)

    # -- task lifecycle ------------------------------------------------------
    def register_task(self, task_id: int, span: PageRun) -> None:
        self._task_spans[task_id] = span

    def free_task(self, task_id: int) -> int:
        span = self._task_spans.pop(task_id, None)
        if span is None:
            return 0
        lst = self._list
        lo, hi = span
        if hi - lo <= len(lst):
            freed = [p for p in range(lo, hi) if p in lst]
        else:
            freed = [p for p in lst if lo <= p < hi]
        for p in freed:
            del lst[p]
        self.freed_pages += len(freed)
        return len(freed)

    def wipe(self) -> int:
        """Release everything at once (device failure); see
        :meth:`HBMPool.wipe`."""
        freed = len(self._list)
        self._list.clear()
        self._task_spans.clear()
        self.freed_pages += freed
        return freed


def resident_runs_in(pool, span: PageRun) -> List[PageRun]:
    """Resident sub-runs of ``span`` in ascending page order, computed as the
    complement of :meth:`missing_runs` so it works on both pool
    implementations without touching their state. Used by the cluster's
    inter-GPU migration path to snapshot a task's live working set."""
    lo, hi = span
    out: List[PageRun] = []
    cur = lo
    for s, e in pool.missing_runs([(lo, hi)]):
        if s > cur:
            out.append((cur, s))
        cur = e
    if cur < hi:
        out.append((cur, hi))
    return out


def make_pool(kind: str, capacity_pages: int):
    """``"run"`` (default run-native) or ``"paged"`` (per-page reference)."""
    if kind == "run":
        return HBMPool(capacity_pages)
    if kind == "paged":
        return HBMPoolPaged(capacity_pages)
    raise ValueError(f"unknown pool kind {kind!r} (use 'run' or 'paged')")
