"""HBM residency pool with an explicit eviction list.

This is the library form of the paper's modified kernel-mode driver state:
an LRU-ordered eviction list over resident pages, with two new operations —

  madvise(pages)  — move pages to the list *tail*, protecting them (the new
                    ioctl MSched adds to the KMD, §6.2);
  migrate(pages)  — evict from the list *head* until there is room, then
                    populate the given pages (the new migrate engine).

Under demand paging, faults evict from the head (standard driver behavior).
Page keys are global integers (task address spaces are disjoint).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, Iterable, List, Sequence, Set, Tuple

from repro.core.pages import PageRun


class HBMPool:
    def __init__(self, capacity_pages: int):
        assert capacity_pages > 0
        self.capacity = capacity_pages
        # insertion order == eviction order; first item = next eviction victim
        self._list: "OrderedDict[int, None]" = OrderedDict()
        # task_id -> page span, registered so free_task() can find a retired
        # task's resident pages without scanning the whole list
        self._task_spans: Dict[int, PageRun] = {}
        # counters
        self.evictions = 0
        self.populations = 0
        self.freed_pages = 0

    # -- queries -------------------------------------------------------------
    def resident(self, page: int) -> bool:
        return page in self._list

    def resident_count(self) -> int:
        return len(self._list)

    @property
    def used(self) -> int:
        """Resident page count (alias of :meth:`resident_count`)."""
        return self.resident_count()

    def free_pages(self) -> int:
        return self.capacity - len(self._list)

    def eviction_order(self) -> List[int]:
        return list(self._list.keys())

    # -- driver ops ----------------------------------------------------------
    def touch(self, page: int) -> None:
        """LRU update on access (demand-paging behavior)."""
        if page in self._list:
            self._list.move_to_end(page)

    def madvise(self, pages: Iterable[int]) -> int:
        """Move resident pages to the tail (protect). Returns #moved."""
        n = 0
        for p in pages:
            if p in self._list:
                self._list.move_to_end(p)
                n += 1
        return n

    def madvise_runs(self, runs: Iterable[PageRun]) -> int:
        """``madvise`` over half-open page runs: visits pages in ascending
        order without materializing a set, so GiB-scale groups stream through.
        ``runs`` must be sorted and disjoint (see ``pages.merge_runs``)."""
        n = 0
        lst = self._list
        for start, stop in runs:
            for p in range(start, stop):
                if p in lst:
                    lst.move_to_end(p)
                    n += 1
        return n

    def evict_head(self) -> int:
        page, _ = self._list.popitem(last=False)
        self.evictions += 1
        return page

    def populate(self, page: int) -> List[int]:
        """Make one page resident (at the tail); returns evicted victims."""
        if page in self._list:
            self._list.move_to_end(page)
            return []
        victims = []
        while len(self._list) >= self.capacity:
            victims.append(self.evict_head())
        self._list[page] = None
        self.populations += 1
        return victims

    def migrate(self, pages: Iterable[int]) -> Tuple[List[int], List[int]]:
        """Proactively populate ``pages`` (in order), evicting from the head.

        Returns (populated, evicted) — only pages that actually moved.
        """
        populated: List[int] = []
        evicted: List[int] = []
        for p in pages:
            if p in self._list:
                self._list.move_to_end(p)
                continue
            evicted.extend(self.populate(p))
            populated.append(p)
        return populated, evicted

    def migrate_runs(
        self, runs: Iterable[PageRun]
    ) -> Tuple[List[int], List[int]]:
        """``migrate`` over half-open page runs (first-access order)."""
        return self.migrate(p for start, stop in runs for p in range(start, stop))

    def all_resident_runs(self, runs: Iterable[PageRun]) -> bool:
        lst = self._list
        return all(p in lst for start, stop in runs for p in range(start, stop))

    def missing_pages(self, pages: Sequence[int]) -> List[int]:
        """Non-resident subset of ``pages``, in order (one call per command
        instead of one residency call per page on the simulator hot path)."""
        lst = self._list
        return [p for p in pages if p not in lst]

    def drop(self, pages: Iterable[int]) -> None:
        """Remove pages without counting an eviction (task exit/free)."""
        for p in pages:
            self._list.pop(p, None)

    # -- task lifecycle ------------------------------------------------------
    def register_task(self, task_id: int, span: PageRun) -> None:
        """Declare the page span a task's address space occupies, so its
        residual pages can be reclaimed when the task retires."""
        self._task_spans[task_id] = span

    def free_task(self, task_id: int) -> int:
        """Reclaim a retired task's resident pages (process exit: the driver
        frees the whole address space). Freed pages don't count as evictions.
        Returns the number of pages actually reclaimed."""
        span = self._task_spans.pop(task_id, None)
        if span is None:
            return 0
        lst = self._list
        lo, hi = span
        if hi - lo <= len(lst):
            freed = [p for p in range(lo, hi) if p in lst]
        else:
            freed = [p for p in lst if lo <= p < hi]
        for p in freed:
            del lst[p]
        self.freed_pages += len(freed)
        return len(freed)
