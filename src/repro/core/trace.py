"""Profiled traces: the offline phase's raw material.

``TraceStore`` accumulates, per kernel name, every observed invocation's
launch arguments, raw touched extents, and latency. Extents are kept
*unmerged* (the instrumented addresses as NVBit would record them) — merging
happens per attributed pointer region inside the analyzer; premature merging
would fuse regions of adjacent allocations and hide base addresses.

Each invocation also carries the allocation map snapshot: the OS-level
MSched tracks cudaMalloc/Free anyway (§5.1), and the analyzer uses it to
attribute extents to the right allocation.
"""
from __future__ import annotations

import dataclasses
import statistics
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.core.commands import Command, KERNEL
from repro.core.pages import Extent


@dataclasses.dataclass
class Invocation:
    args: Tuple[int, ...]
    extents: List[Extent]  # raw, sorted by start
    latency_us: float
    alloc_ranges: Optional[List[Extent]] = None  # (base, size) of live buffers


class TraceStore:
    def __init__(self):
        self.by_kernel: Dict[str, List[Invocation]] = defaultdict(list)

    def record(self, cmd: Command, space=None) -> None:
        if cmd.kind != KERNEL:
            return  # memcpy semantics are explicit; nothing to learn
        allocs = None
        if space is not None:
            allocs = [(b.base, b.size) for b in space.buffers.values()]
        self.by_kernel[cmd.name].append(
            Invocation(cmd.args, sorted(cmd.true_extents), cmd.latency_us, allocs)
        )

    def latency_us(self, kernel_name: str) -> float:
        inv = self.by_kernel.get(kernel_name)
        if not inv:
            return 0.0
        return statistics.fmean(i.latency_us for i in inv)

    def kernels(self) -> List[str]:
        return sorted(self.by_kernel)
