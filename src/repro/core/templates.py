"""Template-based memory prediction — the offline Memory Analyzer (paper §5.2).

Given profiled (launch args → touched extents) traces, the analyzer derives a
per-kernel, per-pointer *formula* mapping argument values to accessed byte
ranges, by matching three templates:

  T1 fixed   — region size invariant across invocations          (~77%)
  T2 linear  — contiguous region, size = c × Π(selected int args) (~18%)
  T3 strided — k equal chunks at a regular stride; chunk size,
               stride and count each fixed or linear in args      (~5%)

Remaining cases (pointer-chasing, <1%) are classified ``opaque`` and fall
back to demand paging at runtime (paper: 0.25% false negatives on average).

The analyzer never sees the workload generators' access closures: it works
purely from the recorded traces, exactly like the paper's NVBit-based flow.
"""
from __future__ import annotations

import dataclasses
import itertools
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.pages import Extent
from repro.core.trace import Invocation, TraceStore

PTR_MIN = 1 << 32  # values below this are treated as 32-bit scalars

T1_FIXED = "fixed"
T2_LINEAR = "linear"
T3_STRIDED = "strided"
OPAQUE = "opaque"

MAX_PRODUCT_ARGS = 3


@dataclasses.dataclass(frozen=True)
class LinearTerm:
    """value = coeff × Π args[idx] (coeff a positive rational)."""

    coeff_num: int
    coeff_den: int
    arg_idxs: Tuple[int, ...]  # empty tuple => constant (coeff itself)

    def evaluate(self, args: Sequence[int]) -> int:
        prod = 1
        for i in self.arg_idxs:
            prod *= int(args[i])
        return (self.coeff_num * prod) // self.coeff_den


@dataclasses.dataclass(frozen=True)
class RegionFormula:
    """Prediction rule for one pointer argument of one kernel."""

    ptr_arg: int
    kind: str  # fixed | linear | strided | opaque
    size: Optional[LinearTerm] = None  # chunk size (or whole region size)
    stride: Optional[LinearTerm] = None  # T3 only
    count: Optional[LinearTerm] = None  # T3 only

    def predict_extents(self, args: Sequence[int]) -> List[Extent]:
        base = int(args[self.ptr_arg])
        if self.kind == OPAQUE:
            return []  # runtime falls back to demand paging
        size = self.size.evaluate(args)
        if self.kind in (T1_FIXED, T2_LINEAR):
            return [(base, size)] if size > 0 else []
        stride = self.stride.evaluate(args)
        count = self.count.evaluate(args)
        return [(base + i * stride, size) for i in range(count) if size > 0]


@dataclasses.dataclass
class KernelDescriptor:
    name: str
    formulas: List[RegionFormula]
    latency_us: float
    template_mix: Dict[str, int]  # counts per template kind (Table 2)

    def predict_extents(self, args: Sequence[int]) -> List[Extent]:
        out: List[Extent] = []
        for f in self.formulas:
            out.extend(f.predict_extents(args))
        return out

    def has_opaque(self) -> bool:
        return any(f.kind == OPAQUE for f in self.formulas)


# --------------------------------------------------------------------------
# Fitting
# --------------------------------------------------------------------------


def _pointer_args(invocations: List[Invocation]) -> List[int]:
    """Arg indices whose value is always the start of an observed extent."""
    if not invocations:
        return []
    n_args = len(invocations[0].args)
    out = []
    for i in range(n_args):
        ok = True
        for inv in invocations:
            v = inv.args[i]
            if v < PTR_MIN or not any(s == v for s, _ in inv.extents):
                ok = False
                break
        if ok:
            out.append(i)
    return out


def _attribute_extents(
    inv: Invocation, ptr_values: List[int]
) -> Tuple[Dict[int, List[Extent]], List[Extent]]:
    """Assign each raw extent to the largest pointer value <= its start that
    lies within the *same allocation* (the OS tracks cudaMalloc, §5.1).

    Returns (per-pointer merged regions, unattributed extents). Unattributed
    extents are indirect accesses: their base never appears among the launch
    arguments — the "Others" residue of Table 2.
    """
    from repro.core.pages import merge_extents

    svals = sorted(ptr_values)
    allocs = sorted(inv.alloc_ranges or [])

    def alloc_of(addr: int) -> Optional[Extent]:
        lo, hi = 0, len(allocs) - 1
        best = None
        while lo <= hi:
            mid = (lo + hi) // 2
            if allocs[mid][0] <= addr:
                best = allocs[mid]
                lo = mid + 1
            else:
                hi = mid - 1
        if best is not None and best[0] <= addr < best[0] + best[1]:
            return best
        return None

    raw: Dict[int, List[Extent]] = {v: [] for v in ptr_values}
    unattributed: List[Extent] = []
    for ext in inv.extents:
        base = None
        for v in svals:
            if v <= ext[0]:
                base = v
            else:
                break
        if base is not None and allocs:
            a_ext = alloc_of(ext[0])
            a_ptr = alloc_of(base)
            if a_ext is None or a_ext != a_ptr:
                base = None
        if base is None:
            unattributed.append(ext)
        else:
            raw[base].append(ext)
    return {v: merge_extents(es) for v, es in raw.items()}, unattributed


def _scalar_candidates(invocations: List[Invocation], ptr_idxs: List[int]) -> List[int]:
    n_args = len(invocations[0].args)
    ptr_set = set(ptr_idxs)
    cands = []
    for i in range(n_args):
        if i in ptr_set:
            continue
        vals = [inv.args[i] for inv in invocations]
        if all(0 < v < PTR_MIN for v in vals):
            cands.append(i)
    return cands


def _fit_linear(
    values: List[int], invocations: List[Invocation], scalar_idxs: List[int]
) -> Optional[LinearTerm]:
    """Find value = c × Π args[subset] holding exactly for every invocation."""
    if all(v == values[0] for v in values):
        return LinearTerm(values[0], 1, ())
    for r in range(1, MAX_PRODUCT_ARGS + 1):
        for combo in itertools.combinations(scalar_idxs, r):
            prods = []
            for inv in invocations:
                prod = 1
                for i in combo:
                    prod *= int(inv.args[i])
                prods.append(prod)
            if any(p == 0 for p in prods):
                continue
            c = Fraction(values[0], prods[0])
            if c <= 0:
                continue
            if all(
                Fraction(v, p) == c for v, p in zip(values[1:], prods[1:])
            ):
                # require the product to actually vary (else it's T1)
                if len(set(prods)) > 1:
                    return LinearTerm(c.numerator, c.denominator, combo)
    return None


def _verify(
    formula: RegionFormula,
    invocations: List[Invocation],
    regions: List[List[Extent]],
) -> bool:
    """A formula is accepted only if it *exactly* reproduces the observed
    (merged) extents of every profiled invocation — strict template matching
    is what gives the paper its 0.00% false-positive rate."""
    from repro.core.pages import merge_extents

    for inv, obs in zip(invocations, regions):
        pred = merge_extents(formula.predict_extents(inv.args))
        if pred != merge_extents(list(obs)):
            return False
    return True


def _fit_pointer(
    ptr_idx: int,
    invocations: List[Invocation],
    regions: List[List[Extent]],
    scalar_idxs: List[int],
) -> RegionFormula:
    # ---- contiguous region: T1 / T2 ---------------------------------------
    if all(len(r) == 1 for r in regions):
        sizes = [r[0][1] for r in regions]
        if all(s == sizes[0] for s in sizes):
            f = RegionFormula(ptr_idx, T1_FIXED, size=LinearTerm(sizes[0], 1, ()))
            if _verify(f, invocations, regions):
                return f
        term = _fit_linear(sizes, invocations, scalar_idxs)
        if term is not None:
            f = RegionFormula(ptr_idx, T2_LINEAR, size=term)
            if _verify(f, invocations, regions):
                return f
        return RegionFormula(ptr_idx, OPAQUE)

    # ---- strided: T3 -------------------------------------------------------
    # Fit chunk size / stride / count from the multi-chunk invocations, then
    # verify the formula against *all* invocations (single-chunk cases arise
    # when stride == chunk size and the trace merges into one extent).
    chunk_sizes: List[int] = []
    strides: List[int] = []
    counts: List[int] = []
    multi_invs: List[Invocation] = []
    regular = True
    for inv, r in zip(invocations, regions):
        if len(r) <= 1:
            continue
        starts = [s for s, _ in r]
        sizes = [sz for _, sz in r]
        st = starts[1] - starts[0]
        if any(sizes[0] != sz for sz in sizes) or any(
            starts[i + 1] - starts[i] != st for i in range(len(starts) - 1)
        ):
            regular = False
            break
        chunk_sizes.append(sizes[0])
        strides.append(st)
        counts.append(len(r))
        multi_invs.append(inv)
    if regular and multi_invs:
        size_t = _fit_linear(chunk_sizes, multi_invs, scalar_idxs)
        cnt_t = _fit_linear(counts, multi_invs, scalar_idxs)
        stride_t = _fit_linear(strides, multi_invs, scalar_idxs)
        if size_t is not None and cnt_t is not None and stride_t is not None:
            f = RegionFormula(
                ptr_idx, T3_STRIDED, size=size_t, stride=stride_t, count=cnt_t
            )
            if _verify(f, invocations, regions):
                return f
        # fall through: maybe the *total* region is linear (count folded in)
    return RegionFormula(ptr_idx, OPAQUE)


def analyze_kernel(name: str, invocations: List[Invocation]) -> KernelDescriptor:
    ptr_idxs = _pointer_args(invocations)
    scalar_idxs = _scalar_candidates(invocations, ptr_idxs)
    # deduplicate pointer args aliasing the same value stream
    seen_value_streams = set()
    uniq_ptrs = []
    for i in ptr_idxs:
        stream = tuple(inv.args[i] for inv in invocations)
        if stream not in seen_value_streams:
            seen_value_streams.add(stream)
            uniq_ptrs.append(i)

    attributed = [
        _attribute_extents(inv, [inv.args[i] for i in uniq_ptrs])
        for inv in invocations
    ]
    formulas = []
    mix: Dict[str, int] = {T1_FIXED: 0, T2_LINEAR: 0, T3_STRIDED: 0, OPAQUE: 0}
    for i in uniq_ptrs:
        regions = [attributed[j][0][inv.args[i]] for j, inv in enumerate(invocations)]
        if all(not r for r in regions):
            continue
        f = _fit_pointer(i, invocations, regions, scalar_idxs)
        formulas.append(f)
        mix[f.kind] += 1
    # extents whose base never appears among the args => indirect access
    if any(unattr for _, unattr in attributed):
        mix[OPAQUE] += 1
        formulas.append(RegionFormula(-1, OPAQUE))

    import statistics

    lat = statistics.fmean(i.latency_us for i in invocations)
    return KernelDescriptor(name, formulas, lat, mix)


def analyze_traces(store: TraceStore) -> Dict[str, KernelDescriptor]:
    """The offline phase output: one descriptor file entry per kernel."""
    return {
        name: analyze_kernel(name, invs)
        for name, invs in store.by_kernel.items()
    }


def template_mix_table(
    descriptors: Dict[str, KernelDescriptor], store: TraceStore
) -> Dict[str, float]:
    """Invocation-weighted template share (reproduces paper Table 2)."""
    totals = {T1_FIXED: 0, T2_LINEAR: 0, T3_STRIDED: 0, OPAQUE: 0}
    for name, desc in descriptors.items():
        n_inv = len(store.by_kernel[name])
        region_total = sum(desc.template_mix.values()) or 1
        for kind, cnt in desc.template_mix.items():
            totals[kind] += n_inv * cnt / region_total
    s = sum(totals.values()) or 1.0
    return {k: 100.0 * v / s for k, v in totals.items()}
