"""Incremental planning engine: O(1)-amortized working-set prediction.

The straightforward coordinator (``opt.build_plan`` over per-switch future
rebuilds) re-decodes every queued command's extents into page lists on every
context switch — O(queue depth x footprint) per switch, which makes the
*simulator* the bottleneck long before the modeled hardware is (cf. the
paper's <1 ms control-plane budget, §6/Fig. 11).

This module plans each switch from state the helpers already maintain
incrementally:

  * every command's page order is decoded **once**, at ``annotate()`` time,
    into run-length page intervals cached on the command;
  * each helper keeps its ``PlannedAccess`` future as an append/pop deque with
    a cumulative-latency prefix array, so locating a timeslice's command range
    is a bisect, not a walk;
  * timeslice page groups are merged interval lists, never materialized int
    sets, so madvise/migrate can stream GiB-scale working sets.

A switch therefore costs O(timeline entries · log queue + horizon runs +
pages actually migrated) instead of O(queue · footprint). ``RunPlan`` can be
materialized into a classic ``OptPlan`` for equivalence testing against
``build_plan``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

from repro.core.opt import OptPlan
from repro.core.pages import (
    PageRun,
    RunSet,
    expand_runs,
    intersect_runs,
    merge_runs,
    subtract_runs,
)
from repro.core.timeline import TaskTimeline

# (task_id, start, end): future-queue index range consumed by one entry
EntryCut = Tuple[int, int, int]


@dataclasses.dataclass
class RunPlan:
    """Run-length form of an OPT plan over the scheduling timeline."""

    entry_cuts: List[EntryCut]
    run_groups: List[List[PageRun]]  # merged (sorted, disjoint) per entry
    first_access_runs: List[PageRun]  # next timeslice, first-touch order

    def to_opt_plan(self, helpers: Dict[int, "TaskHelper"]) -> OptPlan:
        """Materialize the classic set-based plan (equivalence tests only)."""
        groups = [set(expand_runs(g)) for g in self.run_groups]
        first = expand_runs(self.first_access_runs)
        global_seq: List[List[int]] = []
        for tid, start, end in self.entry_cuts:
            h = helpers.get(tid)
            if h is None:
                continue
            for acc in h.future_slice(start, end):
                global_seq.append(list(acc.page_list()))
        return OptPlan(groups, first, global_seq)


def compute_cuts(
    timeline: TaskTimeline, helpers: Dict[int, "TaskHelper"]
) -> List[EntryCut]:
    """Walk the timeline, assigning each entry its command range via bisect
    over the helper's cumulative-latency prefix array (same consumption rule
    as ``build_plan``: a command is consumed while budget remains > 0)."""
    cursors = {tid: h.head_index() for tid, h in helpers.items()}
    cuts: List[EntryCut] = []
    for entry in timeline:
        h = helpers.get(entry.task_id)
        if h is None:
            cuts.append((entry.task_id, 0, 0))
            continue
        start = cursors[entry.task_id]
        end = h.consume_cut(start, entry.timeslice_us)
        cursors[entry.task_id] = end
        cuts.append((entry.task_id, start, end))
    return cuts


def first_access_runs(
    helpers: Dict[int, "TaskHelper"], cuts: List[EntryCut]
) -> List[PageRun]:
    """Pages of the next timeslice in first-access order (deduplicated),
    as runs — the migration pipeline's population order (§6.3)."""
    if not cuts:
        return []
    tid, start, end = cuts[0]
    h = helpers.get(tid)
    if h is None:
        return []
    seen = RunSet()
    seen_shapes: set = set()
    out: List[PageRun] = []
    for acc in h.future_slice(start, end):
        runs = acc.page_runs()
        # iteration-structured workloads repeat identical cached run tuples;
        # an exact repeat has every page seen already, so skip the interval
        # walk entirely (this is the O(1)-amortized part of the hot path)
        if not runs or runs in seen_shapes:
            continue
        seen_shapes.add(runs)
        for s, e in runs:
            out.extend(seen.add(s, e))
    return out


def run_groups(
    helpers: Dict[int, "TaskHelper"], cuts: List[EntryCut]
) -> List[List[PageRun]]:
    """Per-timeline-entry touched-page groups as merged interval lists.
    Iterating a merged group yields ascending unique pages — the same visit
    order as ``sorted(set(...))`` over the per-page representation."""
    groups: List[List[PageRun]] = []
    for tid, start, end in cuts:
        h = helpers.get(tid)
        runs: List[PageRun] = []
        if h is not None:
            seen_shapes: set = set()
            for acc in h.future_slice(start, end):
                r = acc.page_runs()
                # duplicate cached run tuples add nothing to the union
                if r and r not in seen_shapes:
                    seen_shapes.add(r)
                    runs.extend(r)
        groups.append(merge_runs(runs))
    return groups


def plan_switch(
    timeline: TaskTimeline, helpers: Dict[int, "TaskHelper"]
) -> RunPlan:
    """Full incremental plan for one context switch."""
    cuts = compute_cuts(timeline, helpers)
    return RunPlan(cuts, run_groups(helpers, cuts), first_access_runs(helpers, cuts))


def partition_source_tiers(
    requested: Sequence[PageRun],
    peer_candidate: Sequence[PageRun],
    missing_on_peer: Callable[[List[PageRun]], List[PageRun]],
) -> Tuple[List[PageRun], List[PageRun], List[PageRun]]:
    """Split a migration's populate set by *source tier*.

    ``requested`` is the switch's population set in first-access order;
    ``peer_candidate`` is the sorted disjoint run set a peer GPU may still
    hold (e.g. a migrated task's lingering working set from the cluster's
    page-location directory); ``missing_on_peer`` is the peer pool's live
    ``missing_runs`` — the directory is a hint, the pool is the truth.

    Returns ``(peer, host, fresh)``, each order-preserving:

      * **peer**  — lingered *and* still resident on the peer: fetchable over
        NVLink at the link graph's fluid-share rate;
      * **host**  — lingered but since evicted by the peer (the data went to
        host DRAM): a host round-trip at PCIe rate — the fallback a source
        GPU's mid-stream eviction forces;
      * **fresh** — never part of the peer-held set (pages the task had not
        materialized when it migrated): populated through the standard host
        path, counted separately so the tier mix is observable.
    """
    avail = intersect_runs(requested, list(peer_candidate))
    gone = merge_runs(missing_on_peer(avail)) if avail else []
    peer = subtract_runs(avail, gone)
    host = intersect_runs(avail, gone)
    fresh = subtract_runs(requested, merge_runs(avail))
    return peer, host, fresh


def merged_command_runs(cmds, space) -> List[PageRun]:
    """Merged (sorted, disjoint) ground-truth page runs of a command window —
    the macro-stepper's residency precondition: when the merged group is fully
    resident, every command in the window executes with zero stall and no
    backend interaction, so the simulator may advance the whole window in one
    tight loop."""
    runs: List[PageRun] = []
    for cmd in cmds:
        runs.extend(cmd.true_page_runs(space))
    return merge_runs(runs)
