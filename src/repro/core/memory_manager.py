"""MSched memory manager: central coordinator + per-process helpers (Fig. 4).

The helper lives in each task's process: it intercepts launched commands,
annotates them with predicted pages (online predictor) and profiled latency,
and maintains the task-local future command queue. The coordinator, invoked by
the scheduler's context switcher, pulls each helper's future, reconstructs the
global access sequence with the timeline (the Rosetta Stone), madvises in
reverse timeline order to realize Belady-OPT in the driver's eviction list,
and finally migrates the next task's working set (pipelined, first-access
ordered) — completing the *extended context switch*.
"""
from __future__ import annotations

import dataclasses
import time
from bisect import bisect_left
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.commands import Command
from repro.core.hardware import Platform
from repro.core.hbm import HBMPool
from repro.core.migration import (
    MigrationResult,
    RunMigration,
    plan_population,
    plan_population_runs,
)
from repro.core.opt import OptPlan, PlannedAccess, build_plan
from repro.core.pages import AddressSpace, merge_runs, run_page_count
from repro.core.planner import compute_cuts, first_access_runs, run_groups
from repro.core.predictor import Predictor
from repro.core.timeline import TaskTimeline

# control-plane calibration (paper Fig. 11: <1 ms for tens of tasks)
MADVISE_CALL_US = 30.0  # per-task ioctl round trip
MADVISE_PER_PAGE_US = 0.02


@dataclasses.dataclass
class SwitchReport:
    madvise_us: float
    # RunMigration on the incremental path, MigrationResult on legacy; both
    # expose total_us / populated_runs / ready_view(base)
    migration: "RunMigration | MigrationResult"
    populated_pages: int
    evicted_pages: int
    wall_clock_coordinator_s: float  # real measured Python time (Fig. 11)
    # the template-predicted cut for the quantum (the populate plan before
    # residency filtering) — read only by the telemetry prediction auditor;
    # empty on the legacy path, which plans from page lists, not runs
    predicted_runs: "Tuple[PageRun, ...] | List[PageRun]" = ()


class TaskHelper:
    """Per-process predictor + local future command queue.

    The ``PlannedAccess`` future is maintained *incrementally*: ``launch()``
    appends one entry (with the annotate-time page-run cache attached) and
    ``pop()`` advances the head, so a context switch never rebuilds the plan
    from the command queue. A cumulative-latency prefix array rides along so
    the planner can bisect a timeslice's command range in O(log queue).
    ``future_rebuild()`` preserves the original from-scratch derivation as the
    equivalence reference (and the ``--legacy`` benchmark path).
    """

    def __init__(
        self,
        task_id: int,
        space: AddressSpace,
        predictor: Predictor,
        latency_fn=None,
    ):
        self.task_id = task_id
        self.space = space
        self.predictor = predictor
        self.latency_fn = latency_fn  # kernel name -> profiled latency (us)
        self.queue: Deque[Command] = deque()
        # incremental future state; _future/_prefix share the head offset.
        # _prefix[k] is the cumulative latency of the first k entries of
        # _future (len == len(_future) + 1); compaction slices both without
        # renormalizing, so prefix *differences* are stable across pops.
        self._future: List[PlannedAccess] = []
        self._prefix: List[float] = [0.0]
        self._head = 0
        self._launched = 0

    def launch(self, cmd: Command) -> None:
        """Intercept an async command launch: predict + enqueue."""
        cmd.task_id = self.task_id
        self.predictor.annotate(cmd, self.space)
        lat = cmd.latency_us
        if self.latency_fn is not None:
            lat = self.latency_fn(cmd.name) or lat
        self._future.append(
            PlannedAccess(
                self.task_id, self._launched, None, lat,
                runs=cmd.predicted_page_runs or (),
            )
        )
        self._prefix.append(self._prefix[-1] + lat)
        self._launched += 1
        self.queue.append(cmd)

    def future(self, max_commands: Optional[int] = None) -> List[PlannedAccess]:
        """Current future as a list (no page decoding — entries are live)."""
        end = len(self._future)
        if max_commands is not None:
            end = min(end, self._head + max_commands)
        return self._future[self._head : end]

    def future_rebuild(
        self, max_commands: Optional[int] = None
    ) -> List[PlannedAccess]:
        """From-scratch future derivation (the pre-incremental hot path):
        re-decodes every queued command's predicted extents per call."""
        out: List[PlannedAccess] = []
        base = self._launched - len(self.queue)
        for i, cmd in enumerate(self.queue):
            if max_commands is not None and i >= max_commands:
                break
            pages = _page_order(self.space, cmd.predicted_extents or [])
            lat = cmd.latency_us
            if self.latency_fn is not None:
                lat = self.latency_fn(cmd.name) or lat
            out.append(PlannedAccess(self.task_id, base + i, pages, lat))
        return out

    def pop(self) -> Command:
        cmd = self.queue.popleft()  # raises cleanly on empty, state untouched
        self._head += 1
        if self._head >= 1024 and self._head * 2 >= len(self._future):
            del self._future[: self._head]
            del self._prefix[: self._head]
            self._head = 0
        return cmd

    def __len__(self):
        return len(self.queue)

    # -- incremental planner hooks ------------------------------------------
    def head_index(self) -> int:
        return self._head

    def future_slice(self, start: int, end: int) -> List[PlannedAccess]:
        return self._future[start:end]

    def consume_cut(self, start: int, budget_us: float) -> int:
        """Index one past the last command a ``budget_us`` timeslice consumes
        starting at ``start`` (build_plan's rule: consume while budget > 0)."""
        target = self._prefix[start] + budget_us
        return min(bisect_left(self._prefix, target, lo=start), len(self._future))


def predicted_working_set_pages(
    helper: TaskHelper, quantum_us: float
) -> int:
    """Pages the planner predicts the task touches in one scheduling quantum
    (the same cut ``compute_cuts`` takes at a context switch). Shared by the
    serving admission controller and the cluster placement bin-packer."""
    head = helper.head_index()
    end = helper.consume_cut(head, quantum_us)
    runs = [
        run
        for acc in helper.future_slice(head, end)
        for run in acc.page_runs()
    ]
    return run_page_count(merge_runs(runs))


def _page_order(space: AddressSpace, extents) -> List[int]:
    """Pages in first-access order (dedup, stable)."""
    seen: Set[int] = set()
    order: List[int] = []
    for ext in extents:
        for p in space.pages_of_extent(ext):
            if p not in seen:
                seen.add(p)
                order.append(p)
    return order


class Coordinator:
    """Centralized daemon enforcing scheduling-aligned OPT placement.

    The default engine plans each switch incrementally from the helpers' live
    futures (see ``repro.core.planner``); ``legacy=True`` selects the original
    rebuild-everything path, preserved for the sim-throughput benchmark and
    equivalence tests.

    Two optional *cluster hooks* extend the extended context switch beyond
    one GPU (both default to ``None``, in which case every code path is
    byte-identical to the single-GPU coordinator):

      * ``peer_source`` — called with ``(next_task, populated_runs,
        evicted_pages, now)`` after the pool has admitted the population set;
        may return a :class:`~repro.core.migration.TieredMigration` that
        prices some populated runs from a peer GPU's HBM over NVLink instead
        of host DRAM (the cluster's page-location directory decides which).
      * ``cluster_view`` — called with ``now``; returns ``(next_use_us,
        runs)`` pairs for *foreign* runs resident in this pool that the rest
        of the fleet still needs (a migrated-away task's lingering working
        set). The madvise walk merges them into the local timeline order by
        next use, so the eviction list realizes Belady-OPT over the
        **cluster-wide** timeline: the head holds the page the *fleet* needs
        last, not merely the page this GPU needs last.
    """

    def __init__(
        self,
        platform: Platform,
        pool: HBMPool,
        pipelined: bool = True,
        page_size: int = 0,
        legacy: bool = False,
    ):
        self.platform = platform
        self.pool = pool
        self.pipelined = pipelined
        self.page_size = page_size or platform.page_size
        self.legacy = legacy
        self.helpers: Dict[int, TaskHelper] = {}
        # cluster hooks (see class docstring); None = single-GPU behavior
        self.peer_source = None
        self.cluster_view = None
        # cumulative stats
        self.total_madvise_us = 0.0
        self.total_migration_us = 0.0
        self.total_populated = 0
        self.total_evicted = 0

    def register(self, helper: TaskHelper) -> None:
        self.helpers[helper.task_id] = helper

    def unregister(self, task_id: int) -> None:
        """Task exit: drop the helper (its future, prefix array, and queue)
        so retired tasks stop contributing to switch plans."""
        self.helpers.pop(task_id, None)

    def on_context_switch(
        self, next_task: int, timeline: TaskTimeline, now: float = 0.0
    ) -> SwitchReport:
        """Plan one extended context switch. ``now`` is the simulation clock
        at the switch — only the cluster hooks consume it (peer-fetch
        transfers share the link graph's contention bookkeeping, which is
        keyed by absolute time); single-GPU callers may omit it."""
        if self.legacy:
            return self._on_context_switch_legacy(next_task, timeline)
        wall0 = time.perf_counter()
        cuts = compute_cuts(timeline, self.helpers)
        first_runs = first_access_runs(self.helpers, cuts)

        # fast path: no memory pressure — everything needed is resident and
        # HBM is not full, so neither eviction reordering nor migration can
        # change anything (this is what keeps MSched's overhead at 0.59%
        # under 100% subscription, paper §7.1)
        if self.pool.free_pages() > 0 and self.pool.all_resident_runs(first_runs):
            return SwitchReport(
                madvise_us=0.0,
                migration=plan_population_runs(
                    self.platform, [], 0, self.pipelined, self.page_size
                ),
                populated_pages=0,
                evicted_pages=0,
                wall_clock_coordinator_s=time.perf_counter() - wall0,
                predicted_runs=first_runs,
            )

        # --- enforce OPT: walk the timeline in REVERSE, madvise to tail ----
        groups = run_groups(self.helpers, cuts)
        madvise_us = 0.0
        for group in self._opt_order(timeline, groups, now):
            if not group:
                continue
            moved = self.pool.madvise_runs(group)
            madvise_us += MADVISE_CALL_US + MADVISE_PER_PAGE_US * moved
        # --- migrate: populate next task's immediate working set -----------
        # runs go straight through the driver: no page-list materialization
        populated_runs, evicted_runs = self.pool.migrate_runs(first_runs)
        evicted_pages = run_page_count(evicted_runs)
        if self.peer_source is not None and populated_runs:
            tiered = self.peer_source(
                next_task, populated_runs, evicted_pages, now
            )
            if tiered is not None:
                rep = self._report(
                    wall0, madvise_us, tiered,
                    run_page_count(populated_runs), evicted_pages,
                )
                rep.predicted_runs = first_runs
                return rep
        rep = self._finish_switch_runs(
            wall0, madvise_us, populated_runs, evicted_pages
        )
        rep.predicted_runs = first_runs
        return rep

    def _opt_order(
        self, timeline: TaskTimeline, groups, now: float
    ):
        """Madvise order realizing OPT over the *cluster-wide* next-use
        timeline: local timeline groups at their cumulative start offsets,
        foreign lingering runs (``cluster_view``) at the fleet's next-use
        estimate, all madvised furthest-future first so the final list tail
        holds what is needed soonest — anywhere in the fleet. Without a
        cluster view this degenerates to ``reversed(groups)`` exactly (the
        per-GPU Belady walk)."""
        foreign = (
            self.cluster_view(now) if self.cluster_view is not None else None
        )
        if not foreign:
            return reversed(groups)
        sched: List[Tuple[float, int, List]] = []
        off = 0.0
        for entry, group in zip(timeline, groups):
            sched.append((off, 0, group))
            off += entry.timeslice_us
        for next_use_us, runs in foreign:
            sched.append((max(0.0, next_use_us - now), 1, runs))
        sched.sort(key=lambda x: (x[0], x[1]))
        return [g for _, _, g in reversed(sched)]

    def _on_context_switch_legacy(
        self, next_task: int, timeline: TaskTimeline
    ) -> SwitchReport:
        """Pre-incremental engine: rebuild every helper's future and the full
        set-based plan on every switch (O(queue depth x footprint))."""
        wall0 = time.perf_counter()
        futures = {tid: h.future_rebuild() for tid, h in self.helpers.items()}
        plan = build_plan(timeline, futures)

        if self.pool.free_pages() > 0 and all(
            self.pool.resident(p) for p in plan.first_access_order
        ):
            return SwitchReport(
                madvise_us=0.0,
                migration=plan_population(
                    self.platform, [], 0, self.pipelined, self.page_size
                ),
                populated_pages=0,
                evicted_pages=0,
                wall_clock_coordinator_s=time.perf_counter() - wall0,
            )

        madvise_us = 0.0
        for group in reversed(plan.timeslice_page_groups):
            if not group:
                continue
            moved = self.pool.madvise(sorted(group))
            madvise_us += MADVISE_CALL_US + MADVISE_PER_PAGE_US * moved
        populated, evicted = self.pool.migrate(plan.first_access_order)
        return self._finish_switch(wall0, madvise_us, populated, evicted)

    def _finish_switch(
        self,
        wall0: float,
        madvise_us: float,
        populated: List[int],
        evicted: List[int],
    ) -> SwitchReport:
        migration = plan_population(
            self.platform, populated, len(evicted), self.pipelined, self.page_size
        )
        return self._report(
            wall0, madvise_us, migration, len(populated), len(evicted)
        )

    def _finish_switch_runs(
        self,
        wall0: float,
        madvise_us: float,
        populated_runs,
        evicted_pages: int,
    ) -> SwitchReport:
        migration = plan_population_runs(
            self.platform, populated_runs, evicted_pages, self.pipelined,
            self.page_size,
        )
        return self._report(
            wall0, madvise_us, migration, run_page_count(populated_runs),
            evicted_pages,
        )

    def _report(
        self,
        wall0: float,
        madvise_us: float,
        migration,
        populated_pages: int,
        evicted_pages: int,
    ) -> SwitchReport:
        wall = time.perf_counter() - wall0
        self.total_madvise_us += madvise_us
        self.total_migration_us += migration.total_us
        self.total_populated += populated_pages
        self.total_evicted += evicted_pages
        return SwitchReport(
            madvise_us=madvise_us,
            migration=migration,
            populated_pages=populated_pages,
            evicted_pages=evicted_pages,
            wall_clock_coordinator_s=wall,
        )
