"""MSched memory manager: central coordinator + per-process helpers (Fig. 4).

The helper lives in each task's process: it intercepts launched commands,
annotates them with predicted pages (online predictor) and profiled latency,
and maintains the task-local future command queue. The coordinator, invoked by
the scheduler's context switcher, pulls each helper's future, reconstructs the
global access sequence with the timeline (the Rosetta Stone), madvises in
reverse timeline order to realize Belady-OPT in the driver's eviction list,
and finally migrates the next task's working set (pipelined, first-access
ordered) — completing the *extended context switch*.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Set, Tuple

from repro.core.commands import Command
from repro.core.hardware import Platform
from repro.core.hbm import HBMPool
from repro.core.migration import MigrationResult, plan_population
from repro.core.opt import OptPlan, PlannedAccess, build_plan
from repro.core.pages import AddressSpace
from repro.core.predictor import Predictor
from repro.core.timeline import TaskTimeline

# control-plane calibration (paper Fig. 11: <1 ms for tens of tasks)
MADVISE_CALL_US = 30.0  # per-task ioctl round trip
MADVISE_PER_PAGE_US = 0.02


@dataclasses.dataclass
class SwitchReport:
    madvise_us: float
    migration: MigrationResult
    populated_pages: int
    evicted_pages: int
    wall_clock_coordinator_s: float  # real measured Python time (Fig. 11)


class TaskHelper:
    """Per-process predictor + local future command queue."""

    def __init__(
        self,
        task_id: int,
        space: AddressSpace,
        predictor: Predictor,
        latency_fn=None,
    ):
        self.task_id = task_id
        self.space = space
        self.predictor = predictor
        self.latency_fn = latency_fn  # kernel name -> profiled latency (us)
        self.queue: Deque[Command] = deque()

    def launch(self, cmd: Command) -> None:
        """Intercept an async command launch: predict + enqueue."""
        cmd.task_id = self.task_id
        self.predictor.annotate(cmd)
        self.queue.append(cmd)

    def future(self, max_commands: Optional[int] = None) -> List[PlannedAccess]:
        out: List[PlannedAccess] = []
        for i, cmd in enumerate(self.queue):
            if max_commands is not None and i >= max_commands:
                break
            pages = _page_order(self.space, cmd.predicted_extents or [])
            lat = cmd.latency_us
            if self.latency_fn is not None:
                lat = self.latency_fn(cmd.name) or lat
            out.append(PlannedAccess(self.task_id, i, pages, lat))
        return out

    def pop(self) -> Command:
        return self.queue.popleft()

    def __len__(self):
        return len(self.queue)


def _page_order(space: AddressSpace, extents) -> List[int]:
    """Pages in first-access order (dedup, stable)."""
    seen: Set[int] = set()
    order: List[int] = []
    for ext in extents:
        for p in space.pages_of_extent(ext):
            if p not in seen:
                seen.add(p)
                order.append(p)
    return order


class Coordinator:
    """Centralized daemon enforcing scheduling-aligned OPT placement."""

    def __init__(
        self,
        platform: Platform,
        pool: HBMPool,
        pipelined: bool = True,
        page_size: int = 0,
    ):
        self.platform = platform
        self.pool = pool
        self.pipelined = pipelined
        self.page_size = page_size or platform.page_size
        self.helpers: Dict[int, TaskHelper] = {}
        # cumulative stats
        self.total_madvise_us = 0.0
        self.total_migration_us = 0.0
        self.total_populated = 0
        self.total_evicted = 0

    def register(self, helper: TaskHelper) -> None:
        self.helpers[helper.task_id] = helper

    def on_context_switch(
        self, next_task: int, timeline: TaskTimeline
    ) -> SwitchReport:
        wall0 = time.perf_counter()
        futures = {tid: h.future() for tid, h in self.helpers.items()}
        plan = build_plan(timeline, futures)

        # fast path: no memory pressure — everything needed is resident and
        # HBM is not full, so neither eviction reordering nor migration can
        # change anything (this is what keeps MSched's overhead at 0.59%
        # under 100% subscription, paper §7.1)
        if self.pool.free_pages() > 0 and all(
            self.pool.resident(p) for p in plan.first_access_order
        ):
            return SwitchReport(
                madvise_us=0.0,
                migration=plan_population(
                    self.platform, [], 0, self.pipelined, self.page_size
                ),
                populated_pages=0,
                evicted_pages=0,
                wall_clock_coordinator_s=time.perf_counter() - wall0,
            )

        # --- enforce OPT: walk the timeline in REVERSE, madvise to tail ----
        madvise_us = 0.0
        for group in reversed(plan.timeslice_page_groups):
            if not group:
                continue
            moved = self.pool.madvise(sorted(group))
            madvise_us += MADVISE_CALL_US + MADVISE_PER_PAGE_US * moved
        # --- migrate: populate next task's immediate working set -----------
        populated, evicted = self.pool.migrate(plan.first_access_order)
        migration = plan_population(
            self.platform, populated, len(evicted), self.pipelined, self.page_size
        )
        wall = time.perf_counter() - wall0

        self.total_madvise_us += madvise_us
        self.total_migration_us += migration.total_us
        self.total_populated += len(populated)
        self.total_evicted += len(evicted)
        return SwitchReport(
            madvise_us=madvise_us,
            migration=migration,
            populated_pages=len(populated),
            evicted_pages=len(evicted),
            wall_clock_coordinator_s=wall,
        )
