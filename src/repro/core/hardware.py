"""Hardware constants.

GPU-side constants are calibrated from the paper's own measurements (§3, §7.3)
so the simulator reproduces its figures; TPU v5e constants drive the roofline
analysis of the dry-run (§Roofline in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Platform:
    name: str
    hbm_bytes: int
    page_size: int
    # demand-paging fault path (paper §3: 31.79 us/fault, 96% control plane)
    fault_total_us: float
    fault_transfer_us: float
    # batched DMA bandwidths (paper Fig. 9a)
    d2h_gbps: float  # eviction incl. unmap
    h2d_gbps: float  # population incl. map
    duplex_cap_gbps: float  # host-side ceiling on overlapped D2H+H2D
    # UM fault-group model: CUDA UM's tree-based prefetcher escalates the
    # migration granularity from 64 KiB up to 2 MiB for dense access; one
    # CPU-serviced fault per ~1 MiB group reproduces the paper's ~9210
    # faults per 8.5 GB decode step (Fig. 1)
    um_prefetch_pages: int = 256  # 1 MiB fault groups
    # under pressure the driver reclaims space in large chunks (2 MiB blocks
    # batched per eviction pass), kicking out soon-needed pages of *other*
    # tasks — a key source of UM's multitasking thrash (§3)
    um_evict_batch_bytes: int = 64 << 20


# NVIDIA RTX 5080 (16 GB, PCIe 5.0 x16) — the paper's primary testbed.
RTX5080 = Platform(
    name="rtx5080",
    hbm_bytes=16 << 30,
    page_size=4 << 10,
    fault_total_us=31.79,
    fault_transfer_us=1.35,
    d2h_gbps=41.7,
    h2d_gbps=41.7,
    duplex_cap_gbps=63.5,  # Intel chiplet NoC ceiling (paper §7.3)
)

# NVIDIA RTX 3080 (10 GB, PCIe 4.0 x16) — the paper's second testbed.
RTX3080 = Platform(
    name="rtx3080",
    hbm_bytes=10 << 30,
    page_size=4 << 10,
    fault_total_us=31.79,
    fault_transfer_us=2.7,
    d2h_gbps=22.22,
    h2d_gbps=22.22,
    duplex_cap_gbps=39.8,
)

# Heterogeneous serving-fleet presets: datacenter device classes with 40 GB /
# 80 GB HBM variants and differing swap bandwidths, so cluster topologies can
# mix device classes (the fault control-plane cost is the same KMD path the
# paper measures; the transfer term scales with the interconnect).
A100_40G = Platform(
    name="a100_40g",
    hbm_bytes=40 << 30,
    page_size=4 << 10,
    fault_total_us=31.79,
    fault_transfer_us=2.4,
    d2h_gbps=24.0,  # PCIe 4.0 x16
    h2d_gbps=24.0,
    duplex_cap_gbps=42.0,
)

A100_80G = Platform(
    name="a100_80g",
    hbm_bytes=80 << 30,
    page_size=4 << 10,
    fault_total_us=31.79,
    fault_transfer_us=2.2,
    d2h_gbps=26.0,  # PCIe 4.0 x16, SXM board power/host path headroom
    h2d_gbps=26.0,
    duplex_cap_gbps=46.0,
)

H100_80G = Platform(
    name="h100_80g",
    hbm_bytes=80 << 30,
    page_size=4 << 10,
    fault_total_us=31.79,
    fault_transfer_us=1.2,
    d2h_gbps=49.0,  # PCIe 5.0 x16
    h2d_gbps=49.0,
    duplex_cap_gbps=80.0,
)

# NVLink peer-to-peer bandwidth (GB/s per direction) for the cluster link
# graph; GPUs without NVLink reach peers through host-staged PCIe copies.
NVLINK_A100_GBPS = 300.0
NVLINK_H100_GBPS = 450.0

# TPU v5e — the deployment target for the framework (roofline §Perf).
TPU_V5E_PEAK_BF16_FLOPS = 197e12  # per chip
TPU_V5E_HBM_GBPS = 819.0  # per chip
TPU_V5E_ICI_GBPS = 50.0  # per link
TPU_V5E_HBM_BYTES = 16 << 30

TPU_V5E = Platform(
    name="tpu_v5e",
    hbm_bytes=TPU_V5E_HBM_BYTES,
    page_size=4 << 20,  # TPU adaptation: 4 MiB extents (see DESIGN.md)
    fault_total_us=0.0,  # TPUs cannot fault: proactive scheduling is mandatory
    fault_transfer_us=0.0,
    d2h_gbps=32.0,  # host DMA
    h2d_gbps=32.0,
    duplex_cap_gbps=60.0,
)

PLATFORMS = {
    p.name: p
    for p in (RTX5080, RTX3080, A100_40G, A100_80G, H100_80G, TPU_V5E)
}


def hbm_variant(platform: Platform, hbm_bytes: int, name: str = "") -> Platform:
    """Same device class with a different HBM size (e.g. a capacity-binned
    SKU for a heterogeneous cluster)."""
    return dataclasses.replace(
        platform,
        name=name or f"{platform.name}_{hbm_bytes >> 30}g",
        hbm_bytes=hbm_bytes,
    )


def fault_bandwidth_gbps(p: Platform) -> float:
    """Effective page-fault migration bandwidth (paper: 0.12 GB/s on 5080)."""
    return (p.page_size / 1e9) / (p.fault_total_us * 1e-6)
