"""Task schedulers: round-robin and priority (RT/BE) policies.

The scheduler owns the preemptive context switcher (XSched's TSG-based
switching in the paper) and — crucially for MSched — *exposes its timeline*
to the memory manager. Policies only need to produce that timeline; memory
management is fully decoupled (paper §6.1: "the timeline … effectively
decouples the scheduling policy from memory management").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.timeline import TaskTimeline, TimelineEntry


@dataclasses.dataclass
class SchedTask:
    task_id: int
    priority: int = 0  # higher = more urgent (RT), 0 = best-effort
    runnable: bool = True  # has pending work (admitted and not blocked)


class Policy:
    def next_entry(self, tasks: Dict[int, SchedTask]) -> Optional[TimelineEntry]:
        raise NotImplementedError

    def timeline(self, tasks: Dict[int, SchedTask], horizon: int = 0) -> TaskTimeline:
        raise NotImplementedError


class RoundRobinPolicy(Policy):
    """Equal timeslices in fixed order — the paper's default (matches the
    time-sharing behavior of commodity GPUs).

    The task population is dynamic: tasks absent from ``tasks`` have departed
    and are purged from the rotation; tasks present but ``runnable=False``
    (blocked tasks, e.g. RT jobs waiting between request arrivals) keep their
    rotation slot but are *skipped* by both ``next_entry`` and ``timeline`` —
    a non-runnable task must never be scheduled nor planned for. (Requests
    queued by admission control are *not* in ``tasks`` at all: they only
    enter the population once admitted.)
    """

    def __init__(self, quantum_us: float = 5_000.0):
        self.quantum_us = quantum_us
        self._rr: List[int] = []

    def _order(self, tasks: Dict[int, SchedTask]) -> List[int]:
        # purge departed tasks; enroll new ones at the tail (arrival order)
        self._rr = [t for t in self._rr if t in tasks]
        known = set(self._rr)
        for t in sorted(tasks):
            if t not in known:
                self._rr.append(t)
        return [t for t in self._rr if tasks[t].runnable]

    def next_entry(self, tasks):
        order = self._order(tasks)
        if not order:
            return None
        tid = order[0]
        # rotate only the dispatched task; skipped (non-runnable) tasks keep
        # their position so they run promptly once admitted/unblocked
        self._rr.remove(tid)
        self._rr.append(tid)
        return TimelineEntry(tid, self.quantum_us)

    def timeline(self, tasks, horizon: int = 0) -> TaskTimeline:
        order = self._order(tasks)
        horizon = horizon or 2 * max(len(order), 1)
        entries = [
            TimelineEntry(order[i % len(order)], self.quantum_us)
            for i in range(horizon)
        ] if order else []
        return TaskTimeline(entries)


class PriorityPolicy(Policy):
    """Strict priority with RR among equals; RT preempts BE on arrival."""

    def __init__(self, quantum_us: float = 5_000.0, rt_quantum_us: float = 2_000.0):
        self.quantum_us = quantum_us
        self.rt_quantum_us = rt_quantum_us
        self._rr = RoundRobinPolicy(quantum_us)

    def _split(self, tasks):
        """Partition by priority class. Both classes keep their non-runnable
        members (so the BE rotation preserves their slots); runnable filtering
        happens at selection time."""
        rt = {t: s for t, s in tasks.items() if s.priority > 0}
        be = {t: s for t, s in tasks.items() if s.priority == 0}
        return rt, be

    def next_entry(self, tasks):
        rt, be = self._split(tasks)
        runnable_rt = [t for t, s in rt.items() if s.runnable]
        if runnable_rt:
            tid = min(runnable_rt)  # deterministic among RT
            return TimelineEntry(tid, self.rt_quantum_us)
        return self._rr.next_entry(be) if be else None

    def timeline(self, tasks, horizon: int = 0) -> TaskTimeline:
        rt, be = self._split(tasks)
        entries: List[TimelineEntry] = []
        for tid in sorted(t for t, s in rt.items() if s.runnable):
            entries.append(TimelineEntry(tid, self.rt_quantum_us))
        n_be = sum(1 for s in be.values() if s.runnable)
        be_tl = self._rr.timeline(be, horizon or 2 * max(n_be, 1))
        entries.extend(be_tl.entries)
        return TaskTimeline(entries)
