"""Task schedulers: round-robin and priority (RT/BE) policies.

The scheduler owns the preemptive context switcher (XSched's TSG-based
switching in the paper) and — crucially for MSched — *exposes its timeline*
to the memory manager. Policies only need to produce that timeline; memory
management is fully decoupled (paper §6.1: "the timeline … effectively
decouples the scheduling policy from memory management").
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.core.timeline import TaskTimeline, TimelineEntry


@dataclasses.dataclass
class SchedTask:
    task_id: int
    priority: int = 0  # higher = more urgent (RT), 0 = best-effort
    runnable: bool = True  # has pending work


class Policy:
    def next_entry(self, tasks: Dict[int, SchedTask]) -> Optional[TimelineEntry]:
        raise NotImplementedError

    def timeline(self, tasks: Dict[int, SchedTask], horizon: int) -> TaskTimeline:
        raise NotImplementedError


class RoundRobinPolicy(Policy):
    """Equal timeslices in fixed order — the paper's default (matches the
    time-sharing behavior of commodity GPUs)."""

    def __init__(self, quantum_us: float = 5_000.0):
        self.quantum_us = quantum_us
        self._rr: List[int] = []

    def _order(self, tasks: Dict[int, SchedTask]) -> List[int]:
        ids = [t for t in sorted(tasks) if tasks[t].runnable]
        for t in ids:
            if t not in self._rr:
                self._rr.append(t)
        self._rr = [t for t in self._rr if t in ids]
        return self._rr

    def next_entry(self, tasks):
        order = self._order(tasks)
        if not order:
            return None
        tid = order[0]
        self._rr = self._rr[1:] + [tid]  # rotate
        return TimelineEntry(tid, self.quantum_us)

    def timeline(self, tasks, horizon: int = 0) -> TaskTimeline:
        order = self._order(tasks)
        horizon = horizon or 2 * max(len(order), 1)
        entries = [
            TimelineEntry(order[i % len(order)], self.quantum_us)
            for i in range(horizon)
        ] if order else []
        return TaskTimeline(entries)


class PriorityPolicy(Policy):
    """Strict priority with RR among equals; RT preempts BE on arrival."""

    def __init__(self, quantum_us: float = 5_000.0, rt_quantum_us: float = 2_000.0):
        self.quantum_us = quantum_us
        self.rt_quantum_us = rt_quantum_us
        self._rr = RoundRobinPolicy(quantum_us)

    def _split(self, tasks):
        rt = {t: s for t, s in tasks.items() if s.priority > 0 and s.runnable}
        be = {t: s for t, s in tasks.items() if s.priority == 0 and s.runnable}
        return rt, be

    def next_entry(self, tasks):
        rt, be = self._split(tasks)
        if rt:
            tid = min(rt)  # deterministic among RT
            return TimelineEntry(tid, self.rt_quantum_us)
        if be:
            return self._rr.next_entry(be)
        return None

    def timeline(self, tasks, horizon: int = 0) -> TaskTimeline:
        rt, be = self._split(tasks)
        entries: List[TimelineEntry] = []
        for tid in sorted(rt):
            entries.append(TimelineEntry(tid, self.rt_quantum_us))
        be_tl = self._rr.timeline(be, horizon or 2 * max(len(be), 1))
        entries.extend(be_tl.entries)
        return TaskTimeline(entries)
