"""MSched core: proactive memory scheduling for accelerator multitasking.

The paper's contribution as a composable library — see DESIGN.md.
"""
from repro.core.hardware import PLATFORMS, RTX3080, RTX5080, TPU_V5E  # noqa: F401
from repro.core.hbm import HBMPool  # noqa: F401
from repro.core.memory_manager import Coordinator, TaskHelper  # noqa: F401
from repro.core.opt import (  # noqa: F401
    PlannedAccess,
    belady_reference,
    belady_reference_scan,
    build_plan,
)
from repro.core.pages import (  # noqa: F401
    AddressSpace,
    RunSet,
    expand_runs,
    merge_runs,
    pages_to_runs,
)
from repro.core.planner import RunPlan, plan_switch  # noqa: F401
from repro.core.predictor import (  # noqa: F401
    AllocationPredictor,
    OraclePredictor,
    TemplatePredictor,
    evaluate_accuracy,
)
from repro.core.profiler import profile_programs  # noqa: F401
from repro.core.scheduler import PriorityPolicy, RoundRobinPolicy  # noqa: F401
from repro.core.simulator import (  # noqa: F401
    AdmissionController,
    RequestRecord,
    SimResult,
    SimState,
    TaskArrival,
    simulate,
)
from repro.core.templates import analyze_traces, template_mix_table  # noqa: F401
from repro.core.timeline import TaskTimeline, TimelineEntry  # noqa: F401
