"""Online working-set predictors (paper §5).

``TemplatePredictor`` is MSched's predictor: it evaluates the offline-derived
formulas on the live launch arguments (microsecond-scale, pure arithmetic) and
attaches page-aligned predictions to each command.

``AllocationPredictor`` is the naive baseline (§5.1): every pointer-looking
argument is expanded to its entire containing allocation — near-zero false
negatives, catastrophic false positives (up to 99.7% for LLMs, Table 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set

from repro.core.commands import Command, KERNEL
from repro.core.pages import AddressSpace, Extent, merge_extents
from repro.core.templates import KernelDescriptor, PTR_MIN


class Predictor:
    def predict_extents(self, cmd: Command) -> List[Extent]:
        raise NotImplementedError

    def predict_pages(self, cmd: Command, space: AddressSpace) -> Set[int]:
        return space.pages_of(self.predict_extents(cmd))

    def annotate(self, cmd: Command, space: Optional[AddressSpace] = None) -> Command:
        """Attach predicted extents; with ``space``, also decode the page
        order once and cache it on the command (run-length form). Any
        re-annotation replaces both, so the cache can never go stale."""
        cmd.predicted_extents = self.predict_extents(cmd)
        cmd.predicted_page_runs = (
            space.page_runs_of_extents(cmd.predicted_extents)
            if space is not None
            else None
        )
        return cmd


class TemplatePredictor(Predictor):
    def __init__(self, descriptors: Dict[str, KernelDescriptor]):
        self.descriptors = descriptors
        # launches repeat the same (kernel, args) shapes across iterations;
        # the formulas are pure, so their output is memoizable
        self._memo: Dict[tuple, List[Extent]] = {}

    def predict_extents(self, cmd: Command) -> List[Extent]:
        if cmd.kind != KERNEL:
            return list(cmd.true_extents)  # memcpy: explicit API semantics
        key = (cmd.name, cmd.args)
        ext = self._memo.get(key)
        if ext is None:
            desc = self.descriptors.get(cmd.name)
            ext = [] if desc is None else merge_extents(desc.predict_extents(cmd.args))
            if len(self._memo) >= 65536:
                self._memo.clear()
            self._memo[key] = ext
        return ext


class AllocationPredictor(Predictor):
    def __init__(self, space: AddressSpace):
        self.space = space

    def predict_extents(self, cmd: Command) -> List[Extent]:
        if cmd.kind != KERNEL:
            return list(cmd.true_extents)
        out: List[Extent] = []
        for a in cmd.args:
            if a >= PTR_MIN:
                buf = self.space.find_buffer(int(a))
                if buf is not None:
                    out.append((buf.base, buf.size))
        return merge_extents(out)


class OraclePredictor(Predictor):
    """Ground truth (the paper's *Ideal* baseline input)."""

    def predict_extents(self, cmd: Command) -> List[Extent]:
        return list(cmd.true_extents)


# --------------------------------------------------------------------------
# Accuracy accounting (Table 1 methodology: kernel-level F− / F+ over pages)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class AccuracyStats:
    true_pages: int = 0
    missed_pages: int = 0  # false negatives
    pred_pages: int = 0
    wrong_pages: int = 0  # false positives

    @property
    def false_negative_pct(self) -> float:
        return 100.0 * self.missed_pages / self.true_pages if self.true_pages else 0.0

    @property
    def false_positive_pct(self) -> float:
        return 100.0 * self.wrong_pages / self.pred_pages if self.pred_pages else 0.0


def evaluate_accuracy(
    predictor: Predictor,
    commands: Iterable[Command],
    space: AddressSpace,
) -> AccuracyStats:
    stats = AccuracyStats()
    for cmd in commands:
        if cmd.kind != KERNEL:
            continue
        true_pages = space.pages_of(cmd.true_extents)
        pred_pages = predictor.predict_pages(cmd, space)
        stats.true_pages += len(true_pages)
        stats.pred_pages += len(pred_pages)
        stats.missed_pages += len(true_pages - pred_pages)
        stats.wrong_pages += len(pred_pages - true_pages)
    return stats
