"""Offline kernel profiler (paper §5.2, the NVBit analogue).

Runs workload programs in instrumented mode and records, per kernel, every
invocation's launch arguments, touched extents, and latency into a
``TraceStore``. The memory analyzer then fits the templates offline ("can be
integrated into the compiler or executed during installation").
"""
from __future__ import annotations

from typing import Sequence

from repro.core.trace import TraceStore
from repro.core.workloads import TaskProgram


def profile_programs(
    programs: Sequence[TaskProgram], iters: int = 4
) -> TraceStore:
    store = TraceStore()
    for prog in programs:
        for it in range(iters):
            for cmd in prog.iteration(it):
                store.record(cmd, space=prog.space)
    return store
