"""Belady-OPT planning over the reconstructed global access sequence (§6.2).

The coordinator merges each task's *local* future command sequence (from the
per-process helpers) with the scheduler's timeline to obtain the global order
in which pages will be touched. Two artifacts come out of it:

  * ``timeslice_page_groups`` — the page set touched within each timeline
    entry, in timeline order. Walking these groups in *reverse* and madvising
    each to the eviction-list tail leaves the list head holding exactly the
    pages unreferenced for the longest time: Belady's OPT order (Fig. 4).
  * ``first_access_order`` — pages of the next timeslice ordered by first
    access, used by the migration pipeline for *early execution* (§6.3).

``belady_reference`` is an explicit OPT cache simulator used by tests and the
*Ideal* baseline to prove the list mechanism achieves the optimal migration
volume.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.timeline import TaskTimeline


@dataclasses.dataclass
class PlannedAccess:
    task_id: int
    seq_no: int  # command sequence number within the task
    pages: List[int]  # page first-touch order within the command
    latency_us: float


@dataclasses.dataclass
class OptPlan:
    timeslice_page_groups: List[Set[int]]  # one per timeline entry
    first_access_order: List[int]  # next timeslice, de-duplicated
    global_sequence: List[List[int]]  # per global command, page lists


def build_plan(
    timeline: TaskTimeline,
    task_futures: Dict[int, Sequence[PlannedAccess]],
) -> OptPlan:
    """Reconstruct the global access sequence by walking the timeline and
    consuming each task's future commands up to its allocated timeslice."""
    cursors = {tid: 0 for tid in task_futures}
    groups: List[Set[int]] = []
    global_seq: List[List[int]] = []
    first_order: List[int] = []
    first_seen: Set[int] = set()

    for i, entry in enumerate(timeline):
        group: Set[int] = set()
        budget = entry.timeslice_us
        future = task_futures.get(entry.task_id, ())
        cur = cursors.get(entry.task_id, 0)
        while cur < len(future) and budget > 0:
            acc = future[cur]
            group.update(acc.pages)
            global_seq.append(list(acc.pages))
            if i == 0:
                for p in acc.pages:
                    if p not in first_seen:
                        first_seen.add(p)
                        first_order.append(p)
            budget -= acc.latency_us
            cur += 1
        cursors[entry.task_id] = cur
        groups.append(group)
    return OptPlan(groups, first_order, global_seq)


def belady_eviction_order(plan: OptPlan, resident: Sequence[int]) -> List[int]:
    """Expected eviction order under the madvise-walk: pages never referenced
    in the horizon first, then by *decreasing* distance to next use."""
    next_use: Dict[int, int] = {}
    for i, group in enumerate(plan.timeslice_page_groups):
        for p in group:
            next_use.setdefault(p, i)
    inf = len(plan.timeslice_page_groups) + 1
    return sorted(
        resident,
        key=lambda p: -next_use.get(p, inf),
    )


def belady_reference(
    accesses: Sequence[Sequence[int]],
    capacity: int,
    initially_resident: Optional[Set[int]] = None,
) -> Tuple[int, int]:
    """Exact Belady OPT cache simulation over a page-access sequence.

    Returns (misses, evictions) — the minimum achievable migration volume.
    """
    flat: List[int] = []
    for group in accesses:
        flat.extend(group)
    # next-use index table
    next_use: Dict[int, List[int]] = {}
    for i, p in enumerate(flat):
        next_use.setdefault(p, []).append(i)
    for lst in next_use.values():
        lst.reverse()  # pop() yields the next upcoming index

    resident: Set[int] = set(initially_resident or ())
    misses = evictions = 0
    for i, p in enumerate(flat):
        uses = next_use[p]
        while uses and uses[-1] <= i:
            uses.pop()
        if p in resident:
            continue
        misses += 1
        if len(resident) >= capacity:
            # evict the resident page with the farthest next use
            victim, dist = None, -1.0
            for q in resident:
                lst = next_use.get(q)
                while lst and lst[-1] <= i:
                    lst.pop()
                d = lst[-1] if lst else float("inf")
                if d > dist:
                    dist, victim = d, q
                    if d == float("inf"):
                        break
            resident.remove(victim)
            evictions += 1
        resident.add(p)
    return misses, evictions
