"""Belady-OPT planning over the reconstructed global access sequence (§6.2).

The coordinator merges each task's *local* future command sequence (from the
per-process helpers) with the scheduler's timeline to obtain the global order
in which pages will be touched. Two artifacts come out of it:

  * ``timeslice_page_groups`` — the page set touched within each timeline
    entry, in timeline order. Walking these groups in *reverse* and madvising
    each to the eviction-list tail leaves the list head holding exactly the
    pages unreferenced for the longest time: Belady's OPT order (Fig. 4).
  * ``first_access_order`` — pages of the next timeslice ordered by first
    access, used by the migration pipeline for *early execution* (§6.3).

``belady_reference`` is an explicit OPT cache simulator used by tests and the
*Ideal* baseline to prove the list mechanism achieves the optimal migration
volume. It evicts via a lazy max-heap on next-use (O(log R) per miss);
``belady_reference_scan`` preserves the original O(R)-per-miss victim scan as
the equivalence reference.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.core.pages import PageRun, expand_runs, pages_to_runs
from repro.core.timeline import TaskTimeline


@dataclasses.dataclass
class PlannedAccess:
    task_id: int
    seq_no: int  # command sequence number within the task (absolute launch index)
    pages: Optional[List[int]]  # page first-touch order; None when runs-backed
    latency_us: float
    # run-length form of the same first-touch order; the incremental planner
    # fills this from the command's annotate-time cache and leaves ``pages``
    # unmaterialized.
    runs: Optional[Tuple[PageRun, ...]] = None

    def page_runs(self) -> Tuple[PageRun, ...]:
        if self.runs is None:
            self.runs = pages_to_runs(self.pages or [])
        return self.runs

    def page_list(self) -> List[int]:
        if self.pages is None:
            self.pages = expand_runs(self.runs or ())
        return self.pages


@dataclasses.dataclass
class OptPlan:
    timeslice_page_groups: List[Set[int]]  # one per timeline entry
    first_access_order: List[int]  # next timeslice, de-duplicated
    global_sequence: List[List[int]]  # per global command, page lists


def build_plan(
    timeline: TaskTimeline,
    task_futures: Dict[int, Sequence[PlannedAccess]],
) -> OptPlan:
    """Reconstruct the global access sequence by walking the timeline and
    consuming each task's future commands up to its allocated timeslice."""
    cursors = {tid: 0 for tid in task_futures}
    groups: List[Set[int]] = []
    global_seq: List[List[int]] = []
    first_order: List[int] = []
    first_seen: Set[int] = set()

    for i, entry in enumerate(timeline):
        group: Set[int] = set()
        budget = entry.timeslice_us
        future = task_futures.get(entry.task_id, ())
        cur = cursors.get(entry.task_id, 0)
        while cur < len(future) and budget > 0:
            acc = future[cur]
            pages = acc.page_list()
            group.update(pages)
            global_seq.append(list(pages))
            if i == 0:
                for p in pages:
                    if p not in first_seen:
                        first_seen.add(p)
                        first_order.append(p)
            budget -= acc.latency_us
            cur += 1
        cursors[entry.task_id] = cur
        groups.append(group)
    return OptPlan(groups, first_order, global_seq)


def belady_eviction_order(plan: OptPlan, resident: Iterable[int]) -> List[int]:
    """Expected eviction order under the madvise-walk: pages never referenced
    in the horizon first, then by *decreasing* distance to next use.

    ``resident`` may be any iterable — in particular the pool's lazy
    ``iter_eviction()`` view, so OPT-path callers never copy the full
    resident list just to re-sort it."""
    next_use: Dict[int, int] = {}
    for i, group in enumerate(plan.timeslice_page_groups):
        for p in group:
            next_use.setdefault(p, i)
    inf = len(plan.timeslice_page_groups) + 1
    return sorted(
        resident,
        key=lambda p: -next_use.get(p, inf),
    )


def belady_reference(
    accesses: Sequence[Sequence[int]],
    capacity: int,
    initially_resident: Optional[Set[int]] = None,
) -> Tuple[int, int]:
    """Exact Belady OPT cache simulation over a page-access sequence.

    Returns (misses, evictions) — the minimum achievable migration volume.

    Victim selection uses a lazy max-heap keyed on next-use index, making a
    miss O(log R) instead of the O(R) residency scan of
    :func:`belady_reference_scan`. Finite next-use indices are unique (each
    access position names one page), and never-referenced pages are mutually
    interchangeable, so the (misses, evictions) counts are identical to the
    scan for any tie-breaking choice.
    """
    flat: List[int] = []
    for group in accesses:
        flat.extend(group)
    n = len(flat)
    inf = n + 1
    # next occurrence of flat[i]'s page strictly after position i
    nxt = [inf] * n
    last: Dict[int, int] = {}
    for i in range(n - 1, -1, -1):
        nxt[i] = last.get(flat[i], inf)
        last[flat[i]] = i

    resident: Set[int] = set(initially_resident or ())
    next_of: Dict[int, int] = {}  # current next-use per resident page
    heap: List[Tuple[int, int]] = []  # (-next_use, page), lazily invalidated
    for q in resident:
        next_of[q] = last.get(q, inf)  # ``last`` now holds first occurrences
        heapq.heappush(heap, (-next_of[q], q))

    misses = evictions = 0
    for i, p in enumerate(flat):
        if p in resident:
            next_of[p] = nxt[i]
            heapq.heappush(heap, (-nxt[i], p))
            continue
        misses += 1
        if len(resident) >= capacity:
            while True:
                negd, q = heapq.heappop(heap)
                if q in resident and next_of[q] == -negd:
                    break
            resident.remove(q)
            evictions += 1
        resident.add(p)
        next_of[p] = nxt[i]
        heapq.heappush(heap, (-nxt[i], p))
    return misses, evictions


def belady_reference_scan(
    accesses: Sequence[Sequence[int]],
    capacity: int,
    initially_resident: Optional[Set[int]] = None,
) -> Tuple[int, int]:
    """Original O(n·R) Belady OPT simulation (linear victim scan). Kept as
    the straightforward reference that :func:`belady_reference` must match."""
    flat: List[int] = []
    for group in accesses:
        flat.extend(group)
    # next-use index table
    next_use: Dict[int, List[int]] = {}
    for i, p in enumerate(flat):
        next_use.setdefault(p, []).append(i)
    for lst in next_use.values():
        lst.reverse()  # pop() yields the next upcoming index

    resident: Set[int] = set(initially_resident or ())
    misses = evictions = 0
    for i, p in enumerate(flat):
        uses = next_use[p]
        while uses and uses[-1] <= i:
            uses.pop()
        if p in resident:
            continue
        misses += 1
        if len(resident) >= capacity:
            # evict the resident page with the farthest next use
            victim, dist = None, -1.0
            for q in resident:
                lst = next_use.get(q)
                while lst and lst[-1] <= i:
                    lst.pop()
                d = lst[-1] if lst else float("inf")
                if d > dist:
                    dist, victim = d, q
                    if d == float("inf"):
                        break
            resident.remove(victim)
            evictions += 1
        resident.add(p)
    return misses, evictions
