"""Fleet-wide invariant auditing: prove the memory accounting survives
failures.

The simulator's pools, cores, and cluster directories each keep redundant
views of the same state (LRU chain vs. sorted index, linger flags vs.
directory entries, staging intervals vs. host budget). In steady state the
views agree by construction; a *failure* — GPU loss, link flap, task crash —
is exactly the kind of event that can silently break one view while the
others limp on. :class:`InvariantAuditor` cross-checks them, read-only, so
tests (and ``simulate_cluster(..., audit=True)``) can assert at every
failure boundary that no page was duplicated, leaked, or double-freed.

Everything here is strictly observational: auditing never mutates a pool,
directory, or core, so an audited run is bit-for-bit identical to an
unaudited one.
"""
from __future__ import annotations

from typing import Iterable, List, Optional, Sequence

from repro.core.pages import (
    PageRun,
    merge_runs,
    pages_to_runs,
    run_page_count,
    subtract_runs,
)


class InvariantViolation(AssertionError):
    """Raised by :class:`InvariantAuditor` (and the ``audit_*`` helpers)
    when a cross-check fails. Subclasses ``AssertionError`` so plain
    ``pytest.raises(AssertionError)`` also catches it."""


def _resident_runs(pool) -> List[PageRun]:
    """Merged resident runs of either pool kind, via public-ish state."""
    if getattr(pool, "RUN_NATIVE", False):
        return [(s.start, s.stop) for s in pool._segs]
    return list(pages_to_runs(sorted(pool._list)))


def audit_pool(pool, name: str = "pool") -> List[str]:
    """Page-conservation checks on one HBM pool. Returns human-readable
    violation strings (empty = clean)."""
    bad: List[str] = []
    used = pool.used
    if used < 0:
        bad.append(f"{name}: negative resident count {used}")
    if used > pool.capacity:
        bad.append(f"{name}: resident {used} exceeds capacity {pool.capacity}")
    if getattr(pool, "RUN_NATIVE", False):
        # chain vs. count
        chain = pool.eviction_runs()
        chain_pages = sum(e - s for s, e in chain)
        if chain_pages != pool._count:
            bad.append(
                f"{name}: LRU chain holds {chain_pages} pages but _count is "
                f"{pool._count}"
            )
        # chain vs. sorted index (same segments, as multisets)
        index = [(s.start, s.stop) for s in pool._segs]
        if sorted(chain) != sorted(index):
            bad.append(
                f"{name}: chain segments {sorted(chain)[:4]}... disagree "
                f"with index {sorted(index)[:4]}..."
            )
        # index sorted, aligned, disjoint
        if pool._starts != [s for s, _ in index]:
            bad.append(f"{name}: _starts out of sync with segment index")
        if any(a >= b for a, b in index):
            bad.append(f"{name}: empty/inverted segment in index")
        if any(
            index[i][1] > index[i + 1][0] for i in range(len(index) - 1)
        ):
            bad.append(f"{name}: overlapping segments in index")
    else:
        if len(pool._list) != used:
            bad.append(f"{name}: paged list/count mismatch")
    # every resident page must belong to some registered task span
    spans = merge_runs(list(pool._task_spans.values()))
    orphans = subtract_runs(_resident_runs(pool), spans)
    if orphans:
        bad.append(
            f"{name}: {run_page_count(orphans)} resident pages outside every "
            f"registered task span (e.g. {orphans[0]})"
        )
    return bad


def audit_core(core) -> List[str]:
    """Per-core coherence checks (pool included)."""
    name = core.name
    bad = audit_pool(core.pool, f"{name}.pool")
    if core.failed:
        # a failed core must be fully quiescent — fail() surrendered
        # everything, and nothing may have been injected since
        if core.tasks or core.waiting or core.pending:
            bad.append(f"{name}: failed core still holds work")
        if core.pool.used != 0:
            bad.append(
                f"{name}: failed core still has {core.pool.used} resident "
                f"pages"
            )
        if core.lingering:
            bad.append(f"{name}: failed core still flags linger copies")
        if core._warm_runs:
            bad.append(f"{name}: failed core still holds warm runs")
        return bad
    queued_ids = {ev.program.task_id for ev in core.pending} | {
        ev.program.task_id for ev, _rec, _pages in core.waiting
    }
    stale_warm = set(core._warm_runs) - queued_ids
    if stale_warm:
        bad.append(
            f"{name}: warm runs held for non-queued tasks {sorted(stale_warm)}"
        )
    waiting_pages = sum(pages for _ev, _rec, pages in core.waiting)
    if waiting_pages != core._waiting_pages:
        bad.append(
            f"{name}: _waiting_pages {core._waiting_pages} != queue sum "
            f"{waiting_pages}"
        )
    for tid in core.lingering:
        if tid in core.tasks:
            bad.append(f"{name}: task {tid} both running and lingering")
        if tid not in core.pool._task_spans:
            bad.append(
                f"{name}: lingering task {tid} has no registered span "
                f"(double-free?)"
            )
    for rec in core.records:
        if rec.finished_us is not None and rec.rejected:
            bad.append(
                f"{name}: task {rec.task_id} both finished and rejected"
            )
    return bad


class InvariantAuditor:
    """Cross-layer auditor for a (possibly single-GPU) fleet.

    Wire it with whatever layers exist — ``topology``/``fabric``/``vault``
    are optional — and call :meth:`check` at interesting boundaries. With
    ``raise_on_violation`` (the default) the first dirty check raises
    :class:`InvariantViolation` listing every violation found; otherwise
    violations accumulate in :attr:`violations` for later assertion.
    """

    def __init__(
        self,
        cores: Sequence,
        topology=None,
        fabric=None,
        vault=None,
        control=None,
        raise_on_violation: bool = True,
    ):
        self.cores = list(cores)
        self.topology = topology
        self.fabric = fabric
        self.vault = vault
        # ControlPlane or None: while the coordinator is down the directory
        # is legitimately empty but linger copies survive on the cores, so
        # the orphaned-copy reverse check is suspended (recovery must close
        # the window — replay rebuilds the entries or reclaims the copies,
        # and the post-recovery audit enforces it again)
        self.control = control
        self.raise_on_violation = raise_on_violation
        self.violations: List[str] = []
        self.checks = 0

    # -- sub-audits ----------------------------------------------------------
    def _audit_directory(self) -> List[str]:
        bad: List[str] = []
        by_name = {c.name: c for c in self.cores}
        entries = self.fabric.directory.entries()
        for e in entries:
            src = by_name.get(e.src)
            if src is None:
                bad.append(f"directory: entry {e.task_id} on unknown GPU {e.src}")
                continue
            if src.failed:
                bad.append(
                    f"directory: entry {e.task_id} lingers on failed GPU "
                    f"{e.src}"
                )
                continue
            if e.task_id not in src.lingering:
                bad.append(
                    f"directory: entry {e.task_id} on {e.src} but the core "
                    f"does not flag it lingering"
                )
            span = src.pool._task_spans.get(e.task_id)
            if span is None:
                bad.append(
                    f"directory: entry {e.task_id} has no span on {e.src}"
                )
            elif subtract_runs(e.runs, [span]):
                bad.append(
                    f"directory: entry {e.task_id} hints runs outside its "
                    f"span on {e.src}"
                )
            if e.dst not in by_name:
                bad.append(
                    f"directory: entry {e.task_id} targets unknown GPU {e.dst}"
                )
        # reverse: every flagged linger copy must be findable via the
        # directory (else it is unreclaimable — a leak). Suspended while
        # the coordinator is down: the directory died with it, and the
        # copies are exactly what recovery must re-hint or reclaim.
        if self.control is not None and self.control.down:
            return bad
        hinted = {(e.src, e.task_id) for e in entries}
        for core in self.cores:
            for tid in core.lingering:
                if (core.name, tid) not in hinted:
                    bad.append(
                        f"{core.name}: linger flag for task {tid} has no "
                        f"directory entry (orphaned copy)"
                    )
        return bad

    def _audit_topology(self, now: float) -> List[str]:
        bad: List[str] = []
        topo = self.topology
        for start, end, nbytes in topo._staged:
            if nbytes <= 0:
                bad.append(f"topology: staged interval with {nbytes} bytes")
            if end < start:
                bad.append(
                    f"topology: staged interval ends before it starts "
                    f"({start} > {end})"
                )
        in_flight = topo.host_staged_bytes(now)
        if in_flight > topo.host_dram_bytes:
            bad.append(
                f"topology: {in_flight} staged bytes exceed the host budget "
                f"{topo.host_dram_bytes}"
            )
        links = {l.key() for l in topo.links()}
        for key, factor in topo._degraded.items():
            if key not in links:
                bad.append(f"topology: degrade entry for unknown link {key}")
            if not 0.0 <= factor <= 1.0:
                bad.append(f"topology: degrade factor {factor} out of range")
        if topo.deferred < 0:
            bad.append("topology: negative deferral count")
        return bad

    def _audit_vault(self) -> List[str]:
        bad: List[str] = []
        for tid, cks in self.vault._by_task.items():
            if len(cks) > self.vault.keep:
                bad.append(
                    f"vault: {len(cks)} checkpoints kept for task {tid} "
                    f"(cap {self.vault.keep})"
                )
            for ck in cks:
                if ck.task_id != tid:
                    bad.append(f"vault: checkpoint keyed under wrong task {tid}")
                if ck.ready_us < ck.taken_us:
                    bad.append(
                        f"vault: checkpoint for task {tid} ready before taken"
                    )
                if ck.nbytes < 0 or ck.completed < 0:
                    bad.append(f"vault: negative checkpoint fields for {tid}")
        return bad

    # -- entry point ---------------------------------------------------------
    def check(self, now: float = 0.0, where: str = "") -> List[str]:
        """Run every wired audit. Returns (and records) the violations."""
        self.checks += 1
        bad: List[str] = []
        for core in self.cores:
            bad.extend(audit_core(core))
        if self.fabric is not None:
            bad.extend(self._audit_directory())
        if self.topology is not None:
            bad.extend(self._audit_topology(now))
        if self.vault is not None:
            bad.extend(self._audit_vault())
        if bad:
            tagged = [f"[{where or 'audit'}@{now:.0f}us] {b}" for b in bad]
            self.violations.extend(tagged)
            if self.raise_on_violation:
                raise InvariantViolation(
                    f"{len(bad)} invariant violation(s):\n  "
                    + "\n  ".join(tagged)
                )
        return bad
