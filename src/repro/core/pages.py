"""Page-granular address spaces and buffers.

Each task owns a virtual address space; buffers are page-aligned allocations
(the analogue of cudaMalloc regions / framework memory pools). Extents are
(start, size) byte ranges; pages are integer page indices global to a task.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Set, Tuple

Extent = Tuple[int, int]  # (start byte, size in bytes)


@dataclasses.dataclass(frozen=True)
class Buffer:
    buf_id: int
    base: int
    size: int
    label: str = ""

    @property
    def end(self) -> int:
        return self.base + self.size

    def slice(self, offset: int, size: int) -> Extent:
        assert 0 <= offset and offset + size <= self.size, (offset, size, self.size)
        return (self.base + offset, size)


class AddressSpace:
    """Bump allocator with page alignment (frees recycle only at the end)."""

    def __init__(self, page_size: int = 4096, base: int = 0x10_0000_0000):
        self.page_size = page_size
        self._next = base
        self._next_id = 0
        self.buffers: Dict[int, Buffer] = {}

    def malloc(self, size: int, label: str = "") -> Buffer:
        aligned = _round_up(size, self.page_size)
        buf = Buffer(self._next_id, self._next, size, label)
        self.buffers[buf.buf_id] = buf
        self._next += aligned
        self._next_id += 1
        return buf

    def free(self, buf: Buffer) -> None:
        self.buffers.pop(buf.buf_id, None)

    def find_buffer(self, addr: int) -> Buffer | None:
        """Containing allocation for a pointer (allocation-granularity path)."""
        for b in self.buffers.values():
            if b.base <= addr < b.end:
                return b
        return None

    # -- page helpers -------------------------------------------------------
    def pages_of_extent(self, ext: Extent) -> range:
        start, size = ext
        if size <= 0:
            return range(0)
        first = start // self.page_size
        last = (start + size - 1) // self.page_size
        return range(first, last + 1)

    def pages_of(self, extents: Iterable[Extent]) -> Set[int]:
        pages: Set[int] = set()
        for ext in extents:
            pages.update(self.pages_of_extent(ext))
        return pages

    def total_pages(self) -> int:
        return sum(_round_up(b.size, self.page_size) for b in self.buffers.values()) // self.page_size


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def merge_extents(extents: List[Extent]) -> List[Extent]:
    """Coalesce overlapping/adjacent byte ranges (canonical trace form)."""
    if not extents:
        return []
    xs = sorted(extents)
    out = [list(xs[0])]
    for s, sz in xs[1:]:
        cs, csz = out[-1]
        if s <= cs + csz:
            out[-1][1] = max(cs + csz, s + sz) - cs
        else:
            out.append([s, sz])
    return [tuple(e) for e in out]


def extents_bytes(extents: Iterable[Extent]) -> int:
    return sum(sz for _, sz in merge_extents(list(extents)))
