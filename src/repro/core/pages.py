"""Page-granular address spaces and buffers.

Each task owns a virtual address space; buffers are page-aligned allocations
(the analogue of cudaMalloc regions / framework memory pools). Extents are
(start, size) byte ranges; pages are integer page indices global to a task.

Page *runs* are the run-length form used by the planning hot path: a run is a
half-open ``(first_page, stop_page)`` interval, so GiB-scale working sets are
carried around as a handful of intervals instead of huge int sets.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_left, bisect_right
from typing import Dict, Iterable, List, Sequence, Set, Tuple

Extent = Tuple[int, int]  # (start byte, size in bytes)
PageRun = Tuple[int, int]  # half-open page interval (first_page, stop_page)


@dataclasses.dataclass(frozen=True)
class Buffer:
    buf_id: int
    base: int
    size: int
    label: str = ""

    @property
    def end(self) -> int:
        return self.base + self.size

    def slice(self, offset: int, size: int) -> Extent:
        assert 0 <= offset and offset + size <= self.size, (offset, size, self.size)
        return (self.base + offset, size)


class AddressSpace:
    """Bump allocator with page alignment (frees recycle only at the end)."""

    def __init__(self, page_size: int = 4096, base: int = 0x10_0000_0000):
        self.page_size = page_size
        self._base = base
        self._next = base
        self._next_id = 0
        self.buffers: Dict[int, Buffer] = {}
        # sorted-by-base index for O(log n) pointer lookups (bases are
        # monotonic under the bump allocator, so malloc is a plain append)
        self._bases: List[int] = []
        self._by_base: List[Buffer] = []
        # memoized extent-tuple -> page-run decode (see page_runs_of_extents)
        self._run_cache: Dict[Tuple[Extent, ...], Tuple[PageRun, ...]] = {}

    def malloc(self, size: int, label: str = "") -> Buffer:
        aligned = _round_up(size, self.page_size)
        buf = Buffer(self._next_id, self._next, size, label)
        self.buffers[buf.buf_id] = buf
        self._bases.append(buf.base)
        self._by_base.append(buf)
        self._next += aligned
        self._next_id += 1
        return buf

    def page_span(self) -> PageRun:
        """Half-open page interval covering every allocation ever made in
        this space (bump allocator: the span never shrinks)."""
        return (self._base // self.page_size, _round_up(self._next, self.page_size) // self.page_size)

    def release(self) -> PageRun:
        """Tear the space down (task exit): drop every buffer and cache and
        return the page span the owner must reclaim from the HBM pool."""
        span = self.page_span()
        self.buffers.clear()
        self._bases.clear()
        self._by_base.clear()
        self._run_cache.clear()
        return span

    def free(self, buf: Buffer) -> None:
        if self.buffers.pop(buf.buf_id, None) is None:
            return
        # zero-size allocations can share a base; match on buf_id
        i = bisect_left(self._bases, buf.base)
        while i < len(self._bases) and self._bases[i] == buf.base:
            if self._by_base[i].buf_id == buf.buf_id:
                del self._bases[i]
                del self._by_base[i]
                return
            i += 1

    def find_buffer(self, addr: int) -> Buffer | None:
        """Containing allocation for a pointer (allocation-granularity path)."""
        i = bisect_right(self._bases, addr) - 1
        if i >= 0:
            b = self._by_base[i]
            if addr < b.end:
                return b
        return None

    # -- page helpers -------------------------------------------------------
    def pages_of_extent(self, ext: Extent) -> range:
        start, size = ext
        if size <= 0:
            return range(0)
        first = start // self.page_size
        last = (start + size - 1) // self.page_size
        return range(first, last + 1)

    def pages_of(self, extents: Iterable[Extent]) -> Set[int]:
        pages: Set[int] = set()
        for ext in extents:
            pages.update(self.pages_of_extent(ext))
        return pages

    def total_pages(self) -> int:
        return sum(_round_up(b.size, self.page_size) for b in self.buffers.values()) // self.page_size

    def page_runs_of_extents(
        self, extents: Iterable[Extent]
    ) -> Tuple[PageRun, ...]:
        """Deduplicated page runs in first-access order.

        Run-length equivalent of the per-page first-touch walk: expanding the
        result with :func:`expand_runs` yields exactly the page order the old
        per-page decode produced, but the decode itself never materializes
        individual pages. Results are memoized per extent tuple — repeated
        command shapes (the common case for iteration-structured workloads)
        decode once per address space, which is what makes `annotate()`-time
        caching O(1) amortized.
        """
        key = extents if isinstance(extents, tuple) else tuple(extents)
        cached = self._run_cache.get(key)
        if cached is not None:
            return cached
        seen = RunSet()
        out: List[PageRun] = []
        ps = self.page_size
        for start, size in key:
            if size <= 0:
                continue
            out.extend(seen.add(start // ps, (start + size - 1) // ps + 1))
        runs = tuple(out)
        if len(self._run_cache) >= 8192:
            self._run_cache.clear()
        self._run_cache[key] = runs
        return runs


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def merge_extents(extents: List[Extent]) -> List[Extent]:
    """Coalesce overlapping/adjacent byte ranges (canonical trace form)."""
    if not extents:
        return []
    xs = sorted(extents)
    out = [list(xs[0])]
    for s, sz in xs[1:]:
        cs, csz = out[-1]
        if s <= cs + csz:
            out[-1][1] = max(cs + csz, s + sz) - cs
        else:
            out.append([s, sz])
    return [tuple(e) for e in out]


def extents_bytes(extents: Iterable[Extent]) -> int:
    return sum(sz for _, sz in merge_extents(list(extents)))


# --------------------------------------------------------------------------
# Page-run (interval) helpers — the planning hot path's working currency
# --------------------------------------------------------------------------


def merge_runs(runs: Iterable[PageRun]) -> List[PageRun]:
    """Coalesce page runs into a sorted disjoint interval list. Expanding the
    result yields the same pages as ``sorted(set(expand_runs(runs)))``."""
    xs = sorted(runs)
    if not xs:
        return []
    out: List[PageRun] = []
    cs, ce = xs[0]
    for s, e in xs:
        if s <= ce:
            if e > ce:
                ce = e
        else:
            out.append((cs, ce))
            cs, ce = s, e
    out.append((cs, ce))
    return out


def expand_runs(runs: Iterable[PageRun]) -> List[int]:
    return [p for s, e in runs for p in range(s, e)]


def run_page_count(runs: Iterable[PageRun]) -> int:
    return sum(e - s for s, e in runs)


def pages_to_runs(pages: Sequence[int]) -> Tuple[PageRun, ...]:
    """Order-preserving coalesce of an explicit page list (ascending
    consecutive pages fold into one run)."""
    runs: List[List[int]] = []
    for p in pages:
        if runs and p == runs[-1][1]:
            runs[-1][1] = p + 1
        else:
            runs.append([p, p + 1])
    return tuple((s, e) for s, e in runs)


def intersect_runs(
    runs: Iterable[PageRun], other: Sequence[PageRun]
) -> List[PageRun]:
    """Sub-runs of ``runs`` covered by ``other`` (which must be sorted and
    disjoint — e.g. a ``merge_runs`` result), preserving the order of
    ``runs``. The run-level form of ``[p for p in pages if p in other]``."""
    starts = [s for s, _ in other]
    out: List[PageRun] = []
    for a, b in runs:
        i = max(0, bisect_right(starts, a) - 1)
        while i < len(other) and other[i][0] < b:
            s, e = other[i]
            lo, hi = max(a, s), min(b, e)
            if lo < hi:
                out.append((lo, hi))
            i += 1
    return out


def subtract_runs(
    runs: Iterable[PageRun], remove: Sequence[PageRun]
) -> List[PageRun]:
    """Sub-runs of ``runs`` *not* covered by ``remove`` (sorted, disjoint),
    preserving the order of ``runs`` — the order-preserving complement of
    :func:`intersect_runs`."""
    starts = [s for s, _ in remove]
    out: List[PageRun] = []
    for a, b in runs:
        cur = a
        i = bisect_right(starts, a) - 1
        if i < 0 or remove[i][1] <= a:
            i += 1
        while cur < b and i < len(remove) and remove[i][0] < b:
            s, e = remove[i]
            if s > cur:
                out.append((cur, s))
            cur = max(cur, min(e, b))
            i += 1
        if cur < b:
            out.append((cur, b))
    return out


def clip_runs(runs: Iterable[PageRun], max_pages: int) -> List[PageRun]:
    """First ``max_pages`` pages of ``runs`` in order (run-level equivalent
    of ``expand_runs(runs)[:max_pages]``)."""
    out: List[PageRun] = []
    left = max_pages
    for s, e in runs:
        if left <= 0:
            break
        take = min(left, e - s)
        out.append((s, s + take))
        left -= take
    return out


class RunSet:
    """Sorted disjoint interval set with insert-and-report-new support.

    ``add`` inserts a half-open page interval and returns the sub-runs that
    were *not* already present, in ascending order — exactly the pieces a
    first-touch dedup walk would have appended page by page. All operations
    are O(log n + k) in the number of stored intervals.
    """

    def __init__(self) -> None:
        self._starts: List[int] = []
        self._stops: List[int] = []

    def add(self, start: int, stop: int) -> List[PageRun]:
        if start >= stop:
            return []
        starts, stops = self._starts, self._stops
        i = bisect_right(starts, start) - 1
        lo = i if (i >= 0 and stops[i] >= start) else i + 1
        new_runs: List[PageRun] = []
        cur = start
        j = lo
        while j < len(starts) and starts[j] <= stop:
            if starts[j] > cur:
                new_runs.append((cur, starts[j]))
            cur = max(cur, stops[j])
            j += 1
        if cur < stop:
            new_runs.append((cur, stop))
        if lo < j:
            starts[lo:j] = [min(start, starts[lo])]
            stops[lo:j] = [max(stop, stops[j - 1 if j > lo else lo])]
        else:
            starts[lo:lo] = [start]
            stops[lo:lo] = [stop]
        return new_runs

    def __contains__(self, page: int) -> bool:
        i = bisect_right(self._starts, page) - 1
        return i >= 0 and page < self._stops[i]

    def runs(self) -> List[PageRun]:
        return list(zip(self._starts, self._stops))
