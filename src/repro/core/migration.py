"""Page-migration pipeline timing model (§6.3, Figs. 5 & 9).

Baseline driver behavior serializes unmap → D2H evict → H2D populate → map per
page, so the effective swap bandwidth is the harmonic-style combination of the
two directions. MSched drives eviction on one copy engine and population on
the other, exploiting the full-duplex interconnect; the overlapped pipeline is
capped by the host-side ceiling (``duplex_cap_gbps`` — the paper's measured
63.5 GB/s on RTX 5080, limited by the Intel chiplet NoC).

``plan_population`` additionally returns per-page ready times in first-access
order, which the simulator uses for *early execution*: a kernel starts as soon
as its own pages are resident rather than after the whole working set lands.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.hardware import Platform
from repro.core.pages import PageRun, pages_to_runs, run_page_count


@dataclasses.dataclass
class MigrationResult:
    evict_bytes: int
    populate_bytes: int
    total_us: float
    page_ready_us: Dict[int, float]  # page -> time (relative to start)

    @property
    def populated_runs(self) -> List[PageRun]:
        """Populated pages (dict insertion order = first-access order) as
        order-preserving runs."""
        return list(pages_to_runs(list(self.page_ready_us.keys())))

    def ready_view(self, base: float) -> Optional["DictReadyView"]:
        """Run-queryable view over the per-page dict (legacy-planning path)."""
        if not self.page_ready_us:
            return None
        return DictReadyView(self.page_ready_us, base)


class DictReadyView:
    """Ready-time view backed by the legacy per-page dict. O(pages) per
    query — only the preserved ``planning="legacy"`` benchmark path uses it."""

    def __init__(self, page_ready_us: Dict[int, float], base: float):
        self._d = page_ready_us
        self._base = base
        self.global_max = base + max(page_ready_us.values())

    def max_ready(self, runs: Sequence[PageRun]) -> Optional[float]:
        best = None
        get = self._d.get
        for s, e in runs:
            for p in range(s, e):
                t = get(p)
                if t is not None and (best is None or t > best):
                    best = t
        return None if best is None else self._base + best


class IndexReadyView:
    """Ready-time view over populated runs whose per-page ready time is
    monotone in population order: the max over any page subset is the value
    at the subset's largest population index, so one command costs
    O(runs · log populated-runs) instead of O(pages)."""

    def __init__(
        self,
        populated_runs: Sequence[PageRun],
        value_fn: Callable[[int], float],
        n_pages: int,
    ):
        order = sorted(range(len(populated_runs)), key=lambda i: populated_runs[i][0])
        self._starts = [populated_runs[i][0] for i in order]
        self._stops = [populated_runs[i][1] for i in order]
        offsets = []
        off = 0
        for s, e in populated_runs:
            offsets.append(off)
            off += e - s
        self._offsets = [offsets[i] for i in order]
        self._value = value_fn
        self.global_max = value_fn(n_pages - 1) if n_pages else float("-inf")

    def max_ready(self, runs: Sequence[PageRun]) -> Optional[float]:
        starts, stops, offs = self._starts, self._stops, self._offsets
        best_idx = -1
        for a, b in runs:
            j = bisect_right(starts, a) - 1
            if j < 0:
                j = 0
            while j < len(starts) and starts[j] < b:
                if stops[j] > a:
                    hi = stops[j] if stops[j] < b else b
                    idx = offs[j] + (hi - starts[j]) - 1
                    if idx > best_idx:
                        best_idx = idx
                j += 1
        return None if best_idx < 0 else self._value(best_idx)


@dataclasses.dataclass
class RunMigration:
    """Run-native migration plan: per-page ready times in population order,
    without a per-page dict (``times[i]`` is the i-th populated page's ready
    time relative to the switch, computed with the exact float rounding of
    the per-page pipeline loop)."""

    evict_bytes: int
    populate_bytes: int
    total_us: float
    populated_runs: List[PageRun]  # first-access order
    times: Optional[np.ndarray]  # float64, len == populated page count

    @property
    def page_ready_us(self) -> Dict[int, float]:
        """Materialized per-page dict (tests/debug; O(pages))."""
        out: Dict[int, float] = {}
        i = 0
        for s, e in self.populated_runs:
            for p in range(s, e):
                out[p] = float(self.times[i])
                i += 1
        return out

    def ready_view(self, base: float) -> Optional[IndexReadyView]:
        if self.times is None or not len(self.times):
            return None
        times = self.times
        return IndexReadyView(
            self.populated_runs, lambda i: float(base + times[i]), len(times)
        )


@dataclasses.dataclass
class PeerGroup:
    """One peer-HBM source tier of a tiered migration: ``runs`` stream from
    ``src`` (a peer GPU's HBM, over its direct NVLink edge) at
    ``rate_bytes_per_us`` — the *fluid-share* rate the link graph granted the
    fetch, so a contended edge prices slower. Ready times are linear fill in
    population order, independent of the host-link pipeline (NVLink traffic
    never touches the PCIe root port)."""

    src: str
    runs: List[PageRun]
    rate_bytes_per_us: float

    def page_count(self) -> int:
        return run_page_count(self.runs)


class CombinedReadyView:
    """Max-composition of per-tier ready views: a command is ready when its
    last page has landed, whichever tier carried it."""

    def __init__(self, views: Sequence):
        self._views = [v for v in views if v is not None]
        self.global_max = max(
            (v.global_max for v in self._views), default=float("-inf")
        )

    def max_ready(self, runs: Sequence[PageRun]) -> Optional[float]:
        best = None
        for v in self._views:
            t = v.max_ready(runs)
            if t is not None and (best is None or t > best):
                best = t
        return best


@dataclasses.dataclass
class TieredMigration:
    """Migration plan whose populated pages come from multiple source tiers:
    the *host* tier (standard pipelined D2H-evict/H2D-populate recurrence —
    a :class:`RunMigration`) plus zero or more *peer-HBM* tiers
    (:class:`PeerGroup`s fetched over NVLink). Exposes the same surface as
    ``RunMigration`` (``total_us`` / ``populated_runs`` / ``ready_view``), so
    ``SwitchReport.migration`` and the simulator are tier-agnostic."""

    host: RunMigration
    peers: List[PeerGroup]
    page_size: int

    @property
    def evict_bytes(self) -> int:
        return self.host.evict_bytes

    @property
    def peer_bytes(self) -> int:
        return sum(g.page_count() for g in self.peers) * self.page_size

    @property
    def populate_bytes(self) -> int:
        return self.host.populate_bytes + self.peer_bytes

    @property
    def populated_runs(self) -> List[PageRun]:
        out = list(self.host.populated_runs)
        for g in self.peers:
            out.extend(g.runs)
        return out

    def _peer_times(self, g: PeerGroup) -> np.ndarray:
        n = g.page_count()
        return np.arange(1, n + 1, dtype=np.float64) * (
            self.page_size / g.rate_bytes_per_us
        )

    @property
    def total_us(self) -> float:
        peer_last = max(
            (float(self._peer_times(g)[-1]) for g in self.peers if g.page_count()),
            default=0.0,
        )
        return max(self.host.total_us, peer_last)

    def ready_view(self, base: float) -> Optional[CombinedReadyView]:
        views = [self.host.ready_view(base)]
        for g in self.peers:
            times = self._peer_times(g)
            if not len(times):
                continue
            views.append(
                IndexReadyView(
                    g.runs, lambda i, t=times: float(base + t[i]), len(times)
                )
            )
        views = [v for v in views if v is not None]
        return CombinedReadyView(views) if views else None


def migrate_time_us(
    platform: Platform,
    evict_bytes: int,
    populate_bytes: int,
    pipelined: bool = True,
) -> float:
    d2h = platform.d2h_gbps * 1e3  # bytes/us
    h2d = platform.h2d_gbps * 1e3
    if not pipelined:
        return evict_bytes / d2h + populate_bytes / h2d
    t_overlap = max(evict_bytes / d2h, populate_bytes / h2d)
    # host-side duplex ceiling
    cap = platform.duplex_cap_gbps * 1e3
    t_cap = (evict_bytes + populate_bytes) / cap
    return max(t_overlap, t_cap)


def effective_swap_bandwidth_gbps(
    platform: Platform, bytes_each_way: int, pipelined: bool
) -> float:
    t = migrate_time_us(platform, bytes_each_way, bytes_each_way, pipelined)
    return (2 * bytes_each_way) / (t * 1e3) if t else 0.0


def plan_population(
    platform: Platform,
    populate_pages: Sequence[int],
    evict_count: int,
    pipelined: bool = True,
    page_size: int = 0,
) -> MigrationResult:
    """Timing for one proactive migration batch.

    ``populate_pages`` must be in predicted first-access order. Eviction of
    ``evict_count`` victims runs on CE0; population on CE1. Unpipelined mode
    (ablation) serializes: all evictions complete before population starts.
    """
    ps = page_size or platform.page_size
    d2h = platform.d2h_gbps * 1e3
    h2d = platform.h2d_gbps * 1e3
    cap = platform.duplex_cap_gbps * 1e3

    evict_bytes = evict_count * ps
    pop_bytes = len(populate_pages) * ps
    ready: Dict[int, float] = {}

    if not pipelined:
        t0 = evict_bytes / d2h
        for i, p in enumerate(populate_pages):
            ready[p] = t0 + (i + 1) * ps / h2d
        total = t0 + pop_bytes / h2d
        return MigrationResult(evict_bytes, pop_bytes, total, ready)

    # pipelined: population of page i can begin once space exists; we model
    # space reclamation at D2H rate and transfer at the capped duplex rate.
    # effective per-direction rate under the duplex ceiling:
    both_active_rate = min(h2d, cap - min(d2h, cap / 2.0)) if cap < d2h + h2d else h2d
    t = 0.0
    for i, p in enumerate(populate_pages):
        # page i needs i+1 pages of space reclaimed (if evicting at all)
        space_ready = ((i + 1) * ps / d2h) if evict_count > 0 and i < evict_count else 0.0
        t = max(t, space_ready) + ps / both_active_rate
        ready[p] = t
    total = max(t, evict_bytes / d2h)
    return MigrationResult(evict_bytes, pop_bytes, total, ready)


def plan_population_runs(
    platform: Platform,
    populate_runs: Sequence[PageRun],
    evict_count: int,
    pipelined: bool = True,
    page_size: int = 0,
) -> RunMigration:
    """Run-native :func:`plan_population`: identical per-page ready times
    (same float rounding as the scalar recurrence), computed as numpy arrays
    over population indices instead of a Python loop over a page dict."""
    ps = page_size or platform.page_size
    d2h = platform.d2h_gbps * 1e3
    h2d = platform.h2d_gbps * 1e3
    cap = platform.duplex_cap_gbps * 1e3

    n = run_page_count(populate_runs)
    evict_bytes = evict_count * ps
    pop_bytes = n * ps
    if n == 0:
        total = evict_bytes / d2h if not pipelined else max(0.0, evict_bytes / d2h)
        return RunMigration(evict_bytes, pop_bytes, total, [], None)

    idx = np.arange(1, n + 1, dtype=np.int64)  # (i + 1)

    if not pipelined:
        t0 = evict_bytes / d2h
        times = t0 + (idx * ps) / h2d
        total = t0 + pop_bytes / h2d
        return RunMigration(evict_bytes, pop_bytes, total, list(populate_runs), times)

    both_active_rate = min(h2d, cap - min(d2h, cap / 2.0)) if cap < d2h + h2d else h2d
    step = ps / both_active_rate
    s = np.zeros(n)
    if evict_count > 0:
        e = min(evict_count, n)
        s[:e] = (idx[:e] * ps) / d2h
    times = _max_add_scan(s, step)
    total = max(float(times[-1]), evict_bytes / d2h)
    return RunMigration(evict_bytes, pop_bytes, total, list(populate_runs), times)


def _max_add_scan(s: np.ndarray, step: float) -> np.ndarray:
    """Exact vectorization of ``t_i = max(t_{i-1}, s_i) + step`` (t_{-1}=0).

    The recurrence alternates between two regimes — *stalled* (``s`` wins
    every step, so ``t_i = s_i + step`` elementwise) and *streaming* (``t``
    wins, a pure sequential accumulation, which ``np.add.accumulate``
    reproduces with the same left-to-right rounding). Each regime is solved
    in one vector op and the boundary found by comparison, so the result is
    bit-for-bit the scalar loop's at O(regime switches) vector passes; a
    pathological alternation falls back to the scalar loop."""
    n = len(s)
    t = np.empty(n)
    i = 0
    prev = 0.0
    for _ in range(64):
        if i >= n:
            return t
        # streaming candidate: pure accumulation from prev
        arr = np.full(n - i + 1, step)
        arr[0] = prev
        cand = np.add.accumulate(arr)[1:]
        t_prev = np.empty(n - i)
        t_prev[0] = prev
        t_prev[1:] = cand[:-1]
        viol = s[i:] > t_prev
        if not viol.any():
            t[i:] = cand
            return t
        j = int(np.argmax(viol))
        t[i : i + j] = cand[:j]
        i += j
        # stalled candidate: t_k = s_k + step while s keeps outpacing t
        tr = s[i:] + step
        ok = s[i + 1 :] > tr[:-1]
        if ok.all():
            m = n - i
        else:
            m = int(np.argmin(ok)) + 1
        t[i : i + m] = tr[:m]
        i += m
        prev = float(t[i - 1])
    # degenerate regime flapping: scalar reference (still exact)
    while i < n:
        prev = max(prev, float(s[i])) + step
        t[i] = prev
        i += 1
    return t
