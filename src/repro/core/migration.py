"""Page-migration pipeline timing model (§6.3, Figs. 5 & 9).

Baseline driver behavior serializes unmap → D2H evict → H2D populate → map per
page, so the effective swap bandwidth is the harmonic-style combination of the
two directions. MSched drives eviction on one copy engine and population on
the other, exploiting the full-duplex interconnect; the overlapped pipeline is
capped by the host-side ceiling (``duplex_cap_gbps`` — the paper's measured
63.5 GB/s on RTX 5080, limited by the Intel chiplet NoC).

``plan_population`` additionally returns per-page ready times in first-access
order, which the simulator uses for *early execution*: a kernel starts as soon
as its own pages are resident rather than after the whole working set lands.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.hardware import Platform


@dataclasses.dataclass
class MigrationResult:
    evict_bytes: int
    populate_bytes: int
    total_us: float
    page_ready_us: Dict[int, float]  # page -> time (relative to start)


def migrate_time_us(
    platform: Platform,
    evict_bytes: int,
    populate_bytes: int,
    pipelined: bool = True,
) -> float:
    d2h = platform.d2h_gbps * 1e3  # bytes/us
    h2d = platform.h2d_gbps * 1e3
    if not pipelined:
        return evict_bytes / d2h + populate_bytes / h2d
    t_overlap = max(evict_bytes / d2h, populate_bytes / h2d)
    # host-side duplex ceiling
    cap = platform.duplex_cap_gbps * 1e3
    t_cap = (evict_bytes + populate_bytes) / cap
    return max(t_overlap, t_cap)


def effective_swap_bandwidth_gbps(
    platform: Platform, bytes_each_way: int, pipelined: bool
) -> float:
    t = migrate_time_us(platform, bytes_each_way, bytes_each_way, pipelined)
    return (2 * bytes_each_way) / (t * 1e3) if t else 0.0


def plan_population(
    platform: Platform,
    populate_pages: Sequence[int],
    evict_count: int,
    pipelined: bool = True,
    page_size: int = 0,
) -> MigrationResult:
    """Timing for one proactive migration batch.

    ``populate_pages`` must be in predicted first-access order. Eviction of
    ``evict_count`` victims runs on CE0; population on CE1. Unpipelined mode
    (ablation) serializes: all evictions complete before population starts.
    """
    ps = page_size or platform.page_size
    d2h = platform.d2h_gbps * 1e3
    h2d = platform.h2d_gbps * 1e3
    cap = platform.duplex_cap_gbps * 1e3

    evict_bytes = evict_count * ps
    pop_bytes = len(populate_pages) * ps
    ready: Dict[int, float] = {}

    if not pipelined:
        t0 = evict_bytes / d2h
        for i, p in enumerate(populate_pages):
            ready[p] = t0 + (i + 1) * ps / h2d
        total = t0 + pop_bytes / h2d
        return MigrationResult(evict_bytes, pop_bytes, total, ready)

    # pipelined: population of page i can begin once space exists; we model
    # space reclamation at D2H rate and transfer at the capped duplex rate.
    # effective per-direction rate under the duplex ceiling:
    both_active_rate = min(h2d, cap - min(d2h, cap / 2.0)) if cap < d2h + h2d else h2d
    t = 0.0
    for i, p in enumerate(populate_pages):
        # page i needs i+1 pages of space reclaimed (if evicting at all)
        space_ready = ((i + 1) * ps / d2h) if evict_count > 0 and i < evict_count else 0.0
        t = max(t, space_ready) + ps / both_active_rate
        ready[p] = t
    total = max(t, evict_bytes / d2h)
    return MigrationResult(evict_bytes, pop_bytes, total, ready)
