"""Task scheduling timeline — the scheduler↔memory-manager contract (§6.1).

"The task scheduling timeline is an ordered sequence of task entries and
allocated timeslices akin to the run queue in OS schedulers. … It provides the
ground truth for the future execution timeline — which task will execute, for
how long, and in what order." It is the *Rosetta Stone* that lets the memory
manager reconstruct the global future access sequence and enforce OPT.
"""
from __future__ import annotations

import dataclasses
from typing import List


@dataclasses.dataclass(frozen=True)
class TimelineEntry:
    task_id: int
    timeslice_us: float


class TaskTimeline:
    def __init__(self, entries: List[TimelineEntry]):
        self.entries = list(entries)

    def __iter__(self):
        return iter(self.entries)

    def __len__(self):
        return len(self.entries)

    def reversed(self):
        return reversed(self.entries)

    def horizon_us(self) -> float:
        return sum(e.timeslice_us for e in self.entries)

    def task_ids(self):
        return [e.task_id for e in self.entries]
