"""Demand-paging (CUDA UM) baseline emulation (§2.3, §3).

TPUs cannot page-fault, so the UM baseline is an explicit cost model
calibrated from the paper's measurements: 31.79 µs per fault (96 % control
plane), LRU eviction from the driver list head, and a UM-style neighborhood
prefetch (fault groups) that explains why migrated volume exceeds
faults × 4 KiB (paper Fig. 6c).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Set, Tuple

from repro.core.hardware import Platform
from repro.core.hbm import HBMPool


@dataclasses.dataclass
class FaultStats:
    faults: int = 0
    migrated_pages: int = 0
    evicted_pages: int = 0
    fault_us: float = 0.0


class DemandPager:
    def __init__(self, platform: Platform, pool: HBMPool, page_size: int = 0):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size  # simulation page
        self.stats = FaultStats()

    def access(self, pages: List[int]) -> float:
        """Serve a kernel's accesses; returns the stall time in µs.

        UM migrates in 64 KiB fault groups (4 KiB faulting page + 60 KiB
        neighborhood), one CPU-serviced fault per group. When the simulation
        page is larger than a fault group, a missing page costs
        ``page/64KiB`` faults; when smaller, a fault brings in the whole
        aligned group (which is why UM's migrated volume exceeds
        faults × 4 KiB — paper Fig. 6c).
        """
        stall = 0.0
        p_sz = self.page_size
        group_bytes = 4096 * max(1, self.platform.um_prefetch_pages)
        # the UM fault path serializes eviction and population on one engine:
        # effective data rate is the harmonic combination of both directions
        d2h = self.platform.d2h_gbps * 1e3
        h2d_only = self.platform.h2d_gbps * 1e3
        h2d = 1.0 / (1.0 / d2h + 1.0 / h2d_only)  # bytes/us
        batch = max(1, self.platform.um_evict_batch_bytes // p_sz)
        if p_sz >= group_bytes:
            units_per_page = (p_sz + group_bytes - 1) // group_bytes
            for p in pages:
                if self.pool.resident(p):
                    self.pool.touch(p)
                    continue
                self.stats.faults += units_per_page
                stall += units_per_page * self.platform.fault_total_us
                # non-faulting remainder of each group moves at batched H2D
                stall += (p_sz - units_per_page * 4096) / h2d
                self._batch_evict(batch)
                evicted = self.pool.populate(p)
                self.stats.evicted_pages += len(evicted)
                self.stats.migrated_pages += 1
            return stall
        # 4 KiB simulation pages: fault + neighborhood prefetch
        group = group_bytes // p_sz
        for p in pages:
            if self.pool.resident(p):
                self.pool.touch(p)
                continue
            self.stats.faults += 1
            stall += self.platform.fault_total_us
            self._batch_evict(batch)
            base = (p // group) * group
            extra = [
                q
                for q in range(base, base + group)
                if q != p and not self.pool.resident(q)
            ]
            for q in [p] + extra:
                evicted = self.pool.populate(q)
                self.stats.evicted_pages += len(evicted)
                self.stats.migrated_pages += 1
            stall += len(extra) * p_sz / h2d
        return stall

    def _batch_evict(self, batch: int) -> None:
        """Driver chunk reclamation: when HBM is full, free a whole batch."""
        if self.pool.free_pages() > 0:
            return
        n = min(batch, self.pool.resident_count() - 1)
        for _ in range(max(n, 1)):
            self.pool.evict_head()
            self.stats.evicted_pages += 1
