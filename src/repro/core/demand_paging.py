"""Demand-paging (CUDA UM) baseline emulation (§2.3, §3).

TPUs cannot page-fault, so the UM baseline is an explicit cost model
calibrated from the paper's measurements: 31.79 µs per fault (96 % control
plane), LRU eviction from the driver list head, and a UM-style neighborhood
prefetch (fault groups) that explains why migrated volume exceeds
faults × 4 KiB (paper Fig. 6c).

``access_runs`` is the hot path: with a run-native pool it services faults
per missing *run* — fault counts, prefetch volume, and batched evictions in
closed form over interval arithmetic — instead of one Python-loop iteration
per page. Stall times are accumulated with the exact same per-page float
rounding as the scalar loop (``np.add.accumulate`` is sequential), so the
vectorized path is bit-for-bit identical to ``access`` + ``HBMPoolPaged``.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.hardware import Platform
from repro.core.hbm import HBMPool
from repro.core.pages import PageRun, run_page_count

# below this many pages a plain Python loop beats the numpy setup cost
_VECTOR_MIN_PAGES = 256


@dataclasses.dataclass
class FaultStats:
    faults: int = 0
    migrated_pages: int = 0
    evicted_pages: int = 0
    fault_us: float = 0.0


class DemandPager:
    def __init__(self, platform: Platform, pool: HBMPool, page_size: int = 0):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size  # simulation page
        self.stats = FaultStats()

    # -- shared rate math ----------------------------------------------------
    def _rates(self) -> Tuple[float, float, int]:
        """(h2d bytes/µs for the serialized UM fault path, group bytes,
        eviction batch pages)."""
        # the UM fault path serializes eviction and population on one engine:
        # effective data rate is the harmonic combination of both directions
        d2h = self.platform.d2h_gbps * 1e3
        h2d_only = self.platform.h2d_gbps * 1e3
        h2d = 1.0 / (1.0 / d2h + 1.0 / h2d_only)  # bytes/us
        group_bytes = 4096 * max(1, self.platform.um_prefetch_pages)
        batch = max(1, self.platform.um_evict_batch_bytes // self.page_size)
        return h2d, group_bytes, batch

    # -- per-page reference path --------------------------------------------
    def access(self, pages: List[int]) -> float:
        """Serve a kernel's accesses; returns the stall time in µs.

        UM migrates in 64 KiB fault groups (4 KiB faulting page + 60 KiB
        neighborhood), one CPU-serviced fault per group. When the simulation
        page is larger than a fault group, a missing page costs
        ``page/64KiB`` faults; when smaller, a fault brings in the whole
        aligned group (which is why UM's migrated volume exceeds
        faults × 4 KiB — paper Fig. 6c).

        This is the straightforward per-page implementation; the simulator's
        hot path uses :meth:`access_runs`, which must stay bit-for-bit
        equivalent (see tests/core/test_run_native_pool.py).
        """
        stall = 0.0
        p_sz = self.page_size
        h2d, group_bytes, batch = self._rates()
        if p_sz >= group_bytes:
            units_per_page = (p_sz + group_bytes - 1) // group_bytes
            for p in pages:
                if self.pool.resident(p):
                    self.pool.touch(p)
                    continue
                self.stats.faults += units_per_page
                stall += units_per_page * self.platform.fault_total_us
                # non-faulting remainder of each group moves at batched H2D
                stall += (p_sz - units_per_page * 4096) / h2d
                self._batch_evict(batch)
                evicted = self.pool.populate(p)
                self.stats.evicted_pages += len(evicted)
                self.stats.migrated_pages += 1
            return stall
        # 4 KiB simulation pages: fault + neighborhood prefetch
        group = group_bytes // p_sz
        for p in pages:
            if self.pool.resident(p):
                self.pool.touch(p)
                continue
            self.stats.faults += 1
            stall += self.platform.fault_total_us
            self._batch_evict(batch)
            base = (p // group) * group
            extra = [
                q
                for q in range(base, base + group)
                if q != p and not self.pool.resident(q)
            ]
            for q in [p] + extra:
                evicted = self.pool.populate(q)
                self.stats.evicted_pages += len(evicted)
                self.stats.migrated_pages += 1
            stall += len(extra) * p_sz / h2d
        return stall

    def _batch_evict(self, batch: int) -> None:
        """Driver chunk reclamation: when HBM is full, free a whole batch
        (never the entire pool — with a single resident page ``populate``'s
        own head eviction makes room, so the batch path stands down)."""
        if self.pool.free_pages() > 0:
            return
        n = min(batch, self.pool.resident_count() - 1)
        for _ in range(n):
            self.pool.evict_head()
            self.stats.evicted_pages += 1

    # -- run-native path -----------------------------------------------------
    def access_runs(self, runs: Sequence[PageRun]) -> float:
        """Serve a kernel's accesses given as first-touch-ordered page runs.

        With a run-native pool, resident stretches are LRU-spliced and each
        missing stretch is serviced in closed form (fault count, prefetch
        volume, batched evictions); with a paged pool this falls back to the
        per-page reference, making ``pool="paged"`` a full-stack equivalence
        mode."""
        if not getattr(self.pool, "RUN_NATIVE", False):
            return self.access([p for s, e in runs for p in range(s, e)])
        p_sz = self.page_size
        h2d, group_bytes, batch = self._rates()
        if p_sz >= group_bytes:
            return self._access_runs_coarse(runs, h2d, group_bytes, batch)
        return self._access_runs_grouped(runs, h2d, group_bytes, batch)

    def _access_runs_coarse(
        self, runs: Sequence[PageRun], h2d: float, group_bytes: int, batch: int
    ) -> float:
        """Simulation page >= fault group: each missing page is its own
        fault unit; a whole missing run is one arithmetic event."""
        pool = self.pool
        p_sz = self.page_size
        units = (p_sz + group_bytes - 1) // group_bytes
        x = units * self.platform.fault_total_us  # per-page stall add #1
        y = (p_sz - units * 4096) / h2d  # per-page stall add #2
        cap = pool.capacity
        stall = 0.0
        for a, b in runs:
            cur = a
            while cur < b:
                if pool.resident(cur):
                    hi = min(b, pool.resident_stretch_end(cur))
                    pool.touch_runs(((cur, hi),))
                    cur = hi
                    continue
                hi = self._missing_stretch_end(cur, b)
                L = hi - cur
                self.stats.faults += units * L
                self.stats.migrated_pages += L
                stall = _acc2(stall, x, y, L)
                # room-filling prefix needs no eviction at all
                first = min(L, pool.free_pages())
                if first:
                    pool._populate_run(cur, cur + first)
                rem = L - first
                if rem:
                    self._evict_and_fill(cur + first, hi, batch, cap)
                cur = hi
        return stall

    def _evict_and_fill(self, c: int, d: int, batch: int, cap: int) -> None:
        """Insert missing run ``[c, d)`` into a *full* pool with the driver's
        batch-reclaim rhythm: each time HBM fills, a batch of
        ``min(batch, capacity-1)`` head pages is reclaimed, then population
        resumes — the closed form of per-page ``_batch_evict`` + ``populate``
        (victims are the first k·e pages of [list order, run order], which
        can reach into the run itself when it exceeds capacity)."""
        pool = self.pool
        rem = d - c
        e = min(batch, cap - 1)
        if e == 0:
            # capacity-1 pool: every insert displaces the previous page
            pool._evict_head_run(1)
            pool.evictions += rem - 1
            pool.populations += rem - 1
            pool._populate_run(d - 1, d)
            self.stats.evicted_pages += rem
            return
        k = -(-rem // e)
        total = k * e
        self.stats.evicted_pages += total
        if total <= cap:
            pool._evict_head_run(total)
            pool._populate_run(c, d)
        else:
            # the run outsizes HBM: its own leading pages are populated and
            # reclaimed before the tail lands (exactly the per-page dynamics)
            overflow = total - cap
            pool._evict_head_run(cap)
            pool.evictions += overflow
            pool.populations += overflow
            pool._populate_run(c + overflow, d)

    def _access_runs_grouped(
        self, runs: Sequence[PageRun], h2d: float, group_bytes: int, batch: int
    ) -> float:
        """Simulation page < fault group (4 KiB regime): one fault services
        the whole aligned neighborhood, so the event loop advances a fault
        group at a time instead of a page at a time."""
        pool = self.pool
        p_sz = self.page_size
        group = group_bytes // p_sz
        stall = 0.0
        for a, b in runs:
            cur = a
            while cur < b:
                if pool.resident(cur):
                    hi = min(b, pool.resident_stretch_end(cur))
                    pool.touch_runs(((cur, hi),))
                    cur = hi
                    continue
                p = cur
                g0 = (p // group) * group
                g1 = g0 + group
                self.stats.faults += 1
                stall += self.platform.fault_total_us
                if pool.free_pages() == 0:
                    e = min(batch, pool.resident_count() - 1)
                    if e > 0:
                        pool._evict_head_run(e)
                        self.stats.evicted_pages += e
                missing = pool.missing_runs(((g0, g1),))
                n_new = run_page_count(missing)
                # population order: faulting page first, then the still-
                # missing neighborhood ascending
                order: List[PageRun] = [(p, p + 1)]
                for s, e2 in missing:
                    if s <= p < e2:
                        if s < p:
                            order.append((s, p))
                        if p + 1 < e2:
                            order.append((p + 1, e2))
                    else:
                        order.append((s, e2))
                victims = pool.populate_runs(order)
                self.stats.evicted_pages += run_page_count(victims)
                self.stats.migrated_pages += n_new
                stall += (n_new - 1) * p_sz / h2d
                # the rest of this group's pages are usually hits now, but a
                # group that outsizes HBM evicts its own early pages during
                # service — resume the walk and let residency decide
                cur = p + 1
        return stall

    def _missing_stretch_end(self, cur: int, b: int) -> int:
        """End of the non-resident stretch starting at ``cur`` (bounded by
        ``b``), against the pool's current segment index."""
        starts = self.pool._starts
        j = bisect_right(starts, cur)
        return min(b, starts[j]) if j < len(starts) else b


def _acc2(stall: float, x: float, y: float, n: int) -> float:
    """``n`` repetitions of ``stall += x; stall += y`` with per-step float
    rounding — the exact accumulation the per-page loop performs."""
    if n < _VECTOR_MIN_PAGES:
        for _ in range(n):
            stall = stall + x
            stall = stall + y
        return stall
    arr = np.empty(2 * n + 1)
    arr[0] = stall
    arr[1::2] = x
    arr[2::2] = y
    return float(np.add.accumulate(arr)[-1])
