"""GPU command stream model: kernels and memcpys.

From the OS's perspective a GPU task is a sequence of asynchronously-launched
commands (paper §2.1). ``args`` is the flattened 32/64-bit integer view of the
kernel launch arguments (pointers are just big integers; C-structs are sliced
into ints, exactly as the paper's analyzer does). ``true_extents`` is the
ground-truth touched byte ranges — visible only to the *offline* profiler
(the NVBit analogue) and to the simulator, never to the online predictor.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

from repro.core.pages import Extent, PageRun, expand_runs

KERNEL = "kernel"
MEMCPY_H2D = "memcpy_h2d"
MEMCPY_D2H = "memcpy_d2h"


@dataclasses.dataclass
class Command:
    kind: str  # KERNEL | MEMCPY_*
    name: str
    args: Tuple[int, ...]  # flattened int view (pointers + scalars + grid dims)
    latency_us: float  # deterministic execution latency (paper §6: [25,28,39])
    true_extents: List[Extent]  # ground truth (offline/simulation only)
    task_id: int = -1
    seq_no: int = -1
    # attached by the online predictor (per-process helper):
    predicted_extents: Optional[List[Extent]] = None
    # page-order caches: decoded once (at annotate time / first simulated
    # execution), so the planning hot path never re-walks extents.
    # ``predicted_page_runs`` is (re)set by Predictor.annotate().
    predicted_page_runs: Optional[Tuple[PageRun, ...]] = None
    _true_page_runs: Optional[Tuple[PageRun, ...]] = None

    def data_bytes(self) -> int:
        return sum(sz for _, sz in self.true_extents)

    def true_page_runs(self, space) -> Tuple[PageRun, ...]:
        """Ground-truth touched pages as first-access-ordered runs (cached)."""
        if self._true_page_runs is None:
            self._true_page_runs = space.page_runs_of_extents(self.true_extents)
        return self._true_page_runs

    def true_page_list(self, space) -> List[int]:
        """Ground-truth pages in first-access order."""
        return expand_runs(self.true_page_runs(space))


def kernel(name: str, args: Sequence[int], latency_us: float, extents: List[Extent]) -> Command:
    return Command(KERNEL, name, tuple(int(a) for a in args), latency_us, extents)


def memcpy_h2d(dst: Extent, latency_us: float) -> Command:
    """Copy semantics are explicit in the API: dst/size are direct arguments,
    so prediction is trivially exact (paper §5)."""
    return Command(
        MEMCPY_H2D, "memcpy_h2d", (dst[0], dst[1]), latency_us, [dst]
    )


def memcpy_d2h(src: Extent, latency_us: float) -> Command:
    return Command(
        MEMCPY_D2H, "memcpy_d2h", (src[0], src[1]), latency_us, [src]
    )
