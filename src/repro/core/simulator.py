"""Discrete-event execution engine for multitasking under oversubscription.

Runs a set of ``TaskProgram``s under a scheduling policy with one of four
memory backends:

  um      — native demand paging (CUDA UM model; §2.3)
  msched  — proactive memory scheduling: extended context switch with
            timeline-driven OPT placement + pipelined migration (§4–§6)
  ideal   — theoretical optimum: ground-truth working sets, zero control
            plane, full-duplex-cap migration, strict Belady (paper's *Ideal*)
  suv     — single-task static-prefetch baseline (SUV, §7.5): prefetches the
            whole task footprint on switch, oblivious to other tasks

The engine models *early execution* (§6.3): a kernel starts as soon as its own
pages are ready, not when the whole working-set migration finishes.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.commands import Command
from repro.core.demand_paging import DemandPager
from repro.core.hardware import Platform
from repro.core.hbm import HBMPool
from repro.core.memory_manager import Coordinator, TaskHelper
from repro.core.migration import plan_population
from repro.core.pages import AddressSpace
from repro.core.predictor import (
    AllocationPredictor,
    OraclePredictor,
    Predictor,
    TemplatePredictor,
)
from repro.core.profiler import profile_programs
from repro.core.scheduler import Policy, RoundRobinPolicy, SchedTask
from repro.core.templates import analyze_traces
from repro.core.timeline import TaskTimeline
from repro.core.workloads import TaskProgram

MIN_LOOKAHEAD_ITERS = 2  # async launch window (queued-but-not-executed)


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------


class Backend:
    name = "base"

    def on_switch(self, task_id: int, timeline: TaskTimeline, now: float):
        return 0.0, {}

    def on_command(self, cmd: Command, pages: List[int], now: float) -> float:
        return 0.0

    def faults(self) -> int:
        return 0

    def migrated_pages(self) -> int:
        return 0


class UMBackend(Backend):
    name = "um"

    def __init__(self, platform: Platform, pool: HBMPool, page_size: int = 0):
        self.pager = DemandPager(platform, pool, page_size)

    def on_command(self, cmd, pages, now):
        return self.pager.access(pages)

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self.pager.stats.migrated_pages


class MSchedBackend(Backend):
    name = "msched"

    def __init__(
        self,
        platform: Platform,
        pool: HBMPool,
        helpers: Dict[int, TaskHelper],
        pipelined: bool = True,
        control_free: bool = False,
        page_size: int = 0,
        legacy_planning: bool = False,
    ):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.coordinator = Coordinator(
            platform, pool, pipelined=pipelined, page_size=page_size,
            legacy=legacy_planning,
        )
        for h in helpers.values():
            self.coordinator.register(h)
        self.fallback = DemandPager(platform, pool, page_size)  # false negatives
        self.control_free = control_free
        self._migrated = 0

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline)
        self._migrated += report.populated_pages
        ctrl = 0.0 if self.control_free else report.madvise_us
        ready = {
            p: now + ctrl + t for p, t in report.migration.page_ready_us.items()
        }
        return ctrl, ready

    def on_command(self, cmd, pages, now):
        # mispredictions fall back to standard demand paging (§5.2)
        missing = self.pool.missing_pages(pages)
        if not missing:
            return 0.0
        return self.fallback.access(missing)

    def faults(self):
        return self.fallback.stats.faults

    def migrated_pages(self):
        return self._migrated + self.fallback.stats.migrated_pages


class IdealBackend(MSchedBackend):
    """Strict-OPT upper bound: oracle prediction, no control plane, and
    migration at the duplex bandwidth ceiling."""

    name = "ideal"

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline)
        self._migrated += report.populated_pages
        # population at the physically best per-direction rate: the duplex
        # ceiling is shared by concurrent eviction (swap = cap/2 each way,
        # matching the paper's 63.5 GB/s pipelined swap figure)
        rate = min(
            self.platform.h2d_gbps * 1e3, self.platform.duplex_cap_gbps * 1e3 / 2
        )
        ps = self.page_size
        ready = {}
        for i, p in enumerate(report.migration.page_ready_us):
            ready[p] = now + (i + 1) * ps / rate
        return 0.0, ready


class SUVBackend(Backend):
    """Static-analysis single-task prefetch: on switch, prefetch the whole
    footprint of the incoming task (hotness-ordered = buffer order), with no
    awareness of the other tasks' residency or of the schedule."""

    name = "suv"

    def __init__(self, platform: Platform, pool: HBMPool, programs, page_size: int = 0):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.pager = DemandPager(platform, pool, page_size)
        self._task_pages: Dict[int, List[int]] = {}
        for prog in programs:
            pages: List[int] = []
            for b in sorted(prog.space.buffers.values(), key=lambda b: b.base):
                pages.extend(prog.space.pages_of_extent((b.base, b.size)))
            self._task_pages[prog.task_id] = pages
        self._migrated = 0

    def on_switch(self, task_id, timeline, now):
        pages = self._task_pages.get(task_id, [])
        # cap the prefetch at HBM capacity (driver clamps)
        pages = pages[: self.pool.capacity]
        populated, evicted = self.pool.migrate(pages)
        self._migrated += len(populated)
        mig = plan_population(
            self.platform, populated, len(evicted), False, self.page_size
        )
        ready = {p: now + t for p, t in mig.page_ready_us.items()}
        return 0.0, ready

    def on_command(self, cmd, pages, now):
        missing = self.pool.missing_pages(pages)
        return self.pager.access(missing) if missing else 0.0

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self._migrated + self.pager.stats.migrated_pages


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskStats:
    completions: int = 0
    commands: int = 0
    busy_us: float = 0.0
    latencies_us: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    sim_us: float
    per_task: Dict[int, TaskStats]
    faults: int
    migrated_bytes: int
    switches: int
    control_us: float

    def total_completions(self) -> int:
        return sum(t.completions for t in self.per_task.values())

    def throughput_per_s(self) -> float:
        return self.total_completions() / (self.sim_us * 1e-6) if self.sim_us else 0.0

    def latency_percentile_us(
        self, pct: float, task_id: Optional[int] = None
    ) -> float:
        """Request-latency percentile over one task's (or all tasks')
        recorded arrival-to-completion latencies."""
        if task_id is not None:
            xs = sorted(self.per_task[task_id].latencies_us)
        else:
            xs = sorted(
                x for t in self.per_task.values() for x in t.latencies_us
            )
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(pct / 100.0 * len(xs)))]

    def p50_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(50.0, task_id)

    def p99_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(99.0, task_id)


class _RunTask:
    def __init__(
        self,
        prog: TaskProgram,
        helper: Optional[TaskHelper],
        lookahead_us: float = 0.0,
    ):
        self.prog = prog
        self.helper = helper
        self.lookahead_us = lookahead_us
        self.queue: Deque[Command] = deque()
        self.queued_us = 0.0
        self.iter_launched = 0
        self.cmd_in_iter = 0
        self.iter_len = 1
        self.arrivals: Optional[Deque[float]] = None  # RT mode
        self.current_arrival: Optional[float] = None
        self.stats = TaskStats()
        self._refill()

    def _launch_iter(self):
        cmds = self.prog.iteration(self.iter_launched)
        self.iter_len = len(cmds)
        for c in cmds:
            c.seq_no = self.iter_launched
            self.queue.append(c)
            self.queued_us += c.latency_us
            if self.helper is not None:
                self.helper.launch(c)
        self.iter_launched += 1

    def _refill(self):
        # the async launch window must cover at least one full timeslice of
        # future commands for the timeline plan to see the whole working set
        launched_iters = 0
        while (
            launched_iters < MIN_LOOKAHEAD_ITERS
            or self.queued_us < self.lookahead_us
        ):
            self._launch_iter()
            launched_iters += 1
            if launched_iters > 10_000:
                break

    def peek(self) -> Command:
        return self.queue[0]

    def advance(self, now: float) -> bool:
        """Consume one command; returns True when an iteration completed."""
        cmd = self.queue.popleft()
        self.queued_us -= cmd.latency_us
        if self.helper is not None and len(self.helper.queue):
            self.helper.pop()
        self.cmd_in_iter += 1
        done = False
        if self.cmd_in_iter >= self.iter_len:
            self.cmd_in_iter = 0
            self.stats.completions += 1
            done = True
        if len(self.queue) < self.iter_len or self.queued_us < self.lookahead_us:
            self._launch_iter()
        return done

    def runnable(self, now: float) -> bool:
        if self.arrivals is None:
            return True
        if self.current_arrival is not None:
            return True
        while self.arrivals and self.arrivals[0] <= now:
            self.current_arrival = self.arrivals.popleft()
            return True
        return False

    def next_arrival(self) -> Optional[float]:
        if self.arrivals is None or self.current_arrival is not None:
            return None
        return self.arrivals[0] if self.arrivals else None


def make_backend(
    name: str,
    platform: Platform,
    pool: HBMPool,
    programs: Sequence[TaskProgram],
    predictor_kind: str = "template",
    pipelined: bool = True,
    page_size: int = 0,
    planning: str = "incremental",
) -> Tuple[Backend, Dict[int, TaskHelper]]:
    helpers: Dict[int, TaskHelper] = {}
    if name == "um":
        return UMBackend(platform, pool, page_size), helpers
    if name == "suv":
        return SUVBackend(platform, pool, programs, page_size), helpers

    # msched / ideal need per-task helpers with a predictor
    if name == "ideal" or predictor_kind == "oracle":
        predictors: Dict[int, Predictor] = {
            p.task_id: OraclePredictor() for p in programs
        }
    elif predictor_kind == "allocation":
        predictors = {p.task_id: AllocationPredictor(p.space) for p in programs}
    else:  # template: offline profile + analyze (the real MSched flow)
        store = profile_programs(programs, iters=4)
        descriptors = analyze_traces(store)
        predictors = {
            p.task_id: TemplatePredictor(descriptors) for p in programs
        }
    for p in programs:
        helpers[p.task_id] = TaskHelper(p.task_id, p.space, predictors[p.task_id])
    cls = IdealBackend if name == "ideal" else MSchedBackend
    backend = cls(
        platform, pool, helpers, pipelined=pipelined, page_size=page_size,
        legacy_planning=(planning == "legacy"),
    )
    return backend, helpers


def simulate(
    programs: Sequence[TaskProgram],
    platform: Platform,
    backend_name: str = "msched",
    capacity_bytes: Optional[int] = None,
    sim_us: float = 2_000_000.0,
    policy: Optional[Policy] = None,
    predictor_kind: str = "template",
    pipelined: bool = True,
    arrivals: Optional[Dict[int, List[float]]] = None,
    priorities: Optional[Dict[int, int]] = None,
    prepopulate: bool = True,
    planning: str = "incremental",
) -> SimResult:
    page_size = programs[0].space.page_size
    cap_bytes = capacity_bytes or platform.hbm_bytes
    pool = HBMPool(max(1, cap_bytes // page_size))
    backend, helpers = make_backend(
        backend_name, platform, pool, programs, predictor_kind, pipelined,
        page_size, planning,
    )
    cached_decode = planning != "legacy"
    policy = policy or RoundRobinPolicy()

    quantum = getattr(policy, "quantum_us", 5_000.0)
    tasks: Dict[int, _RunTask] = {}
    for prog in programs:
        rt = _RunTask(prog, helpers.get(prog.task_id), lookahead_us=2.2 * quantum)
        if arrivals and prog.task_id in arrivals:
            rt.arrivals = deque(arrivals[prog.task_id])
            rt.current_arrival = None
        tasks[prog.task_id] = rt

    # warm start: fill HBM fairly (tasks ran before the measuring window)
    if prepopulate:
        share = pool.capacity // max(1, len(programs))
        for prog in programs:
            pages: List[int] = []
            for b in sorted(prog.space.buffers.values(), key=lambda b: b.base):
                pages.extend(prog.space.pages_of_extent((b.base, b.size)))
            for p in pages[:share]:
                pool.populate(p)

    t = 0.0
    switches = 0
    control_us = 0.0
    while t < sim_us:
        sched = {
            tid: SchedTask(
                tid,
                priority=(priorities or {}).get(tid, 0),
                runnable=rt.runnable(t),
            )
            for tid, rt in tasks.items()
        }
        entry = policy.next_entry(sched)
        if entry is None:
            # idle until next arrival
            nxt = [rt.next_arrival() for rt in tasks.values()]
            nxt = [x for x in nxt if x is not None]
            if not nxt:
                break
            t = max(t, min(nxt))
            continue
        # the timeline's first entry must be the task about to run —
        # next_entry() already rotated the policy's run queue past it
        timeline = TaskTimeline([entry] + policy.timeline(sched).entries)
        ctrl, ready = backend.on_switch(entry.task_id, timeline, t)
        t += ctrl
        control_us += ctrl
        switches += 1

        rt = tasks[entry.task_id]
        budget = entry.timeslice_us
        slice_start = t
        while budget > 0 and rt.runnable(t):
            cmd = rt.peek()
            # cached run-length decode; the legacy path re-walks the extents
            # per executed command (preserved for the sim-throughput baseline)
            if cached_decode:
                pages = cmd.true_page_list(rt.prog.space)
            else:
                pages = _true_page_order(rt.prog.space, cmd)
            start = t
            if ready:
                ready_get = ready.get
                for p in pages:
                    r = ready_get(p)
                    if r is not None and r > start:
                        start = r
            stall = backend.on_command(cmd, pages, start)
            end = start + stall + cmd.latency_us
            rt.stats.commands += 1
            rt.stats.busy_us += end - t
            budget -= end - t
            t = end
            completed = rt.advance(t)
            if completed and rt.current_arrival is not None:
                rt.stats.latencies_us.append(t - rt.current_arrival)
                rt.current_arrival = None
                # next pending arrival (if already due) picked up by runnable()

    return SimResult(
        sim_us=t,
        per_task={tid: rt.stats for tid, rt in tasks.items()},
        faults=backend.faults(),
        migrated_bytes=backend.migrated_pages() * page_size,
        switches=switches,
        control_us=control_us,
    )


def _true_page_order(space: AddressSpace, cmd: Command) -> List[int]:
    seen = set()
    order = []
    for ext in cmd.true_extents:
        for p in space.pages_of_extent(ext):
            if p not in seen:
                seen.add(p)
                order.append(p)
    return order
