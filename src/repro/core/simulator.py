"""Discrete-event execution engine for multitasking under oversubscription.

Runs a set of ``TaskProgram``s under a scheduling policy with one of four
memory backends:

  um      — native demand paging (CUDA UM model; §2.3)
  msched  — proactive memory scheduling: extended context switch with
            timeline-driven OPT placement + pipelined migration (§4–§6)
  ideal   — theoretical optimum: ground-truth working sets, zero control
            plane, full-duplex-cap migration, strict Belady (paper's *Ideal*)
  suv     — single-task static-prefetch baseline (SUV, §7.5): prefetches the
            whole task footprint on switch, oblivious to other tasks

The engine models *early execution* (§6.3): a kernel starts as soon as its own
pages are ready, not when the whole working-set migration finishes.

Execution is *run-native* end to end: commands carry cached page-run tuples,
residency/ready queries are interval operations, and once a timeslice's
migration has landed the engine *macro-steps* — it verifies the upcoming
command window's merged run group is fully resident once, then advances the
whole window in a tight loop with no per-command backend calls (bit-for-bit
identical results; see EXPERIMENTS.md "The macro-stepping invariant").
``pool="paged"`` swaps in the per-page reference pool for equivalence runs.

The task population is *dynamic*: besides the static ``programs`` set, callers
may supply ``task_events`` — timed :class:`TaskArrival`s whose programs are
admitted (optionally gated by an admission controller), run to completion
(``TaskProgram.total_iterations``), and then retire, tearing down their
address space and returning their HBM pages. With no events configured the
engine is bit-for-bit identical to the static simulator.

The engine itself is the *re-entrant* :class:`SimCore`: ``simulate()`` builds
one core and drives it to the horizon in a single call, while the cluster
scheduler (``repro.cluster``) composes N cores — one per GPU — under one
event loop, advancing each with ``run(until_us, final=False)`` between
cluster events and steering work through the external hooks (``inject`` /
``eject`` / ``steal_waiting``). A 1-GPU cluster therefore reproduces
``simulate()`` bit-for-bit (pinned in tests/cluster/test_cluster_engine.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from itertools import islice
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.commands import Command
from repro.core.demand_paging import DemandPager
from repro.core.hardware import Platform
from repro.core.hbm import HBMPool, make_pool, resident_runs_in
from repro.core.memory_manager import (
    Coordinator,
    TaskHelper,
    predicted_working_set_pages,
)
from repro.core.migration import IndexReadyView, plan_population_runs
from repro.core.pages import AddressSpace, PageRun, clip_runs, pages_to_runs, run_page_count
from repro.core.planner import merged_command_runs
from repro.core.predictor import (
    AllocationPredictor,
    OraclePredictor,
    Predictor,
    TemplatePredictor,
)
from repro.core.profiler import profile_programs
from repro.core.scheduler import Policy, RoundRobinPolicy, SchedTask
from repro.core.templates import analyze_traces
from repro.core.timeline import TaskTimeline
from repro.core.workloads import TaskProgram, footprint_pages

MIN_LOOKAHEAD_ITERS = 2  # async launch window (queued-but-not-executed)


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------


class Backend:
    name = "base"
    # True when executing a fully-resident command still mutates LRU state
    # (demand paging touches pages); the macro-stepper must replicate that
    resident_touch = False
    # True when on_switch reads the scheduling timeline (msched/ideal); the
    # engine skips building the multi-entry timeline otherwise — at serving
    # scale (2 ms TSG quanta, hundreds of tasks) that build dominates UM runs
    uses_timeline = False

    def on_switch(self, task_id: int, timeline: TaskTimeline, now: float):
        """Returns (control_us, ready_view | None). The view answers
        ``max_ready(runs)`` — the time the last-arriving page of ``runs``
        lands — in O(runs) instead of a per-page dict probe."""
        return 0.0, None

    def on_command(
        self, cmd: Command, runs: Sequence[PageRun], now: float
    ) -> float:
        return 0.0

    def admit_task(self, prog: TaskProgram) -> Optional[TaskHelper]:
        """Dynamic task arrival: set up per-task backend state. Returns the
        task's helper when the backend uses one (msched/ideal)."""
        return None

    def retire_task(self, task_id: int) -> None:
        """Dynamic task departure: tear down per-task backend state."""

    def faults(self) -> int:
        return 0

    def migrated_pages(self) -> int:
        return 0

    def switch_info(self) -> Optional[Dict[str, float]]:
        """Telemetry probe: what the last ``on_switch`` moved (populated /
        evicted pages, control time, migration duration). ``None`` for
        backends that do no proactive work at a switch (um). Read only when
        a telemetry hub is attached — never on the untraced hot path."""
        return None


class UMBackend(Backend):
    name = "um"
    resident_touch = True

    def __init__(self, platform: Platform, pool: HBMPool, page_size: int = 0):
        self.pager = DemandPager(platform, pool, page_size)

    def on_command(self, cmd, runs, now):
        return self.pager.access_runs(runs)

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self.pager.stats.migrated_pages


class MSchedBackend(Backend):
    name = "msched"
    uses_timeline = True

    def __init__(
        self,
        platform: Platform,
        pool: HBMPool,
        helpers: Dict[int, TaskHelper],
        pipelined: bool = True,
        control_free: bool = False,
        page_size: int = 0,
        legacy_planning: bool = False,
        predictor_factory: Optional[Callable[[TaskProgram], Predictor]] = None,
    ):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.coordinator = Coordinator(
            platform, pool, pipelined=pipelined, page_size=page_size,
            legacy=legacy_planning,
        )
        for h in helpers.values():
            self.coordinator.register(h)
        self.fallback = DemandPager(platform, pool, page_size)  # false negatives
        self.control_free = control_free
        self.predictor_factory = predictor_factory
        self._migrated = 0
        self.last_report = None  # latest SwitchReport, for switch_info()

    def admit_task(self, prog):
        if self.predictor_factory is None:
            raise RuntimeError("backend built without a predictor factory")
        helper = TaskHelper(prog.task_id, prog.space, self.predictor_factory(prog))
        self.coordinator.register(helper)
        return helper

    def retire_task(self, task_id):
        self.coordinator.unregister(task_id)

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline, now)
        self.last_report = report
        self._migrated += report.populated_pages
        ctrl = 0.0 if self.control_free else report.madvise_us
        return ctrl, report.migration.ready_view(now + ctrl)

    def switch_info(self):
        rep = self.last_report
        if rep is None:
            return None
        return {
            "populated_pages": rep.populated_pages,
            "evicted_pages": rep.evicted_pages,
            "madvise_us": rep.madvise_us,
            "migration_us": rep.migration.total_us,
        }

    def on_command(self, cmd, runs, now):
        # mispredictions fall back to standard demand paging (§5.2)
        missing = self.pool.missing_runs(runs)
        if not missing:
            return 0.0
        return self.fallback.access_runs(missing)

    def faults(self):
        return self.fallback.stats.faults

    def migrated_pages(self):
        return self._migrated + self.fallback.stats.migrated_pages


class IdealBackend(MSchedBackend):
    """Strict-OPT upper bound: oracle prediction, no control plane, and
    migration at the duplex bandwidth ceiling."""

    name = "ideal"

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline, now)
        self.last_report = report
        self._migrated += report.populated_pages
        # population at the physically best per-direction rate: the duplex
        # ceiling is shared by concurrent eviction (swap = cap/2 each way,
        # matching the paper's 63.5 GB/s pipelined swap figure)
        rate = min(
            self.platform.h2d_gbps * 1e3, self.platform.duplex_cap_gbps * 1e3 / 2
        )
        ps = self.page_size
        runs = report.migration.populated_runs
        n = run_page_count(runs)
        if n == 0:
            return 0.0, None
        return 0.0, IndexReadyView(
            runs, lambda i: now + ((i + 1) * ps) / rate, n
        )


def _task_footprint_runs(prog: "TaskProgram") -> List[PageRun]:
    """Whole-footprint page runs in buffer (base) order — the SUV prefetch
    order and the warm-start fill order."""
    runs: List[PageRun] = []
    for b in sorted(prog.space.buffers.values(), key=lambda b: b.base):
        pages = prog.space.pages_of_extent((b.base, b.size))
        if not len(pages):
            continue
        s, e = pages.start, pages.stop
        if runs and runs[-1][1] == s:
            runs[-1] = (runs[-1][0], e)
        else:
            runs.append((s, e))
    return runs


class SUVBackend(Backend):
    """Static-analysis single-task prefetch: on switch, prefetch the whole
    footprint of the incoming task (hotness-ordered = buffer order), with no
    awareness of the other tasks' residency or of the schedule."""

    name = "suv"

    def __init__(self, platform: Platform, pool: HBMPool, programs, page_size: int = 0):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.pager = DemandPager(platform, pool, page_size)
        self._task_runs: Dict[int, List[PageRun]] = {}
        for prog in programs:
            self.admit_task(prog)
        self._migrated = 0
        self.last_switch = None  # (populated, evicted) pages, telemetry

    def admit_task(self, prog):
        self._task_runs[prog.task_id] = _task_footprint_runs(prog)
        return None

    def retire_task(self, task_id):
        self._task_runs.pop(task_id, None)

    def on_switch(self, task_id, timeline, now):
        runs = self._task_runs.get(task_id, [])
        # cap the prefetch at HBM capacity (driver clamps)
        runs = clip_runs(runs, self.pool.capacity)
        populated, evicted = self.pool.migrate_runs(runs)
        npop = run_page_count(populated)
        nev = run_page_count(evicted)
        self._migrated += npop
        self.last_switch = (npop, nev)
        mig = plan_population_runs(
            self.platform, populated, nev, False, self.page_size,
        )
        return 0.0, mig.ready_view(now)

    def switch_info(self):
        if self.last_switch is None:
            return None
        npop, nev = self.last_switch
        return {
            "populated_pages": npop,
            "evicted_pages": nev,
            "madvise_us": 0.0,
            "migration_us": 0.0,
        }

    def on_command(self, cmd, runs, now):
        missing = self.pool.missing_runs(runs)
        return self.pager.access_runs(missing) if missing else 0.0

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self._migrated + self.pager.stats.migrated_pages


# --------------------------------------------------------------------------
# Dynamic task lifecycle
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskArrival:
    """A timed task-arrival event: ``program`` joins the task population at
    ``time_us`` (subject to admission control) and retires after
    ``program.total_iterations`` completed iterations."""

    time_us: float
    program: TaskProgram
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one dynamically-arrived task (one request in
    the serving regime); the raw material for SLO metrics."""

    task_id: int
    arrival_us: float
    admitted_us: Optional[float] = None
    first_iter_us: Optional[float] = None  # end of first completed iteration
    finished_us: Optional[float] = None
    iterations_done: int = 0
    total_iterations: Optional[int] = None
    rejected: bool = False
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def ttft_us(self) -> Optional[float]:
        """Time-to-first-token: arrival → end of the first iteration (the
        prefill + first decode step in the serving lifecycle)."""
        if self.first_iter_us is None:
            return None
        return self.first_iter_us - self.arrival_us

    def tpot_us(self) -> Optional[float]:
        """Time-per-output-token over the decode phase (post first token)."""
        if (
            self.finished_us is None
            or self.first_iter_us is None
            or not self.total_iterations
            or self.total_iterations < 2
        ):
            return None
        return (self.finished_us - self.first_iter_us) / (self.total_iterations - 1)

    def latency_us(self) -> Optional[float]:
        if self.finished_us is None:
            return None
        return self.finished_us - self.arrival_us

    def meets_slo(
        self,
        ttft_slo_us: Optional[float] = None,
        tpot_slo_us: Optional[float] = None,
    ) -> bool:
        if self.finished_us is None:
            return False
        if ttft_slo_us is not None:
            ttft = self.ttft_us()
            if ttft is None or ttft > ttft_slo_us:
                return False
        if (
            tpot_slo_us is not None
            and self.total_iterations is not None
            and self.total_iterations >= 2
        ):
            # single-token requests have no decode phase: TPOT is undefined
            # and cannot be violated
            tpot = self.tpot_us()
            if tpot is None or tpot > tpot_slo_us:
                return False
        return True


class AdmissionController:
    """Decides what happens when a dynamic task arrives (or is re-evaluated
    from the wait queue): ``"admit"``, ``"queue"``, or ``"reject"``.

    ``state`` is the live :class:`SimState` view — pool occupancy, active
    helpers (predicted working sets), the scheduling policy, and the clock —
    so controllers can be MSched-aware without owning simulator internals.
    """

    def decide(
        self, prog: TaskProgram, arrival_us: float, state: "SimState"
    ) -> str:
        return "admit"


@dataclasses.dataclass
class SimState:
    """Read-only view handed to admission controllers (and, via
    :meth:`SimCore.state_view`, to cluster placement policies)."""

    now: float
    platform: Platform
    pool: HBMPool
    policy: "Policy"
    page_size: int
    active: Dict[int, TaskProgram]
    helpers: Dict[int, TaskHelper]
    waiting: int  # queued-but-not-admitted candidates (FIFO ahead included)
    waiting_pages: int = 0  # summed whole-footprint pages of that queue


def active_demand_pages(state: SimState, quantum_us: float) -> int:
    """Per-schedule-cycle HBM demand of the admitted population: every active
    task runs once per round-robin cycle, so the cycle demand is the sum of
    the predicted per-quantum working sets — the whole footprint for tasks
    without a helper (UM-style backends) or with an empty future (the
    conservative bound). Shared by admission control and cluster placement."""
    total = 0
    for tid, prog in state.active.items():
        helper = state.helpers.get(tid)
        if helper is not None and len(helper):
            total += predicted_working_set_pages(helper, quantum_us)
        else:
            total += footprint_pages(prog, state.page_size)
    return total


@dataclasses.dataclass
class EjectedTask:
    """A task forcibly removed mid-run for inter-GPU migration: the program
    (address space intact — *not* released), its completed-iteration count,
    and the working set that was resident when it was ejected. The cluster
    checkpoints the working set, prices the transfer on the link graph, and
    re-injects a continuation on the target GPU."""

    program: TaskProgram
    completed: int
    resident_runs: List[PageRun]
    record: Optional[RequestRecord]

    def working_set_pages(self) -> int:
        return run_page_count(self.resident_runs)


@dataclasses.dataclass
class FailedTask:
    """One running task lost to a device failure: the program (address space
    intact — the backing data model lives in host DRAM, only the HBM cache
    and execution state are gone), the iterations it had completed on this
    visit, and its record fragment. The cluster re-places it from its newest
    durable source (checkpoint > lingering peer copy > cold restart)."""

    program: TaskProgram
    completed: int
    record: Optional[RequestRecord]


@dataclasses.dataclass
class FailureReport:
    """Everything a failing core surrenders to the cluster: running tasks
    (with their progress), queued-but-unadmitted candidates and not-yet-due
    arrivals (both with any pending warm runs — those sit in host DRAM and
    survive the device), and the page count the HBM wipe released."""

    time_us: float
    running: List[FailedTask]
    waiting: List[Tuple[TaskArrival, RequestRecord, Optional[List[PageRun]]]]
    pending: List[Tuple[TaskArrival, Optional[List[PageRun]]]]
    lost_pages: int


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------


def percentile(sorted_xs: Sequence[float], pct: float) -> float:
    """The repo-wide percentile convention: nearest-rank over an
    already-sorted sample, index = floor(pct/100 * n) clamped to the last
    element. ``SimResult``, the cluster aggregation layer, and every
    benchmark scoreboard delegate here, so the convention cannot drift
    between per-run and fleet-level metrics (a 1-GPU fleet's merged
    percentiles must equal the single-core run's — pinned in
    ``tests/cluster/test_telemetry_cluster.py``)."""
    assert 0.0 <= pct <= 100.0, f"percentile out of range: {pct}"
    if not sorted_xs:
        return 0.0
    assert sorted_xs[0] <= sorted_xs[-1], "percentile() wants a sorted sample"
    return sorted_xs[min(len(sorted_xs) - 1, int(pct / 100.0 * len(sorted_xs)))]


@dataclasses.dataclass
class TaskStats:
    completions: int = 0
    commands: int = 0
    busy_us: float = 0.0
    latencies_us: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    sim_us: float
    per_task: Dict[int, TaskStats]
    faults: int
    migrated_bytes: int
    switches: int
    control_us: float
    # dynamic-lifecycle records (empty for static simulations)
    requests: List[RequestRecord] = dataclasses.field(default_factory=list)
    # end-of-run HBM occupancy / reclamation (leak accounting)
    hbm_used_pages: int = 0
    hbm_freed_pages: int = 0

    def total_completions(self) -> int:
        return sum(t.completions for t in self.per_task.values())

    # -- serving / SLO metrics ----------------------------------------------
    # percentile convention: index = floor(pct/100 * n), clamped (see
    # module-level :func:`percentile` — the single implementation every
    # aggregation layer shares)
    def finished_requests(self) -> List[RequestRecord]:
        return [r for r in self.requests if r.finished_us is not None]

    def request_metric_us(self, metric: str) -> List[float]:
        """Per-request metric samples: ``ttft`` | ``tpot`` | ``latency``."""
        fn = {
            "ttft": RequestRecord.ttft_us,
            "tpot": RequestRecord.tpot_us,
            "latency": RequestRecord.latency_us,
        }[metric]
        return [v for r in self.requests if (v := fn(r)) is not None]

    def request_percentile_us(self, metric: str, pct: float) -> float:
        return percentile(sorted(self.request_metric_us(metric)), pct)

    def goodput_per_s(
        self,
        ttft_slo_us: Optional[float] = None,
        tpot_slo_us: Optional[float] = None,
        window_us: Optional[float] = None,
    ) -> float:
        """Completed requests per second that met every given SLO.

        ``window_us`` defaults to this run's makespan; cross-run comparisons
        (e.g. UM vs MSched on the same trace) must pass a *common* window, or
        the ratio conflates SLO attainment with drain speed.
        """
        window = window_us if window_us is not None else self.sim_us
        if not window:
            return 0.0
        good = sum(
            1 for r in self.requests if r.meets_slo(ttft_slo_us, tpot_slo_us)
        )
        return good / (window * 1e-6)

    def throughput_per_s(self) -> float:
        return self.total_completions() / (self.sim_us * 1e-6) if self.sim_us else 0.0

    def latency_percentile_us(
        self, pct: float, task_id: Optional[int] = None
    ) -> float:
        """Request-latency percentile over one task's (or all tasks')
        recorded arrival-to-completion latencies."""
        if task_id is not None:
            xs = sorted(self.per_task[task_id].latencies_us)
        else:
            xs = sorted(
                x for t in self.per_task.values() for x in t.latencies_us
            )
        return percentile(xs, pct)

    def p50_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(50.0, task_id)

    def p99_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(99.0, task_id)


class _RunTask:
    def __init__(
        self,
        prog: TaskProgram,
        helper: Optional[TaskHelper],
        lookahead_us: float = 0.0,
    ):
        self.prog = prog
        self.helper = helper
        self.lookahead_us = lookahead_us
        self.queue: Deque[Command] = deque()
        self.queued_us = 0.0
        self.iter_launched = 0
        self.cmd_in_iter = 0
        self.iter_len = 1
        self.total_iterations: Optional[int] = getattr(
            prog, "total_iterations", None
        )
        self.arrivals: Optional[Deque[float]] = None  # RT mode
        self.current_arrival: Optional[float] = None
        self.stats = TaskStats()
        self._refill()

    def _exhausted(self) -> bool:
        """Finite program with every iteration already launched."""
        return (
            self.total_iterations is not None
            and self.iter_launched >= self.total_iterations
        )

    def finished(self) -> bool:
        """Finite program with every iteration completed — retire the task."""
        return (
            self.total_iterations is not None
            and self.stats.completions >= self.total_iterations
        )

    def _launch_iter(self):
        cmds = self.prog.iteration(self.iter_launched)
        self.iter_len = len(cmds)
        for c in cmds:
            c.seq_no = self.iter_launched
            self.queue.append(c)
            self.queued_us += c.latency_us
            if self.helper is not None:
                self.helper.launch(c)
        self.iter_launched += 1

    def _refill(self):
        # the async launch window must cover at least one full timeslice of
        # future commands for the timeline plan to see the whole working set
        launched_iters = 0
        while (
            launched_iters < MIN_LOOKAHEAD_ITERS
            or self.queued_us < self.lookahead_us
        ):
            if self._exhausted():
                break
            self._launch_iter()
            launched_iters += 1
            if launched_iters > 10_000:
                break

    def peek(self) -> Command:
        return self.queue[0]

    def advance(self, now: float) -> bool:
        """Consume one command; returns True when an iteration completed."""
        cmd = self.queue.popleft()
        self.queued_us -= cmd.latency_us
        if self.helper is not None and len(self.helper.queue):
            self.helper.pop()
        self.cmd_in_iter += 1
        done = False
        if self.cmd_in_iter >= self.iter_len:
            self.cmd_in_iter = 0
            self.stats.completions += 1
            done = True
        if not self._exhausted() and (
            len(self.queue) < self.iter_len or self.queued_us < self.lookahead_us
        ):
            self._launch_iter()
        return done

    def runnable(self, now: float) -> bool:
        if self.arrivals is None:
            return True
        if self.current_arrival is not None:
            return True
        while self.arrivals and self.arrivals[0] <= now:
            self.current_arrival = self.arrivals.popleft()
            return True
        return False

    def next_arrival(self) -> Optional[float]:
        if self.arrivals is None or self.current_arrival is not None:
            return None
        return self.arrivals[0] if self.arrivals else None


def make_backend(
    name: str,
    platform: Platform,
    pool: HBMPool,
    programs: Sequence[TaskProgram],
    predictor_kind: str = "template",
    pipelined: bool = True,
    page_size: int = 0,
    planning: str = "incremental",
    profile_set: Optional[Sequence[TaskProgram]] = None,
) -> Tuple[Backend, Dict[int, TaskHelper]]:
    """``profile_set`` overrides the programs used for offline template
    analysis — dynamic scenarios profile representative programs up front and
    admit instances of the same kernels later."""
    helpers: Dict[int, TaskHelper] = {}
    if name == "um":
        return UMBackend(platform, pool, page_size), helpers
    if name == "suv":
        return SUVBackend(platform, pool, programs, page_size), helpers

    # msched / ideal need per-task helpers with a predictor; the factory is
    # kept on the backend so dynamically admitted tasks get the same kind
    if name == "ideal" or predictor_kind == "oracle":
        factory: Callable[[TaskProgram], Predictor] = lambda p: OraclePredictor()
    elif predictor_kind == "allocation":
        factory = lambda p: AllocationPredictor(p.space)
    else:  # template: offline profile + analyze (the real MSched flow)
        store = profile_programs(list(profile_set or programs), iters=4)
        descriptors = analyze_traces(store)
        factory = lambda p: TemplatePredictor(descriptors)
    for p in programs:
        helpers[p.task_id] = TaskHelper(p.task_id, p.space, factory(p))
    cls = IdealBackend if name == "ideal" else MSchedBackend
    backend = cls(
        platform, pool, helpers, pipelined=pipelined, page_size=page_size,
        legacy_planning=(planning == "legacy"),
        predictor_factory=factory,
    )
    return backend, helpers


class SimCore:
    """Re-entrant single-GPU simulation core.

    Construction performs everything ``simulate()`` used to do before its
    event loop (pool/backend/helper setup, warm start, degenerate-task purge);
    :meth:`run` advances the clock. The classic entrypoint drives one core to
    the horizon in a single ``run(sim_us, final=True)`` call; the cluster
    scheduler interleaves ``run(T, final=False)`` calls with the external
    hooks:

      * :meth:`inject` — enqueue a future :class:`TaskArrival` (placement
        dispatches trace requests to the chosen GPU), optionally with
        ``warm_runs`` — the migrated working set that lands in HBM with the
        task (checkpoint restore);
      * :meth:`eject` — remove an admitted task mid-run *without* retiring it,
        returning its program and resident working set for migration;
      * :meth:`steal_waiting` — pop the newest queued-but-unadmitted
        candidate for rerouting to another GPU (nothing resident: free);
      * :meth:`state_view` — the same read-only :class:`SimState` admission
        controllers get, for load-aware placement.

    ``final=False`` clamps idle clock jumps to ``until_us`` and never
    force-admits a starved wait queue, so events injected at or after the
    horizon are always observed in time; the single terminal
    ``run(horizon, final=True)`` restores ``simulate()``'s end-of-run
    semantics exactly, which is what makes a 1-GPU cluster bit-for-bit
    identical to ``simulate()``.
    """

    def __init__(
        self,
        programs: Sequence[TaskProgram],
        platform: Platform,
        backend_name: str = "msched",
        capacity_bytes: Optional[int] = None,
        policy: Optional[Policy] = None,
        predictor_kind: str = "template",
        pipelined: bool = True,
        arrivals: Optional[Dict[int, List[float]]] = None,
        priorities: Optional[Dict[int, int]] = None,
        prepopulate: bool = True,
        planning: str = "incremental",
        task_events: Optional[Sequence[TaskArrival]] = None,
        admission: Optional[AdmissionController] = None,
        profile_set: Optional[Sequence[TaskProgram]] = None,
        page_size: int = 0,
        pool: str = "run",
        dynamic: Optional[bool] = None,
        name: str = "gpu0",
        telemetry=None,
    ):
        programs = list(programs)
        if not page_size:
            if programs:
                page_size = programs[0].space.page_size
            elif task_events:
                page_size = task_events[0].program.space.page_size
            else:
                page_size = 4096
        all_progs = programs + [ev.program for ev in task_events or ()]
        for prog in all_progs:
            if prog.space.page_size != page_size:
                raise ValueError(
                    f"task {prog.task_id} uses page_size "
                    f"{prog.space.page_size}, simulation uses {page_size}; "
                    "pool residency keys would not be comparable"
                )
        cap_bytes = capacity_bytes or platform.hbm_bytes
        self.name = name
        self.platform = platform
        self.page_size = page_size
        self.pool = make_pool(pool, max(1, cap_bytes // page_size))
        self.backend, self.helpers = make_backend(
            backend_name, platform, self.pool, programs, predictor_kind,
            pipelined, page_size, planning, profile_set,
        )
        self.cached_decode = planning != "legacy"
        self.policy = policy or RoundRobinPolicy()
        self.admission = admission
        self.priorities = priorities
        self.quantum = getattr(self.policy, "quantum_us", 5_000.0)

        self.tasks: Dict[int, _RunTask] = {}
        for prog in programs:
            rt = _RunTask(
                prog, self.helpers.get(prog.task_id),
                lookahead_us=2.2 * self.quantum,
            )
            if arrivals and prog.task_id in arrivals:
                rt.arrivals = deque(arrivals[prog.task_id])
                rt.current_arrival = None
            self.tasks[prog.task_id] = rt
            self.pool.register_task(prog.task_id, prog.space.page_span())

        # warm start: fill HBM fairly (tasks ran before the measuring window).
        # migrate_runs over a fresh pool appends the exact page order the old
        # per-page populate loop produced, at O(runs)
        if prepopulate:
            share = self.pool.capacity // max(1, len(programs))
            for prog in programs:
                self.pool.migrate_runs(clip_runs(_task_footprint_runs(prog), share))

        # -- dynamic lifecycle state ----------------------------------------
        self.dynamic = bool(task_events) if dynamic is None else bool(dynamic)
        self.pending: Deque[TaskArrival] = deque(
            sorted(task_events or [], key=lambda e: e.time_us)
        )
        self.waiting: Deque[Tuple[TaskArrival, RequestRecord, int]] = deque()
        self._waiting_pages = 0
        self.records: List[RequestRecord] = []
        self.rec_by_tid: Dict[int, RequestRecord] = {}
        self.retired_stats: Dict[int, TaskStats] = {}
        self.used_task_ids = set(self.tasks)  # static ids + every id admitted
        self._warm_runs: Dict[int, List[PageRun]] = {}

        # cluster hook: called with (ev, rec, warm_runs) when the admission
        # controller rejects a queued candidate; returning True means the
        # rejection was handled externally (e.g. the cluster re-routed a
        # migrated continuation back to a GPU with headroom) and the record
        # must NOT be marked rejected. None = single-GPU behavior.
        self.reject_hook: Optional[
            Callable[[TaskArrival, RequestRecord, Optional[List[PageRun]]], bool]
        ] = None
        # tasks ejected with linger=True: their working set stays resident
        # (demoted to the eviction-list head) as a peer-prefetch source until
        # reclaimed by pressure or reclaim_linger()
        self.lingering: set = set()
        # cluster hook: called with (task_id, now) when a task retires, so
        # fleet-level bookkeeping (the peer-prefetch fabric's directory
        # hints) is reaped at finish instead of waiting for the next
        # rebalance tick. None = single-GPU behavior.
        self.finish_hook: Optional[Callable[[int, float], None]] = None
        # control-plane hook: called with (task_id, event, now) at the three
        # data-plane lifecycle boundaries ("admitted", "finished",
        # "rejected") so the cluster control plane can journal them. None =
        # no control plane attached (the default, and the single-GPU case).
        self.lifecycle_hook: Optional[Callable[[int, str, float], None]] = None
        # telemetry hub (repro.telemetry.Telemetry) or None; every emission
        # site is guarded, so the None path is exactly the untraced code
        self.telemetry = telemetry
        self._tel_faults = 0  # backend fault counter at last fault event

        self.t = 0.0
        self.switches = 0
        self.control_us = 0.0
        self.sched_cache: Optional[Dict[int, SchedTask]] = None
        # device-failure state: a failed core refuses work (run() no-ops,
        # inject() raises) until recover()
        self.failed = False

        # purge degenerate zero-iteration static programs before the clock
        # starts
        for tid in [tid for tid, rt in self.tasks.items() if rt.finished()]:
            self._retire(tid, 0.0)

    # -- external hooks (cluster composition) -------------------------------
    def state_view(self) -> SimState:
        return self._state(self.t)

    def inject(
        self, ev: TaskArrival, warm_runs: Optional[Sequence[PageRun]] = None
    ) -> None:
        """Enqueue a future arrival. ``warm_runs`` (a migrated task's
        checkpointed working set) is populated into HBM at admission — the
        restore half of the transfer the cluster already priced."""
        if self.failed:
            raise RuntimeError(
                f"cannot inject into failed core {self.name}; callers must "
                "dispatch to an alive device"
            )
        self.dynamic = True
        if self.pending and ev.time_us < self.pending[-1].time_us:
            self.pending = deque(
                sorted([*self.pending, ev], key=lambda e: e.time_us)
            )
        else:
            self.pending.append(ev)
        if warm_runs:
            self._warm_runs[ev.program.task_id] = list(warm_runs)

    def eject(
        self,
        task_id: int,
        resident_runs: Optional[List[PageRun]] = None,
        linger: bool = False,
    ) -> EjectedTask:
        """Forcibly remove an admitted task for migration: scheduler state,
        helper, and resident pages are torn down on this GPU, but the program
        is *not* released and its record is *not* marked finished. Work of a
        partially-completed iteration is replayed on the target (checkpoints
        are iteration-granular). ``resident_runs`` lets a caller that already
        snapshotted the working set (to price the transfer before committing
        to the move) pass it through instead of recomputing it — it must be
        current, i.e. no pool mutation since the snapshot.

        ``linger=True`` keeps the working set *resident* instead of freeing
        it, demoted to the eviction-list head: the pages cost this GPU
        nothing (they are the first victims under any pressure) but remain a
        peer-prefetch source the migration target can pull over NVLink —
        until local eviction or :meth:`reclaim_linger` takes them."""
        rt = self.tasks.pop(task_id)
        self.sched_cache = None
        self.backend.retire_task(task_id)
        self.helpers.pop(task_id, None)
        # the id may legitimately come back: a rebalanced task can ping-pong
        # onto a GPU it already visited (each visit is its own record
        # fragment; the cluster merge stitches them)
        self.used_task_ids.discard(task_id)
        span = rt.prog.space.page_span()
        resident = (
            resident_runs
            if resident_runs is not None
            else resident_runs_in(self.pool, span)
        )
        self.pool.register_task(task_id, span)  # cover late allocations
        if linger:
            self.pool.demote_runs(resident)
            self.lingering.add(task_id)
        else:
            self.pool.free_task(task_id)
        self._bank_stats(task_id, rt.stats)
        rec = self.rec_by_tid.get(task_id)
        if rec is not None:
            rec.iterations_done = rt.stats.completions
            rec.meta["ejected_us"] = self.t
        return EjectedTask(
            program=rt.prog,
            completed=rt.stats.completions,
            resident_runs=resident,
            record=rec,
        )

    def reclaim_linger(self, task_id: int) -> int:
        """Free whatever remains of a lingered task's working set (the
        cluster calls this when the migrated task finishes elsewhere, is
        re-migrated, or the run ends). A no-op unless the task is actually
        lingering here — a ping-ponged task that was re-admitted owns its
        pages again and must not lose them. Returns pages reclaimed."""
        if task_id not in self.lingering:
            return 0
        self.lingering.discard(task_id)
        return self.pool.free_task(task_id)

    def steal_waiting(
        self,
    ) -> Optional[Tuple[TaskArrival, RequestRecord, Optional[List[PageRun]]]]:
        """Pop the *newest* queued-but-unadmitted candidate (LIFO steal keeps
        FIFO fairness for the queue head) so the cluster can reroute it to a
        less-loaded GPU. Its record stays in this core's log (the cluster
        merge combines it with the target GPU's). The third element is the
        candidate's pending warm working set, if it was itself a migrated
        continuation still waiting for admission — it travels with the
        steal instead of going stale here."""
        if not self.waiting:
            return None
        ev, rec, pages = self.waiting.pop()
        self._waiting_pages -= pages
        rec.meta["rerouted_us"] = self.t
        return ev, rec, self._warm_runs.pop(ev.program.task_id, None)

    def fail(self, now: float) -> FailureReport:
        """Device-failure teardown: every admitted task is torn down (stats
        banked, record fragment stamped ``failed_us``), queued and pending
        candidates are surrendered with their pending warm runs, lingering
        peer copies evaporate with the HBM they lived in, and the pool is
        wiped. The core refuses work until :meth:`recover`. Returns what the
        cluster must re-place or account as lost."""
        if self.failed:
            raise RuntimeError(f"core {self.name} is already failed")
        self.failed = True
        self.sched_cache = None
        self.t = max(self.t, now)
        running: List[FailedTask] = []
        for tid in list(self.tasks):
            rt = self.tasks.pop(tid)
            self.backend.retire_task(tid)
            self.helpers.pop(tid, None)
            # the id comes back when the victim is re-placed (possibly here,
            # after recovery) — same convention as eject()
            self.used_task_ids.discard(tid)
            self._bank_stats(tid, rt.stats)
            rec = self.rec_by_tid.get(tid)
            if rec is not None:
                rec.iterations_done = rt.stats.completions
                rec.meta["failed_us"] = now
            running.append(FailedTask(rt.prog, rt.stats.completions, rec))
        waiting = []
        for ev, rec, _pages in self.waiting:
            rec.meta["failed_us"] = now
            waiting.append(
                (ev, rec, self._warm_runs.pop(ev.program.task_id, None))
            )
        self.waiting.clear()
        self._waiting_pages = 0
        pending = [
            (ev, self._warm_runs.pop(ev.program.task_id, None))
            for ev in self.pending
        ]
        self.pending.clear()
        self._warm_runs.clear()
        self.lingering.clear()
        lost = self.pool.wipe()
        return FailureReport(now, running, waiting, pending, lost)

    def recover(self, now: float) -> None:
        """Bring a failed device back empty-handed: HBM is cold, the queue
        empty — the device simply starts taking work again."""
        if not self.failed:
            return
        self.failed = False
        self.t = max(self.t, now)

    def shed_one_waiting(
        self, pred: Callable[[TaskArrival], bool]
    ) -> Optional[Tuple[TaskArrival, RequestRecord]]:
        """Shed the *newest* queued candidate matching ``pred`` (graceful
        degradation under shrunken fleet capacity; newest-first preserves
        FIFO fairness for the older queue head, mirroring
        :meth:`steal_waiting`). The record is marked rejected and any
        pending warm runs are dropped. Returns the shed (event, record), or
        ``None`` when nothing matches."""
        for i in range(len(self.waiting) - 1, -1, -1):
            ev, rec, pages = self.waiting[i]
            if not pred(ev):
                continue
            del self.waiting[i]
            self._waiting_pages -= pages
            self._warm_runs.pop(ev.program.task_id, None)
            rec.rejected = True
            rec.meta["shed_us"] = self.t
            if self.telemetry is not None:
                self.telemetry.instant(
                    "shed",
                    self.name,
                    self.t,
                    task_id=ev.program.task_id,
                    reason="capacity_shed",
                )
            if self.lifecycle_hook is not None:
                self.lifecycle_hook(ev.program.task_id, "rejected", self.t)
            return ev, rec
        return None

    def cancel_task(self, task_id: int, now: float) -> bool:
        """Operator cancel (control-plane API): remove the task wherever it
        lives on this core — running (ejected, pages freed), queued, or
        pending — and mark its record rejected with a ``cancelled_us``
        stamp. A pending arrival has no record yet, so one is synthesized
        (cancelled work is accounted, never silently dropped). Returns
        True when the task was found here; lifecycle hooks do not fire —
        the caller journals the cancel itself."""
        if task_id in self.tasks:
            ej = self.eject(task_id)
            if ej.record is not None:
                ej.record.rejected = True
                ej.record.meta["cancelled_us"] = now
            return True
        for i, (ev, rec, pages) in enumerate(self.waiting):
            if ev.program.task_id == task_id:
                del self.waiting[i]
                self._waiting_pages -= pages
                self._warm_runs.pop(task_id, None)
                rec.rejected = True
                rec.meta["cancelled_us"] = now
                return True
        for i, ev in enumerate(self.pending):
            if ev.program.task_id == task_id:
                del self.pending[i]
                self._warm_runs.pop(task_id, None)
                rec = RequestRecord(
                    task_id,
                    ev.time_us,
                    rejected=True,
                    meta=dict(ev.meta, cancelled_us=now),
                )
                self.records.append(rec)
                self.rec_by_tid[task_id] = rec
                return True
        return False

    # -- lifecycle internals -------------------------------------------------
    def _state(self, now: float) -> SimState:
        return SimState(
            now=now,
            platform=self.platform,
            pool=self.pool,
            policy=self.policy,
            page_size=self.page_size,
            active={tid: r.prog for tid, r in self.tasks.items()},
            helpers=self.helpers,
            waiting=len(self.waiting),
            waiting_pages=self._waiting_pages,
        )

    def _admit(self, ev: TaskArrival, rec: RequestRecord, now: float) -> None:
        self.sched_cache = None
        prog = ev.program
        if prog.task_id in self.used_task_ids:
            raise ValueError(
                f"TaskArrival task_id {prog.task_id} collides with an "
                "existing task; ids must be unique across programs and events"
            )
        self.used_task_ids.add(prog.task_id)
        # a ping-ponged task returning to a GPU where its old working set
        # still lingers re-owns those pages through the normal span
        # registration below
        self.lingering.discard(prog.task_id)
        helper = self.backend.admit_task(prog)
        if helper is not None:
            self.helpers[prog.task_id] = helper
        rt = _RunTask(prog, helper, lookahead_us=2.2 * self.quantum)
        self.tasks[prog.task_id] = rt
        self.pool.register_task(prog.task_id, prog.space.page_span())
        warm = self._warm_runs.pop(prog.task_id, None)
        if warm:
            self.pool.migrate_runs(clip_runs(warm, self.pool.capacity))
        rec.admitted_us = now
        if self.telemetry is not None:
            self.telemetry.instant(
                "admission",
                self.name,
                now,
                task_id=prog.task_id,
                queued_us=max(0.0, now - ev.time_us),
            )
        if self.lifecycle_hook is not None:
            self.lifecycle_hook(prog.task_id, "admitted", now)
        if rt.finished():
            # degenerate zero-iteration program: it can never produce the
            # completion event that triggers retirement, so retire it here
            self._retire(prog.task_id, now)

    def _bank_stats(self, tid: int, stats: TaskStats) -> None:
        """Accumulate a departing task's stats. A rebalanced task can visit
        this GPU more than once (eject, then ping-pong back); each visit's
        work must add up, not overwrite."""
        cur = self.retired_stats.get(tid)
        if cur is None:
            self.retired_stats[tid] = stats
        else:
            cur.completions += stats.completions
            cur.commands += stats.commands
            cur.busy_us += stats.busy_us
            cur.latencies_us.extend(stats.latencies_us)

    def _retire(self, tid: int, now: float) -> None:
        self.sched_cache = None
        rt = self.tasks.pop(tid)
        self.backend.retire_task(tid)
        self.helpers.pop(tid, None)
        # final span (covers any post-admission allocations), then reclaim
        span = rt.prog.release()
        self.pool.register_task(tid, span)
        self.pool.free_task(tid)
        self._bank_stats(tid, rt.stats)
        rec = self.rec_by_tid.get(tid)
        if rec is not None:
            rec.finished_us = now
            rec.iterations_done = rt.stats.completions
        if self.telemetry is not None:
            self.telemetry.instant(
                "finish",
                self.name,
                now,
                task_id=tid,
                iterations=rt.stats.completions,
            )
        if self.finish_hook is not None:
            self.finish_hook(tid, now)
        if self.lifecycle_hook is not None:
            self.lifecycle_hook(tid, "finished", now)

    def _drain_waiting(self, now: float) -> None:
        # FIFO re-evaluation of the wait queue: stop at the first candidate
        # the controller still holds back (no overtaking)
        while self.waiting:
            ev, rec, pages = self.waiting[0]
            verdict = (
                self.admission.decide(ev.program, ev.time_us, self._state(now))
                if self.admission is not None
                else "admit"
            )
            if verdict == "admit":
                self.waiting.popleft()
                self._waiting_pages -= pages
                self._admit(ev, rec, now)
            elif verdict == "reject":
                self.waiting.popleft()
                self._waiting_pages -= pages
                warm = self._warm_runs.pop(ev.program.task_id, None)
                if self.reject_hook is not None and self.reject_hook(
                    ev, rec, warm
                ):
                    # handled externally (re-routed); this fragment stays
                    # unfinished — the target GPU's fragment completes it
                    continue
                rec.rejected = True
                if self.telemetry is not None:
                    self.telemetry.instant(
                        "shed",
                        self.name,
                        now,
                        task_id=ev.program.task_id,
                        reason="admission_reject",
                    )
                if self.lifecycle_hook is not None:
                    self.lifecycle_hook(ev.program.task_id, "rejected", now)
            else:
                break

    def _process_arrivals(self, now: float) -> None:
        # due arrivals join the wait queue in arrival order; one FIFO drain
        # then decides everyone (no overtaking: the drain stops at the first
        # candidate the controller holds back)
        while self.pending and self.pending[0].time_us <= now:
            ev = self.pending.popleft()
            rec = RequestRecord(
                ev.program.task_id,
                ev.time_us,
                total_iterations=getattr(ev.program, "total_iterations", None),
                meta=dict(ev.meta),
            )
            self.records.append(rec)
            self.rec_by_tid[ev.program.task_id] = rec
            pages = footprint_pages(ev.program, self.page_size)
            self.waiting.append((ev, rec, pages))
            self._waiting_pages += pages
        self._drain_waiting(now)

    def _complete(self, tid: int, rt: _RunTask, now: float) -> bool:
        """Post-iteration bookkeeping shared by the per-command and macro
        paths; returns True when the task finished and retired (end the
        timeslice)."""
        if rt.current_arrival is not None:
            rt.stats.latencies_us.append(now - rt.current_arrival)
            rt.current_arrival = None
            # next pending arrival (if already due) picked up by runnable()
        if self.dynamic:
            rec = self.rec_by_tid.get(tid)
            if rec is not None and rt.stats.completions == 1:
                rec.first_iter_us = now
        if rt.finished():
            # finite programs retire regardless of how they entered —
            # a drained static task must not pin the scheduler forever
            self._retire(tid, now)
            if self.dynamic:
                self._process_arrivals(now)  # freed pages may unblock queue
            return True
        return False

    # -- the event loop ------------------------------------------------------
    def run(self, until_us: float, final: bool = True) -> float:
        """Advance the clock to ``until_us`` (a timeslice in flight may
        overrun it, exactly as ``simulate()`` overruns its horizon). Returns
        the clock. Non-final runs stop — without consuming time — when the
        core has nothing to do before the horizon. A failed core holds its
        clock still until recovered."""
        if self.failed:
            return self.t
        while self.t < until_us:
            if not self._step(until_us, final):
                break
        return self.t

    def _step(self, until_us: float, final: bool) -> bool:
        t = self.t
        if self.dynamic:
            self._process_arrivals(t)
        if self.sched_cache is not None:
            sched = self.sched_cache
        else:
            sched = {
                tid: SchedTask(
                    tid,
                    priority=(self.priorities or {}).get(tid, 0),
                    runnable=rt.runnable(t),
                )
                for tid, rt in self.tasks.items()
            }
            # runnable-ness only changes with the clock in RT-arrivals mode;
            # otherwise the view is invalidated solely by admit/retire, so it
            # can be reused across the (possibly hundreds of thousands of)
            # switches of a long serving trace
            if all(rt.arrivals is None for rt in self.tasks.values()):
                self.sched_cache = sched
        entry = self.policy.next_entry(sched)
        if entry is None:
            # idle until the next RT arrival or task-arrival event
            nxt = [rt.next_arrival() for rt in self.tasks.values()]
            nxt = [x for x in nxt if x is not None]
            if self.pending:
                nxt.append(self.pending[0].time_us)
            if nxt:
                target = min(nxt)
                if not final:
                    # never leap past the cluster event horizon: an arrival
                    # injected there must still be observed in time
                    target = min(target, until_us)
                self.t = max(t, target)
                return True
            if self.waiting:
                if not final:
                    # the cluster may still inject or steal work; starved-
                    # queue force-admission belongs to the terminal drain
                    return False
                # nothing running and nothing due: force-admit the queue head
                # (an idle device can always take work) to guarantee progress
                ev, rec, pages = self.waiting.popleft()
                self._waiting_pages -= pages
                self._admit(ev, rec, t)
                return True
            return False
        # the timeline's first entry must be the task about to run —
        # next_entry() already rotated the policy's run queue past it.
        # Backends that never read the plan (um/suv) skip the multi-entry
        # build: at 2 ms TSG quanta over hundreds of serving tasks it is
        # pure overhead
        backend = self.backend
        if backend.uses_timeline:
            timeline = TaskTimeline([entry] + self.policy.timeline(sched).entries)
        else:
            timeline = TaskTimeline([entry])
        ctrl, ready = backend.on_switch(entry.task_id, timeline, t)
        tel = self.telemetry
        aud = tel.audit if tel is not None else None
        if tel is not None:
            self._tel_switch_begin(entry.task_id, t, ctrl)
        t += ctrl
        self.control_us += ctrl
        self.switches += 1

        rt = self.tasks[entry.task_id]
        if not rt.queue:
            # only reachable when iteration() returns no commands: fail loud
            # instead of spinning the scheduler at zero simulated time
            raise RuntimeError(
                f"task {entry.task_id} is runnable but has no queued "
                "commands; its iteration() produced an empty command list"
            )
        budget = entry.timeslice_us
        space = rt.prog.space
        tid = entry.task_id
        pool = self.pool
        cached_decode = self.cached_decode
        ready_max = ready.global_max if ready is not None else None
        # macro-stepping: once migration has landed (past the last ready
        # time), check the upcoming command window's merged working set once;
        # while it is fully resident, every command runs with zero stall and
        # no backend interaction, so advance the window in a tight loop.
        # A failed check disables re-checking until pool state changes again
        # (any command that actually stalls re-arms it).
        try_macro = cached_decode
        while budget > 0 and rt.runnable(t) and rt.queue:
            if try_macro and (ready_max is None or t >= ready_max):
                # cheap precheck: a window can only qualify if its first
                # command is fully resident — under fault-thrash (UM) this
                # skips the merged-group build entirely
                if not pool.all_resident_runs(rt.queue[0].true_page_runs(space)):
                    try_macro = False
                    window = 0
                else:
                    window = _macro_window(rt.queue, budget)
                merged = (
                    merged_command_runs(islice(rt.queue, window), space)
                    if window
                    else None
                )
                if merged is not None and pool.all_resident_runs(merged):
                    touches = backend.resident_touch
                    ended = False
                    while (
                        window > 0 and budget > 0 and rt.queue
                        and rt.runnable(t)
                    ):
                        cmd = rt.queue[0]
                        if aud is not None:
                            aud.observe_command(self.name, cmd, space)
                        if touches:
                            pool.touch_runs(cmd.true_page_runs(space))
                        end = t + cmd.latency_us  # start == t, stall == 0
                        rt.stats.commands += 1
                        rt.stats.busy_us += end - t
                        budget -= end - t
                        t = end
                        window -= 1
                        if rt.advance(t) and self._complete(tid, rt, t):
                            ended = True
                            break
                    if ended:
                        break
                    continue  # window exhausted: re-derive it
                try_macro = False
            cmd = rt.peek()
            # cached run-length decode; the legacy path re-walks the extents
            # per executed command (preserved for the sim-throughput baseline)
            if cached_decode:
                runs = cmd.true_page_runs(space)
            else:
                runs = pages_to_runs(_true_page_order(space, cmd))
            start = t
            if ready is not None and start < ready_max:
                r = ready.max_ready(runs)
                if r is not None and r > start:
                    start = r
            stall = backend.on_command(cmd, runs, start)
            if stall > 0.0:
                try_macro = cached_decode  # residency changed: re-arm
            if aud is not None:
                aud.observe_command(self.name, cmd, space)
            if tel is not None and (start > t or stall > 0.0):
                self._tel_command(tid, t, start, stall)
            end = start + stall + cmd.latency_us
            rt.stats.commands += 1
            rt.stats.busy_us += end - t
            budget -= end - t
            t = end
            if rt.advance(t) and self._complete(tid, rt, t):
                break
        if tel is not None:
            if aud is not None:
                aud.end_quantum(self.name)
            tel.end("switch", self.name, t, task_id=tid)
            if self.switches % tel.sample_stride == 0:
                tel.counter(self.name, "hbm_used_pages", t, self.pool.used)
                tel.counter(self.name, "run_queue_depth", t, len(self.tasks))
                tel.counter(
                    self.name, "wait_queue_depth", t, len(self.waiting)
                )
        self.t = t
        return True

    # -- telemetry emission (slow path only; never reached when off) ---------
    def _tel_switch_begin(self, tid: int, t: float, ctrl: float) -> None:
        tel = self.telemetry
        tel.begin("switch", self.name, t, task_id=tid, ctrl_us=ctrl)
        if ctrl > 0.0:
            tel.stall(tid, "scheduler_control", ctrl)
        if tel.audit is not None:
            # predictive backends (msched/ideal) expose the coordinator's
            # SwitchReport; backends that plan nothing are not audited
            rep = getattr(self.backend, "last_report", None)
            if rep is not None:
                tel.audit.begin_quantum(
                    self.name, tid, rep.predicted_runs,
                    rep.migration.populated_runs, self.page_size,
                )
        info = self.backend.switch_info()
        if info is not None:
            if info["populated_pages"] > 0:
                tel.span(
                    "migration_plan",
                    self.name,
                    t + info["madvise_us"],
                    info["migration_us"],
                    task_id=tid,
                    pages=info["populated_pages"],
                )
            if info["evicted_pages"] > 0:
                tel.instant(
                    "eviction_batch",
                    self.name,
                    t,
                    task_id=tid,
                    pages=info["evicted_pages"],
                )

    def _tel_command(
        self, tid: int, t: float, start: float, stall: float
    ) -> None:
        tel = self.telemetry
        if start > t:
            # the command waited for planned migration traffic to land
            # (the backend's ready-view): migration-wait inside the slice
            tel.stall(tid, "mig_wait_exec", start - t)
            tel.span(
                "migration_land", self.name, t, start - t, task_id=tid
            )
        if stall > 0.0:
            faults = self.backend.faults()
            tel.span(
                "fault_service",
                self.name,
                start,
                stall,
                task_id=tid,
                faults=faults - self._tel_faults,
            )
            self._tel_faults = faults
            tel.stall(tid, "fault_service", stall)
            if tel.audit is not None:
                # under-fetch residue: pages the populate plan failed to
                # cover, serviced by the fallback demand pager
                tel.audit.observe_fault(self.name, tid, stall)

    def result(self) -> SimResult:
        per_task = {tid: rt.stats for tid, rt in self.tasks.items()}
        for tid, banked in self.retired_stats.items():
            live = per_task.get(tid)
            if live is None:
                per_task[tid] = banked
            else:
                # a previously-ejected task is back and still running at the
                # horizon: both visits' work counts (fresh copy — result()
                # must not mutate live state)
                per_task[tid] = TaskStats(
                    banked.completions + live.completions,
                    banked.commands + live.commands,
                    banked.busy_us + live.busy_us,
                    banked.latencies_us + live.latencies_us,
                )
        return SimResult(
            sim_us=self.t,
            per_task=per_task,
            faults=self.backend.faults(),
            migrated_bytes=self.backend.migrated_pages() * self.page_size,
            switches=self.switches,
            control_us=self.control_us,
            requests=self.records,
            hbm_used_pages=self.pool.used,
            hbm_freed_pages=self.pool.freed_pages,
        )


def simulate(
    programs: Sequence[TaskProgram],
    platform: Platform,
    backend_name: str = "msched",
    capacity_bytes: Optional[int] = None,
    sim_us: float = 2_000_000.0,
    policy: Optional[Policy] = None,
    predictor_kind: str = "template",
    pipelined: bool = True,
    arrivals: Optional[Dict[int, List[float]]] = None,
    priorities: Optional[Dict[int, int]] = None,
    prepopulate: bool = True,
    planning: str = "incremental",
    task_events: Optional[Sequence[TaskArrival]] = None,
    admission: Optional[AdmissionController] = None,
    profile_set: Optional[Sequence[TaskProgram]] = None,
    page_size: int = 0,
    pool: str = "run",
    telemetry=None,
) -> SimResult:
    core = SimCore(
        programs,
        platform,
        backend_name,
        capacity_bytes=capacity_bytes,
        policy=policy,
        predictor_kind=predictor_kind,
        pipelined=pipelined,
        arrivals=arrivals,
        priorities=priorities,
        prepopulate=prepopulate,
        planning=planning,
        task_events=task_events,
        admission=admission,
        profile_set=profile_set,
        page_size=page_size,
        pool=pool,
        telemetry=telemetry,
    )
    core.run(sim_us, final=True)
    res = core.result()
    if telemetry is not None:
        telemetry.finalize(res)
    return res


def _true_page_order(space: AddressSpace, cmd: Command) -> List[int]:
    seen = set()
    order = []
    for ext in cmd.true_extents:
        for p in space.pages_of_extent(ext):
            if p not in seen:
                seen.add(p)
                order.append(p)
    return order


def _macro_window(queue: "Deque[Command]", budget_us: float) -> int:
    """Number of queued commands a zero-stall execution would start within
    ``budget_us`` (the slice consumption rule: a command starts while budget
    remains > 0)."""
    rem = budget_us
    k = 0
    for cmd in queue:
        if rem <= 0:
            break
        rem -= cmd.latency_us
        k += 1
    return k
