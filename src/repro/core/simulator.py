"""Discrete-event execution engine for multitasking under oversubscription.

Runs a set of ``TaskProgram``s under a scheduling policy with one of four
memory backends:

  um      — native demand paging (CUDA UM model; §2.3)
  msched  — proactive memory scheduling: extended context switch with
            timeline-driven OPT placement + pipelined migration (§4–§6)
  ideal   — theoretical optimum: ground-truth working sets, zero control
            plane, full-duplex-cap migration, strict Belady (paper's *Ideal*)
  suv     — single-task static-prefetch baseline (SUV, §7.5): prefetches the
            whole task footprint on switch, oblivious to other tasks

The engine models *early execution* (§6.3): a kernel starts as soon as its own
pages are ready, not when the whole working-set migration finishes.

Execution is *run-native* end to end: commands carry cached page-run tuples,
residency/ready queries are interval operations, and once a timeslice's
migration has landed the engine *macro-steps* — it verifies the upcoming
command window's merged run group is fully resident once, then advances the
whole window in a tight loop with no per-command backend calls (bit-for-bit
identical results; see EXPERIMENTS.md "The macro-stepping invariant").
``pool="paged"`` swaps in the per-page reference pool for equivalence runs.

The task population is *dynamic*: besides the static ``programs`` set, callers
may supply ``task_events`` — timed :class:`TaskArrival`s whose programs are
admitted (optionally gated by an admission controller), run to completion
(``TaskProgram.total_iterations``), and then retire, tearing down their
address space and returning their HBM pages. With no events configured the
engine is bit-for-bit identical to the static simulator.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from itertools import islice
from typing import Callable, Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.commands import Command
from repro.core.demand_paging import DemandPager
from repro.core.hardware import Platform
from repro.core.hbm import HBMPool, make_pool
from repro.core.memory_manager import Coordinator, TaskHelper
from repro.core.migration import IndexReadyView, plan_population_runs
from repro.core.pages import AddressSpace, PageRun, clip_runs, pages_to_runs, run_page_count
from repro.core.planner import merged_command_runs
from repro.core.predictor import (
    AllocationPredictor,
    OraclePredictor,
    Predictor,
    TemplatePredictor,
)
from repro.core.profiler import profile_programs
from repro.core.scheduler import Policy, RoundRobinPolicy, SchedTask
from repro.core.templates import analyze_traces
from repro.core.timeline import TaskTimeline
from repro.core.workloads import TaskProgram

MIN_LOOKAHEAD_ITERS = 2  # async launch window (queued-but-not-executed)


# --------------------------------------------------------------------------
# Backends
# --------------------------------------------------------------------------


class Backend:
    name = "base"
    # True when executing a fully-resident command still mutates LRU state
    # (demand paging touches pages); the macro-stepper must replicate that
    resident_touch = False
    # True when on_switch reads the scheduling timeline (msched/ideal); the
    # engine skips building the multi-entry timeline otherwise — at serving
    # scale (2 ms TSG quanta, hundreds of tasks) that build dominates UM runs
    uses_timeline = False

    def on_switch(self, task_id: int, timeline: TaskTimeline, now: float):
        """Returns (control_us, ready_view | None). The view answers
        ``max_ready(runs)`` — the time the last-arriving page of ``runs``
        lands — in O(runs) instead of a per-page dict probe."""
        return 0.0, None

    def on_command(
        self, cmd: Command, runs: Sequence[PageRun], now: float
    ) -> float:
        return 0.0

    def admit_task(self, prog: TaskProgram) -> Optional[TaskHelper]:
        """Dynamic task arrival: set up per-task backend state. Returns the
        task's helper when the backend uses one (msched/ideal)."""
        return None

    def retire_task(self, task_id: int) -> None:
        """Dynamic task departure: tear down per-task backend state."""

    def faults(self) -> int:
        return 0

    def migrated_pages(self) -> int:
        return 0


class UMBackend(Backend):
    name = "um"
    resident_touch = True

    def __init__(self, platform: Platform, pool: HBMPool, page_size: int = 0):
        self.pager = DemandPager(platform, pool, page_size)

    def on_command(self, cmd, runs, now):
        return self.pager.access_runs(runs)

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self.pager.stats.migrated_pages


class MSchedBackend(Backend):
    name = "msched"
    uses_timeline = True

    def __init__(
        self,
        platform: Platform,
        pool: HBMPool,
        helpers: Dict[int, TaskHelper],
        pipelined: bool = True,
        control_free: bool = False,
        page_size: int = 0,
        legacy_planning: bool = False,
        predictor_factory: Optional[Callable[[TaskProgram], Predictor]] = None,
    ):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.coordinator = Coordinator(
            platform, pool, pipelined=pipelined, page_size=page_size,
            legacy=legacy_planning,
        )
        for h in helpers.values():
            self.coordinator.register(h)
        self.fallback = DemandPager(platform, pool, page_size)  # false negatives
        self.control_free = control_free
        self.predictor_factory = predictor_factory
        self._migrated = 0

    def admit_task(self, prog):
        if self.predictor_factory is None:
            raise RuntimeError("backend built without a predictor factory")
        helper = TaskHelper(prog.task_id, prog.space, self.predictor_factory(prog))
        self.coordinator.register(helper)
        return helper

    def retire_task(self, task_id):
        self.coordinator.unregister(task_id)

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline)
        self._migrated += report.populated_pages
        ctrl = 0.0 if self.control_free else report.madvise_us
        return ctrl, report.migration.ready_view(now + ctrl)

    def on_command(self, cmd, runs, now):
        # mispredictions fall back to standard demand paging (§5.2)
        missing = self.pool.missing_runs(runs)
        if not missing:
            return 0.0
        return self.fallback.access_runs(missing)

    def faults(self):
        return self.fallback.stats.faults

    def migrated_pages(self):
        return self._migrated + self.fallback.stats.migrated_pages


class IdealBackend(MSchedBackend):
    """Strict-OPT upper bound: oracle prediction, no control plane, and
    migration at the duplex bandwidth ceiling."""

    name = "ideal"

    def on_switch(self, task_id, timeline, now):
        report = self.coordinator.on_context_switch(task_id, timeline)
        self._migrated += report.populated_pages
        # population at the physically best per-direction rate: the duplex
        # ceiling is shared by concurrent eviction (swap = cap/2 each way,
        # matching the paper's 63.5 GB/s pipelined swap figure)
        rate = min(
            self.platform.h2d_gbps * 1e3, self.platform.duplex_cap_gbps * 1e3 / 2
        )
        ps = self.page_size
        runs = report.migration.populated_runs
        n = run_page_count(runs)
        if n == 0:
            return 0.0, None
        return 0.0, IndexReadyView(
            runs, lambda i: now + ((i + 1) * ps) / rate, n
        )


def _task_footprint_runs(prog: "TaskProgram") -> List[PageRun]:
    """Whole-footprint page runs in buffer (base) order — the SUV prefetch
    order and the warm-start fill order."""
    runs: List[PageRun] = []
    for b in sorted(prog.space.buffers.values(), key=lambda b: b.base):
        pages = prog.space.pages_of_extent((b.base, b.size))
        if not len(pages):
            continue
        s, e = pages.start, pages.stop
        if runs and runs[-1][1] == s:
            runs[-1] = (runs[-1][0], e)
        else:
            runs.append((s, e))
    return runs


class SUVBackend(Backend):
    """Static-analysis single-task prefetch: on switch, prefetch the whole
    footprint of the incoming task (hotness-ordered = buffer order), with no
    awareness of the other tasks' residency or of the schedule."""

    name = "suv"

    def __init__(self, platform: Platform, pool: HBMPool, programs, page_size: int = 0):
        self.platform = platform
        self.pool = pool
        self.page_size = page_size or platform.page_size
        self.pager = DemandPager(platform, pool, page_size)
        self._task_runs: Dict[int, List[PageRun]] = {}
        for prog in programs:
            self.admit_task(prog)
        self._migrated = 0

    def admit_task(self, prog):
        self._task_runs[prog.task_id] = _task_footprint_runs(prog)
        return None

    def retire_task(self, task_id):
        self._task_runs.pop(task_id, None)

    def on_switch(self, task_id, timeline, now):
        runs = self._task_runs.get(task_id, [])
        # cap the prefetch at HBM capacity (driver clamps)
        runs = clip_runs(runs, self.pool.capacity)
        populated, evicted = self.pool.migrate_runs(runs)
        self._migrated += run_page_count(populated)
        mig = plan_population_runs(
            self.platform, populated, run_page_count(evicted), False,
            self.page_size,
        )
        return 0.0, mig.ready_view(now)

    def on_command(self, cmd, runs, now):
        missing = self.pool.missing_runs(runs)
        return self.pager.access_runs(missing) if missing else 0.0

    def faults(self):
        return self.pager.stats.faults

    def migrated_pages(self):
        return self._migrated + self.pager.stats.migrated_pages


# --------------------------------------------------------------------------
# Dynamic task lifecycle
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskArrival:
    """A timed task-arrival event: ``program`` joins the task population at
    ``time_us`` (subject to admission control) and retires after
    ``program.total_iterations`` completed iterations."""

    time_us: float
    program: TaskProgram
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle timestamps of one dynamically-arrived task (one request in
    the serving regime); the raw material for SLO metrics."""

    task_id: int
    arrival_us: float
    admitted_us: Optional[float] = None
    first_iter_us: Optional[float] = None  # end of first completed iteration
    finished_us: Optional[float] = None
    iterations_done: int = 0
    total_iterations: Optional[int] = None
    rejected: bool = False
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def ttft_us(self) -> Optional[float]:
        """Time-to-first-token: arrival → end of the first iteration (the
        prefill + first decode step in the serving lifecycle)."""
        if self.first_iter_us is None:
            return None
        return self.first_iter_us - self.arrival_us

    def tpot_us(self) -> Optional[float]:
        """Time-per-output-token over the decode phase (post first token)."""
        if (
            self.finished_us is None
            or self.first_iter_us is None
            or not self.total_iterations
            or self.total_iterations < 2
        ):
            return None
        return (self.finished_us - self.first_iter_us) / (self.total_iterations - 1)

    def latency_us(self) -> Optional[float]:
        if self.finished_us is None:
            return None
        return self.finished_us - self.arrival_us

    def meets_slo(
        self,
        ttft_slo_us: Optional[float] = None,
        tpot_slo_us: Optional[float] = None,
    ) -> bool:
        if self.finished_us is None:
            return False
        if ttft_slo_us is not None:
            ttft = self.ttft_us()
            if ttft is None or ttft > ttft_slo_us:
                return False
        if (
            tpot_slo_us is not None
            and self.total_iterations is not None
            and self.total_iterations >= 2
        ):
            # single-token requests have no decode phase: TPOT is undefined
            # and cannot be violated
            tpot = self.tpot_us()
            if tpot is None or tpot > tpot_slo_us:
                return False
        return True


class AdmissionController:
    """Decides what happens when a dynamic task arrives (or is re-evaluated
    from the wait queue): ``"admit"``, ``"queue"``, or ``"reject"``.

    ``state`` is the live :class:`SimState` view — pool occupancy, active
    helpers (predicted working sets), the scheduling policy, and the clock —
    so controllers can be MSched-aware without owning simulator internals.
    """

    def decide(
        self, prog: TaskProgram, arrival_us: float, state: "SimState"
    ) -> str:
        return "admit"


@dataclasses.dataclass
class SimState:
    """Read-only view handed to admission controllers."""

    now: float
    platform: Platform
    pool: HBMPool
    policy: "Policy"
    page_size: int
    active: Dict[int, TaskProgram]
    helpers: Dict[int, TaskHelper]
    waiting: int  # queued-but-not-admitted candidates (FIFO ahead included)


# --------------------------------------------------------------------------
# Engine
# --------------------------------------------------------------------------


@dataclasses.dataclass
class TaskStats:
    completions: int = 0
    commands: int = 0
    busy_us: float = 0.0
    latencies_us: List[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class SimResult:
    sim_us: float
    per_task: Dict[int, TaskStats]
    faults: int
    migrated_bytes: int
    switches: int
    control_us: float
    # dynamic-lifecycle records (empty for static simulations)
    requests: List[RequestRecord] = dataclasses.field(default_factory=list)
    # end-of-run HBM occupancy / reclamation (leak accounting)
    hbm_used_pages: int = 0
    hbm_freed_pages: int = 0

    def total_completions(self) -> int:
        return sum(t.completions for t in self.per_task.values())

    # -- serving / SLO metrics ----------------------------------------------
    def finished_requests(self) -> List[RequestRecord]:
        return [r for r in self.requests if r.finished_us is not None]

    def request_metric_us(self, metric: str) -> List[float]:
        """Per-request metric samples: ``ttft`` | ``tpot`` | ``latency``."""
        fn = {
            "ttft": RequestRecord.ttft_us,
            "tpot": RequestRecord.tpot_us,
            "latency": RequestRecord.latency_us,
        }[metric]
        return [v for r in self.requests if (v := fn(r)) is not None]

    def request_percentile_us(self, metric: str, pct: float) -> float:
        xs = sorted(self.request_metric_us(metric))
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(pct / 100.0 * len(xs)))]

    def goodput_per_s(
        self,
        ttft_slo_us: Optional[float] = None,
        tpot_slo_us: Optional[float] = None,
        window_us: Optional[float] = None,
    ) -> float:
        """Completed requests per second that met every given SLO.

        ``window_us`` defaults to this run's makespan; cross-run comparisons
        (e.g. UM vs MSched on the same trace) must pass a *common* window, or
        the ratio conflates SLO attainment with drain speed.
        """
        window = window_us if window_us is not None else self.sim_us
        if not window:
            return 0.0
        good = sum(
            1 for r in self.requests if r.meets_slo(ttft_slo_us, tpot_slo_us)
        )
        return good / (window * 1e-6)

    def throughput_per_s(self) -> float:
        return self.total_completions() / (self.sim_us * 1e-6) if self.sim_us else 0.0

    def latency_percentile_us(
        self, pct: float, task_id: Optional[int] = None
    ) -> float:
        """Request-latency percentile over one task's (or all tasks')
        recorded arrival-to-completion latencies."""
        if task_id is not None:
            xs = sorted(self.per_task[task_id].latencies_us)
        else:
            xs = sorted(
                x for t in self.per_task.values() for x in t.latencies_us
            )
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(pct / 100.0 * len(xs)))]

    def p50_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(50.0, task_id)

    def p99_latency_us(self, task_id: Optional[int] = None) -> float:
        return self.latency_percentile_us(99.0, task_id)


class _RunTask:
    def __init__(
        self,
        prog: TaskProgram,
        helper: Optional[TaskHelper],
        lookahead_us: float = 0.0,
    ):
        self.prog = prog
        self.helper = helper
        self.lookahead_us = lookahead_us
        self.queue: Deque[Command] = deque()
        self.queued_us = 0.0
        self.iter_launched = 0
        self.cmd_in_iter = 0
        self.iter_len = 1
        self.total_iterations: Optional[int] = getattr(
            prog, "total_iterations", None
        )
        self.arrivals: Optional[Deque[float]] = None  # RT mode
        self.current_arrival: Optional[float] = None
        self.stats = TaskStats()
        self._refill()

    def _exhausted(self) -> bool:
        """Finite program with every iteration already launched."""
        return (
            self.total_iterations is not None
            and self.iter_launched >= self.total_iterations
        )

    def finished(self) -> bool:
        """Finite program with every iteration completed — retire the task."""
        return (
            self.total_iterations is not None
            and self.stats.completions >= self.total_iterations
        )

    def _launch_iter(self):
        cmds = self.prog.iteration(self.iter_launched)
        self.iter_len = len(cmds)
        for c in cmds:
            c.seq_no = self.iter_launched
            self.queue.append(c)
            self.queued_us += c.latency_us
            if self.helper is not None:
                self.helper.launch(c)
        self.iter_launched += 1

    def _refill(self):
        # the async launch window must cover at least one full timeslice of
        # future commands for the timeline plan to see the whole working set
        launched_iters = 0
        while (
            launched_iters < MIN_LOOKAHEAD_ITERS
            or self.queued_us < self.lookahead_us
        ):
            if self._exhausted():
                break
            self._launch_iter()
            launched_iters += 1
            if launched_iters > 10_000:
                break

    def peek(self) -> Command:
        return self.queue[0]

    def advance(self, now: float) -> bool:
        """Consume one command; returns True when an iteration completed."""
        cmd = self.queue.popleft()
        self.queued_us -= cmd.latency_us
        if self.helper is not None and len(self.helper.queue):
            self.helper.pop()
        self.cmd_in_iter += 1
        done = False
        if self.cmd_in_iter >= self.iter_len:
            self.cmd_in_iter = 0
            self.stats.completions += 1
            done = True
        if not self._exhausted() and (
            len(self.queue) < self.iter_len or self.queued_us < self.lookahead_us
        ):
            self._launch_iter()
        return done

    def runnable(self, now: float) -> bool:
        if self.arrivals is None:
            return True
        if self.current_arrival is not None:
            return True
        while self.arrivals and self.arrivals[0] <= now:
            self.current_arrival = self.arrivals.popleft()
            return True
        return False

    def next_arrival(self) -> Optional[float]:
        if self.arrivals is None or self.current_arrival is not None:
            return None
        return self.arrivals[0] if self.arrivals else None


def make_backend(
    name: str,
    platform: Platform,
    pool: HBMPool,
    programs: Sequence[TaskProgram],
    predictor_kind: str = "template",
    pipelined: bool = True,
    page_size: int = 0,
    planning: str = "incremental",
    profile_set: Optional[Sequence[TaskProgram]] = None,
) -> Tuple[Backend, Dict[int, TaskHelper]]:
    """``profile_set`` overrides the programs used for offline template
    analysis — dynamic scenarios profile representative programs up front and
    admit instances of the same kernels later."""
    helpers: Dict[int, TaskHelper] = {}
    if name == "um":
        return UMBackend(platform, pool, page_size), helpers
    if name == "suv":
        return SUVBackend(platform, pool, programs, page_size), helpers

    # msched / ideal need per-task helpers with a predictor; the factory is
    # kept on the backend so dynamically admitted tasks get the same kind
    if name == "ideal" or predictor_kind == "oracle":
        factory: Callable[[TaskProgram], Predictor] = lambda p: OraclePredictor()
    elif predictor_kind == "allocation":
        factory = lambda p: AllocationPredictor(p.space)
    else:  # template: offline profile + analyze (the real MSched flow)
        store = profile_programs(list(profile_set or programs), iters=4)
        descriptors = analyze_traces(store)
        factory = lambda p: TemplatePredictor(descriptors)
    for p in programs:
        helpers[p.task_id] = TaskHelper(p.task_id, p.space, factory(p))
    cls = IdealBackend if name == "ideal" else MSchedBackend
    backend = cls(
        platform, pool, helpers, pipelined=pipelined, page_size=page_size,
        legacy_planning=(planning == "legacy"),
        predictor_factory=factory,
    )
    return backend, helpers


def simulate(
    programs: Sequence[TaskProgram],
    platform: Platform,
    backend_name: str = "msched",
    capacity_bytes: Optional[int] = None,
    sim_us: float = 2_000_000.0,
    policy: Optional[Policy] = None,
    predictor_kind: str = "template",
    pipelined: bool = True,
    arrivals: Optional[Dict[int, List[float]]] = None,
    priorities: Optional[Dict[int, int]] = None,
    prepopulate: bool = True,
    planning: str = "incremental",
    task_events: Optional[Sequence[TaskArrival]] = None,
    admission: Optional[AdmissionController] = None,
    profile_set: Optional[Sequence[TaskProgram]] = None,
    page_size: int = 0,
    pool: str = "run",
) -> SimResult:
    if not page_size:
        if programs:
            page_size = programs[0].space.page_size
        elif task_events:
            page_size = task_events[0].program.space.page_size
        else:
            page_size = 4096
    all_progs = list(programs) + [ev.program for ev in task_events or ()]
    for prog in all_progs:
        if prog.space.page_size != page_size:
            raise ValueError(
                f"task {prog.task_id} uses page_size "
                f"{prog.space.page_size}, simulation uses {page_size}; "
                "pool residency keys would not be comparable"
            )
    cap_bytes = capacity_bytes or platform.hbm_bytes
    pool = make_pool(pool, max(1, cap_bytes // page_size))
    backend, helpers = make_backend(
        backend_name, platform, pool, programs, predictor_kind, pipelined,
        page_size, planning, profile_set,
    )
    cached_decode = planning != "legacy"
    policy = policy or RoundRobinPolicy()

    quantum = getattr(policy, "quantum_us", 5_000.0)
    tasks: Dict[int, _RunTask] = {}
    for prog in programs:
        rt = _RunTask(prog, helpers.get(prog.task_id), lookahead_us=2.2 * quantum)
        if arrivals and prog.task_id in arrivals:
            rt.arrivals = deque(arrivals[prog.task_id])
            rt.current_arrival = None
        tasks[prog.task_id] = rt
        pool.register_task(prog.task_id, prog.space.page_span())

    # warm start: fill HBM fairly (tasks ran before the measuring window).
    # migrate_runs over a fresh pool appends the exact page order the old
    # per-page populate loop produced, at O(runs)
    if prepopulate:
        share = pool.capacity // max(1, len(programs))
        for prog in programs:
            pool.migrate_runs(clip_runs(_task_footprint_runs(prog), share))

    # -- dynamic lifecycle state --------------------------------------------
    dynamic = bool(task_events)
    pending: Deque[TaskArrival] = deque(
        sorted(task_events or [], key=lambda e: e.time_us)
    )
    waiting: Deque[Tuple[TaskArrival, RequestRecord]] = deque()
    records: List[RequestRecord] = []
    rec_by_tid: Dict[int, RequestRecord] = {}
    retired_stats: Dict[int, TaskStats] = {}
    used_task_ids = set(tasks)  # static ids + every id ever admitted

    def _sim_state(now: float) -> SimState:
        return SimState(
            now=now,
            platform=platform,
            pool=pool,
            policy=policy,
            page_size=page_size,
            active={tid: r.prog for tid, r in tasks.items()},
            helpers=helpers,
            waiting=len(waiting),
        )

    def _admit(ev: TaskArrival, rec: RequestRecord, now: float) -> None:
        nonlocal sched_cache
        sched_cache = None
        prog = ev.program
        if prog.task_id in used_task_ids:
            raise ValueError(
                f"TaskArrival task_id {prog.task_id} collides with an "
                "existing task; ids must be unique across programs and events"
            )
        used_task_ids.add(prog.task_id)
        helper = backend.admit_task(prog)
        if helper is not None:
            helpers[prog.task_id] = helper
        rt = _RunTask(prog, helper, lookahead_us=2.2 * quantum)
        tasks[prog.task_id] = rt
        pool.register_task(prog.task_id, prog.space.page_span())
        rec.admitted_us = now
        if rt.finished():
            # degenerate zero-iteration program: it can never produce the
            # completion event that triggers retirement, so retire it here
            _retire(prog.task_id, now)

    def _retire(tid: int, now: float) -> None:
        nonlocal sched_cache
        sched_cache = None
        rt = tasks.pop(tid)
        backend.retire_task(tid)
        helpers.pop(tid, None)
        # final span (covers any post-admission allocations), then reclaim
        span = rt.prog.release()
        pool.register_task(tid, span)
        pool.free_task(tid)
        retired_stats[tid] = rt.stats
        rec = rec_by_tid.get(tid)
        if rec is not None:
            rec.finished_us = now
            rec.iterations_done = rt.stats.completions

    def _drain_waiting(now: float) -> None:
        # FIFO re-evaluation of the wait queue: stop at the first candidate
        # the controller still holds back (no overtaking)
        while waiting:
            ev, rec = waiting[0]
            verdict = (
                admission.decide(ev.program, ev.time_us, _sim_state(now))
                if admission is not None
                else "admit"
            )
            if verdict == "admit":
                waiting.popleft()
                _admit(ev, rec, now)
            elif verdict == "reject":
                waiting.popleft()
                rec.rejected = True
            else:
                break

    def _process_arrivals(now: float) -> None:
        # due arrivals join the wait queue in arrival order; one FIFO drain
        # then decides everyone (no overtaking: the drain stops at the first
        # candidate the controller holds back)
        while pending and pending[0].time_us <= now:
            ev = pending.popleft()
            rec = RequestRecord(
                ev.program.task_id,
                ev.time_us,
                total_iterations=getattr(ev.program, "total_iterations", None),
                meta=dict(ev.meta),
            )
            records.append(rec)
            rec_by_tid[ev.program.task_id] = rec
            waiting.append((ev, rec))
        _drain_waiting(now)

    def _complete(tid: int, rt: _RunTask, now: float) -> bool:
        """Post-iteration bookkeeping shared by the per-command and macro
        paths; returns True when the task finished and retired (end the
        timeslice)."""
        if rt.current_arrival is not None:
            rt.stats.latencies_us.append(now - rt.current_arrival)
            rt.current_arrival = None
            # next pending arrival (if already due) picked up by runnable()
        if dynamic:
            rec = rec_by_tid.get(tid)
            if rec is not None and rt.stats.completions == 1:
                rec.first_iter_us = now
        if rt.finished():
            # finite programs retire regardless of how they entered —
            # a drained static task must not pin the scheduler forever
            _retire(tid, now)
            if dynamic:
                _process_arrivals(now)  # freed pages may unblock the queue
            return True
        return False

    # purge degenerate zero-iteration static programs before the clock starts
    for tid in [tid for tid, rt in tasks.items() if rt.finished()]:
        _retire(tid, 0.0)

    t = 0.0
    switches = 0
    control_us = 0.0
    sched_cache: Optional[Dict[int, SchedTask]] = None
    while t < sim_us:
        if dynamic:
            _process_arrivals(t)
        if sched_cache is not None:
            sched = sched_cache
        else:
            sched = {
                tid: SchedTask(
                    tid,
                    priority=(priorities or {}).get(tid, 0),
                    runnable=rt.runnable(t),
                )
                for tid, rt in tasks.items()
            }
            # runnable-ness only changes with the clock in RT-arrivals mode;
            # otherwise the view is invalidated solely by admit/retire, so it
            # can be reused across the (possibly hundreds of thousands of)
            # switches of a long serving trace
            if all(rt.arrivals is None for rt in tasks.values()):
                sched_cache = sched
        entry = policy.next_entry(sched)
        if entry is None:
            # idle until the next RT arrival or task-arrival event
            nxt = [rt.next_arrival() for rt in tasks.values()]
            nxt = [x for x in nxt if x is not None]
            if pending:
                nxt.append(pending[0].time_us)
            if nxt:
                t = max(t, min(nxt))
                continue
            if waiting:
                # nothing running and nothing due: force-admit the queue head
                # (an idle device can always take work) to guarantee progress
                ev, rec = waiting.popleft()
                _admit(ev, rec, t)
                continue
            break
        # the timeline's first entry must be the task about to run —
        # next_entry() already rotated the policy's run queue past it.
        # Backends that never read the plan (um/suv) skip the multi-entry
        # build: at 2 ms TSG quanta over hundreds of serving tasks it is
        # pure overhead
        if backend.uses_timeline:
            timeline = TaskTimeline([entry] + policy.timeline(sched).entries)
        else:
            timeline = TaskTimeline([entry])
        ctrl, ready = backend.on_switch(entry.task_id, timeline, t)
        t += ctrl
        control_us += ctrl
        switches += 1

        rt = tasks[entry.task_id]
        if not rt.queue:
            # only reachable when iteration() returns no commands: fail loud
            # instead of spinning the scheduler at zero simulated time
            raise RuntimeError(
                f"task {entry.task_id} is runnable but has no queued "
                "commands; its iteration() produced an empty command list"
            )
        budget = entry.timeslice_us
        space = rt.prog.space
        tid = entry.task_id
        ready_max = ready.global_max if ready is not None else None
        # macro-stepping: once migration has landed (past the last ready
        # time), check the upcoming command window's merged working set once;
        # while it is fully resident, every command runs with zero stall and
        # no backend interaction, so advance the window in a tight loop.
        # A failed check disables re-checking until pool state changes again
        # (any command that actually stalls re-arms it).
        try_macro = cached_decode
        while budget > 0 and rt.runnable(t) and rt.queue:
            if try_macro and (ready_max is None or t >= ready_max):
                # cheap precheck: a window can only qualify if its first
                # command is fully resident — under fault-thrash (UM) this
                # skips the merged-group build entirely
                if not pool.all_resident_runs(rt.queue[0].true_page_runs(space)):
                    try_macro = False
                    window = 0
                else:
                    window = _macro_window(rt.queue, budget)
                merged = (
                    merged_command_runs(islice(rt.queue, window), space)
                    if window
                    else None
                )
                if merged is not None and pool.all_resident_runs(merged):
                    touches = backend.resident_touch
                    ended = False
                    while (
                        window > 0 and budget > 0 and rt.queue
                        and rt.runnable(t)
                    ):
                        cmd = rt.queue[0]
                        if touches:
                            pool.touch_runs(cmd.true_page_runs(space))
                        end = t + cmd.latency_us  # start == t, stall == 0
                        rt.stats.commands += 1
                        rt.stats.busy_us += end - t
                        budget -= end - t
                        t = end
                        window -= 1
                        if rt.advance(t) and _complete(tid, rt, t):
                            ended = True
                            break
                    if ended:
                        break
                    continue  # window exhausted: re-derive it
                try_macro = False
            cmd = rt.peek()
            # cached run-length decode; the legacy path re-walks the extents
            # per executed command (preserved for the sim-throughput baseline)
            if cached_decode:
                runs = cmd.true_page_runs(space)
            else:
                runs = pages_to_runs(_true_page_order(space, cmd))
            start = t
            if ready is not None and start < ready_max:
                r = ready.max_ready(runs)
                if r is not None and r > start:
                    start = r
            stall = backend.on_command(cmd, runs, start)
            if stall > 0.0:
                try_macro = cached_decode  # residency changed: re-arm
            end = start + stall + cmd.latency_us
            rt.stats.commands += 1
            rt.stats.busy_us += end - t
            budget -= end - t
            t = end
            if rt.advance(t) and _complete(tid, rt, t):
                break

    per_task = {tid: rt.stats for tid, rt in tasks.items()}
    per_task.update(retired_stats)
    return SimResult(
        sim_us=t,
        per_task=per_task,
        faults=backend.faults(),
        migrated_bytes=backend.migrated_pages() * page_size,
        switches=switches,
        control_us=control_us,
        requests=records,
        hbm_used_pages=pool.used,
        hbm_freed_pages=pool.freed_pages,
    )


def _true_page_order(space: AddressSpace, cmd: Command) -> List[int]:
    seen = set()
    order = []
    for ext in cmd.true_extents:
        for p in space.pages_of_extent(ext):
            if p not in seen:
                seen.add(p)
                order.append(p)
    return order


def _macro_window(queue: "Deque[Command]", budget_us: float) -> int:
    """Number of queued commands a zero-stall execution would start within
    ``budget_us`` (the slice consumption rule: a command starts while budget
    remains > 0)."""
    rem = budget_us
    k = 0
    for cmd in queue:
        if rem <= 0:
            break
        rem -= cmd.latency_us
        k += 1
    return k
