"""Live multi-task JAX runtime: MSched driving *real* array migrations.

Each task is a real (reduced-config) model from the zoo whose parameters are
page-granular segments in a task address space. "HBM" is a budgeted device
pool: resident segments are ``jax.Array``s, evicted segments live as host
numpy copies. On every context switch the MSched coordinator predicts the
next task's working set (template predictor over the decode command stream,
including the growing KV slice), enforces the OPT eviction order, and
migrates segments with real ``jax.device_put`` / host copies.

Correctness contract (tested): step outputs are bit-identical to an
all-resident baseline, because MSched migration is semantically transparent —
exactly the paper's OS-level transparency claim.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.commands import Command, kernel
from repro.core.hbm import HBMPool
from repro.core.memory_manager import Coordinator, TaskHelper
from repro.core.pages import AddressSpace
from repro.core.predictor import TemplatePredictor
from repro.core.profiler import profile_programs
from repro.core.scheduler import RoundRobinPolicy, SchedTask
from repro.core.templates import analyze_traces
from repro.core.timeline import TaskTimeline
from repro.core.hardware import TPU_V5E


@dataclasses.dataclass
class Segment:
    path: str
    base: int
    nbytes: int
    host: np.ndarray  # authoritative host copy when evicted
    device: Optional[jax.Array] = None  # resident copy


class LiveModelTask:
    """A decode job over a reduced model; weights are pageable segments."""

    def __init__(self, task_id: int, arch: str, page_size: int = 4096, seed: int = 0):
        from repro.models.model import build_model

        self.task_id = task_id
        self.cfg = get_config(arch).reduced()
        self.fns = build_model(self.cfg)
        self.space = AddressSpace(page_size=page_size, base=(task_id + 1) << 44)
        params = self.fns.init(jax.random.PRNGKey(seed))
        self.treedef = jax.tree.structure(params)
        leaves = jax.tree.leaves(params)
        paths = [
            "/".join(str(k) for k in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(params)[0]
        ]
        self.segments: List[Segment] = []
        for path, leaf in zip(paths, leaves):
            host = np.asarray(leaf)
            buf = self.space.malloc(max(host.nbytes, 1), path)
            self.segments.append(Segment(path, buf.base, host.nbytes, host))
        # decode state
        self.tokens = jnp.ones((1, 1), jnp.int32)
        self.pos = 0
        self.kv_buf = self.space.malloc(1 << 20, "kv")
        self._step = jax.jit(lambda p, t: self.fns.forward(p, {"tokens": t}))

    # -- command stream (the helper intercepts these) -----------------------
    def next_commands(self, step_idx: int) -> List[Command]:
        exts = [(s.base, s.nbytes) for s in self.segments]
        exts.append((self.kv_buf.base, min(4096 * (step_idx + 1), self.kv_buf.size)))
        args = tuple(s.base for s in self.segments[:8]) + (
            self.kv_buf.base,
            step_idx + 1,
            4096,
        )
        return [kernel(f"{self.cfg.name}_step", args, 500.0, exts)]

    # -- execution -----------------------------------------------------------
    def run_step(self, rng_step: int) -> np.ndarray:
        params = self.resident_params()
        tok = jnp.asarray([[1 + (rng_step % 13)]], jnp.int32)
        out = self._step(params, tok)
        return np.asarray(out)

    def resident_params(self):
        leaves = []
        for s in self.segments:
            if s.device is None:
                raise RuntimeError(f"segment {s.path} not resident (fault)")
            leaves.append(s.device)
        return jax.tree.unflatten(self.treedef, leaves)

    def footprint_bytes(self) -> int:
        return sum(s.nbytes for s in self.segments) + self.kv_buf.size

    # program interface used by the profiler
    def iteration(self, it: int) -> List[Command]:
        return self.next_commands(it)


@dataclasses.dataclass
class LiveStats:
    steps: Dict[int, int]
    migrated_in_bytes: int
    migrated_out_bytes: int
    demand_faults: int
    switch_wall_s: List[float]


class LiveRuntime:
    """Round-robin multitasking with proactive working-set migration."""

    def __init__(
        self,
        tasks: List[LiveModelTask],
        hbm_budget_bytes: int,
        steps_per_slice: int = 4,
        page_size: int = 4096,
    ):
        self.tasks = {t.task_id: t for t in tasks}
        self.page_size = page_size
        self.pool = HBMPool(max(1, hbm_budget_bytes // page_size))
        # offline phase: profile + analyze (real MSched flow)
        store = profile_programs(list(tasks), iters=3)
        descriptors = analyze_traces(store)
        self.coordinator = Coordinator(TPU_V5E, self.pool, page_size=page_size)
        self.helpers: Dict[int, TaskHelper] = {}
        for t in tasks:
            h = TaskHelper(t.task_id, t.space, TemplatePredictor(descriptors))
            self.helpers[t.task_id] = h
            self.coordinator.register(h)
        # page -> (task, segment) index for real data movement
        self.page_owner: Dict[int, Tuple[int, int]] = {}
        for t in tasks:
            for si, seg in enumerate(t.segments):
                for p in t.space.pages_of_extent((seg.base, seg.nbytes)):
                    self.page_owner[p] = (t.task_id, si)
        self.steps_per_slice = steps_per_slice
        self.policy = RoundRobinPolicy(quantum_us=1000.0 * steps_per_slice)
        self.stats = LiveStats({t.task_id: 0 for t in tasks}, 0, 0, 0, [])
        self._step_counter = {t.task_id: 0 for t in tasks}

    # -- real data movement ---------------------------------------------------
    def _sync_residency(self) -> None:
        """Make device arrays mirror the pool's residency decisions: a
        segment is on-device iff all of its pages are pool-resident."""
        for task in self.tasks.values():
            for seg in task.segments:
                pages = task.space.pages_of_extent((seg.base, seg.nbytes))
                resident = all(self.pool.resident(p) for p in pages)
                if resident and seg.device is None:
                    seg.device = jax.device_put(jnp.asarray(seg.host))  # H2D
                    self.stats.migrated_in_bytes += seg.nbytes
                elif not resident and seg.device is not None:
                    seg.host = np.asarray(seg.device)  # D2H eviction
                    seg.device = None
                    self.stats.migrated_out_bytes += seg.nbytes

    def _fault_in(self, task: LiveModelTask) -> None:
        """Demand-paging fallback: any still-missing segment faults in."""
        for seg in task.segments:
            if seg.device is None:
                pages = list(task.space.pages_of_extent((seg.base, seg.nbytes)))
                self.pool.migrate(pages)
                self._sync_residency()
                self.stats.demand_faults += 1

    # -- main loop -------------------------------------------------------------
    def run(self, total_slices: int = 12) -> LiveStats:
        for _ in range(total_slices):
            sched = {tid: SchedTask(tid) for tid in self.tasks}
            entry = self.policy.next_entry(sched)
            timeline = TaskTimeline([entry] + self.policy.timeline(sched).entries)
            task = self.tasks[entry.task_id]
            helper = self.helpers[entry.task_id]
            # refill the async window
            while len(helper.queue) < 2 * self.steps_per_slice:
                for cmd in task.next_commands(
                    self._step_counter[entry.task_id] + len(helper.queue)
                ):
                    helper.launch(cmd)
            # extended context switch: proactive working-set migration
            t0 = time.perf_counter()
            self.coordinator.on_context_switch(entry.task_id, timeline)
            self._sync_residency()
            self.stats.switch_wall_s.append(time.perf_counter() - t0)
            self._fault_in(task)
            for _ in range(self.steps_per_slice):
                step = self._step_counter[entry.task_id]
                task.run_step(step)
                self._step_counter[entry.task_id] += 1
                self.stats.steps[entry.task_id] += 1
                if helper.queue:
                    helper.pop()
        return self.stats
