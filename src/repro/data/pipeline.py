"""Deterministic, shardable token pipeline.

Sources: synthetic LM stream (hash-mixed, reproducible across restarts and
mesh shapes) or a binary token file (memory-mapped). The iterator state is a
single integer step, so checkpoint/restore and elastic re-mesh resume exactly
(no hidden RNG state) — the fault-tolerance substrate depends on this.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    token_file: Optional[str] = None  # raw int32 tokens; else synthetic


class TokenPipeline:
    """step -> batch dict {tokens, labels}; pure function of (config, step)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._mmap = None
        if cfg.token_file:
            self._mmap = np.memmap(cfg.token_file, dtype=np.int32, mode="r")

    def _synthetic(self, step: int) -> np.ndarray:
        c = self.cfg
        n = c.global_batch * (c.seq_len + 1)
        base = np.arange(n, dtype=np.uint64) + np.uint64(step) * np.uint64(n)
        mixed = (base * np.uint64(2654435761) + np.uint64(c.seed)) % np.uint64(
            2**31 - 1
        )
        toks = (mixed % np.uint64(max(c.vocab_size - 2, 1))).astype(np.int32) + 1
        return toks.reshape(c.global_batch, c.seq_len + 1)

    def _from_file(self, step: int) -> np.ndarray:
        c = self.cfg
        n = c.global_batch * (c.seq_len + 1)
        start = (step * n) % max(len(self._mmap) - n, 1)
        return np.asarray(self._mmap[start : start + n]).reshape(
            c.global_batch, c.seq_len + 1
        )

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        toks = self._from_file(step) if self._mmap is not None else self._synthetic(step)
        return {
            "tokens": np.ascontiguousarray(toks[:, :-1]),
            "labels": np.ascontiguousarray(toks[:, 1:]),
        }

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def pipeline_for(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0) -> TokenPipeline:
    return TokenPipeline(
        DataConfig(
            seq_len=shape.seq_len,
            global_batch=shape.global_batch,
            vocab_size=cfg.vocab_size,
            seed=seed,
        )
    )
