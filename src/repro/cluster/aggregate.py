"""Cluster-wide result aggregation: merge per-GPU ``SimResult``s and request
records into one fleet view with cluster p50/p99 metrics.

A migrated request leaves *fragments* on every GPU it touched — an
unfinished record on the source (arrival, maybe admission and first
iteration) and a continuation record on the target (its own arrival =
checkpoint landing, and the completion). :func:`merge_request_records`
stitches fragments back into one request-lifetime record keyed by task id,
so TTFT is measured from the *original* arrival and completion from wherever
the request actually finished. :class:`RequestStats` then condenses any
record list into the serving scoreboard (single sort per metric) — the same
percentile convention as ``SimResult.request_percentile_us`` — and is shared
by ``serving.engine.serve_trace`` (replacing its ad-hoc per-field
aggregation) and the cluster engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.simulator import (  # noqa: F401  (percentile re-exported)
    RequestRecord,
    SimResult,
    TaskStats,
    percentile,
)


def _merge_fragments(frags: List[RequestRecord]) -> RequestRecord:
    frags = sorted(frags, key=lambda r: r.arrival_us)
    first = frags[0]
    merged = RequestRecord(
        task_id=first.task_id,
        arrival_us=first.arrival_us,
        admitted_us=min(
            (r.admitted_us for r in frags if r.admitted_us is not None),
            default=None,
        ),
        first_iter_us=min(
            (r.first_iter_us for r in frags if r.first_iter_us is not None),
            default=None,
        ),
        finished_us=max(
            (r.finished_us for r in frags if r.finished_us is not None),
            default=None,
        ),
        iterations_done=sum(r.iterations_done for r in frags),
        # the source fragment carries the request's full iteration count;
        # continuations only the remainder
        total_iterations=max(
            (r.total_iterations for r in frags if r.total_iterations is not None),
            default=None,
        ),
        rejected=frags[-1].rejected,
    )
    for r in frags:
        merged.meta.update(r.meta)
    merged.meta["fragments"] = len(frags)
    return merged


def merge_request_records(
    per_gpu: Iterable[Sequence[RequestRecord]],
) -> List[RequestRecord]:
    """Merge per-GPU record lists into per-request records (first-seen
    order). Requests that stayed on one GPU pass through untouched."""
    by_tid: Dict[int, List[RequestRecord]] = {}
    order: List[int] = []
    for records in per_gpu:
        for rec in records:
            if rec.task_id not in by_tid:
                by_tid[rec.task_id] = []
                order.append(rec.task_id)
            by_tid[rec.task_id].append(rec)
    out: List[RequestRecord] = []
    for tid in order:
        frags = by_tid[tid]
        out.append(frags[0] if len(frags) == 1 else _merge_fragments(frags))
    return out


def merge_task_stats(per_gpu: Iterable[Dict[int, TaskStats]]) -> Dict[int, TaskStats]:
    """Sum per-task stats across GPUs (a migrated task contributes partial
    work on every GPU it ran on)."""
    out: Dict[int, TaskStats] = {}
    for stats_map in per_gpu:
        for tid, st in stats_map.items():
            cur = out.get(tid)
            if cur is None:
                out[tid] = TaskStats(
                    st.completions, st.commands, st.busy_us,
                    list(st.latencies_us),
                )
            else:
                cur.completions += st.completions
                cur.commands += st.commands
                cur.busy_us += st.busy_us
                cur.latencies_us.extend(st.latencies_us)
    return out


def merge_sim_results(
    results: Sequence[SimResult],
    records: Optional[List[RequestRecord]] = None,
) -> SimResult:
    """One fleet-level ``SimResult``: wall clock is the slowest GPU, counters
    are summed, and requests are the merged (de-fragmented) records."""
    if records is None:
        records = merge_request_records([r.requests for r in results])
    return SimResult(
        sim_us=max((r.sim_us for r in results), default=0.0),
        per_task=merge_task_stats([r.per_task for r in results]),
        faults=sum(r.faults for r in results),
        migrated_bytes=sum(r.migrated_bytes for r in results),
        switches=sum(r.switches for r in results),
        control_us=sum(r.control_us for r in results),
        requests=records,
        hbm_used_pages=sum(r.hbm_used_pages for r in results),
        hbm_freed_pages=sum(r.hbm_freed_pages for r in results),
    )


def peak_concurrent_bytes(
    footprints: Dict[int, int], records: Sequence[RequestRecord]
) -> float:
    """Peak concurrently-admitted footprint: sweep admit/finish edges.
    The oversubscription a run *actually* hit, for reporting."""
    edges: List[tuple] = []
    for rec in records:
        if rec.admitted_us is None:
            continue
        nbytes = footprints.get(rec.task_id, 0)
        edges.append((rec.admitted_us, 1, nbytes))
        if rec.finished_us is not None:
            edges.append((rec.finished_us, -1, nbytes))
    cur = peak = 0.0
    for _, sign, nbytes in sorted(edges):
        cur += sign * nbytes
        peak = max(peak, cur)
    return peak


@dataclasses.dataclass
class RequestStats:
    """Serving scoreboard over a record list (cluster-wide when the records
    are merged per-GPU fragments)."""

    n_requests: int
    n_finished: int
    n_rejected: int
    ttft_p50_us: float
    ttft_p99_us: float
    tpot_p50_us: float
    tpot_p99_us: float
    latency_p50_us: float
    latency_p99_us: float
    goodput_per_s: float
    throughput_per_s: float

    @classmethod
    def from_records(
        cls,
        records: Sequence[RequestRecord],
        ttft_slo_us: Optional[float],
        tpot_slo_us: Optional[float],
        window_us: float,
    ) -> "RequestStats":
        """``window_us`` is the offered-load window shared by every run
        replaying the same trace (see ``serve_trace``); goodput and
        throughput are normalized by it."""
        ttft = sorted(v for r in records if (v := r.ttft_us()) is not None)
        tpot = sorted(v for r in records if (v := r.tpot_us()) is not None)
        lat = sorted(v for r in records if (v := r.latency_us()) is not None)
        finished = sum(1 for r in records if r.finished_us is not None)
        good = sum(1 for r in records if r.meets_slo(ttft_slo_us, tpot_slo_us))
        window_s = max(window_us, 1.0) * 1e-6
        return cls(
            n_requests=len(records),
            n_finished=finished,
            n_rejected=sum(1 for r in records if r.rejected),
            ttft_p50_us=percentile(ttft, 50.0),
            ttft_p99_us=percentile(ttft, 99.0),
            tpot_p50_us=percentile(tpot, 50.0),
            tpot_p99_us=percentile(tpot, 99.0),
            latency_p50_us=percentile(lat, 50.0),
            latency_p99_us=percentile(lat, 99.0),
            goodput_per_s=good / window_s,
            throughput_per_s=finished / window_s,
        )
