"""Cluster serving engine: N per-GPU simulation cores under one event loop.

``simulate_cluster`` replays a request trace against a
:class:`~repro.cluster.topology.ClusterTopology`: every GPU is one
re-entrant :class:`~repro.core.simulator.SimCore` (its own HBM pool, memory
backend, scheduler, and admission controller), and the cluster loop owns the
global event stream — trace arrivals, dispatched to a GPU by the placement
policy the moment they arrive, and periodic rebalance ticks that migrate
work off pressured devices through the link graph.

The loop is a conservative discrete-event composition: between two global
events no interaction between GPUs is possible (tasks only meet at
placement/rebalance decisions), so each core safely advances to the next
event time on its own (``run(T, final=False)``), and the per-GPU results are
exact. With a single GPU the composition degenerates to exactly
``simulate()`` — bit-for-bit, for all four memory backends (pinned in
tests/cluster/test_cluster_engine.py).

``repro.serving`` is imported lazily: serving builds its per-run scoreboard
on :mod:`repro.cluster.aggregate`, and the module-level import edge must
point only that way.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

from repro.core.invariants import InvariantAuditor
from repro.core.scheduler import Policy, RoundRobinPolicy
from repro.core.simulator import AdmissionController, SimCore, SimResult
from repro.cluster.aggregate import (
    RequestStats,
    merge_request_records,
    merge_sim_results,
    peak_concurrent_bytes,
)
from repro.cluster.faults import (
    CheckpointVault,
    FaultInjector,
    FaultRuntime,
    RecoveryEvent,
)
from repro.cluster.migration import MigrationEvent, Rebalancer
from repro.cluster.placement import MSchedPlacement, PlacementPolicy, make_placement
from repro.cluster.prefetch import PeerFetchEvent, PeerPrefetchFabric
from repro.cluster.topology import HOST, ClusterTopology
from repro.cluster.transfer_plan import TransferPlanner
from repro.telemetry.hub import TRACK_CLUSTER

# version tag for ClusterReport.to_json artifacts (benchmarks/common.py).
# v2 added the control-plane fields (journal length, replays, coordinator
# crashes, deadline misses, preemptions, deadline sheds); v1 artifacts are
# still readable — the new fields default to zero.
REPORT_SCHEMA = "cluster-report-v2"
_ACCEPTED_SCHEMAS = ("cluster-report-v1", REPORT_SCHEMA)


def _result_to_json(res: SimResult) -> Dict[str, object]:
    return {
        "sim_us": res.sim_us,
        "per_task": {
            str(tid): dataclasses.asdict(st)
            for tid, st in res.per_task.items()
        },
        "faults": res.faults,
        "migrated_bytes": res.migrated_bytes,
        "switches": res.switches,
        "control_us": res.control_us,
        "requests": [dataclasses.asdict(r) for r in res.requests],
        "hbm_used_pages": res.hbm_used_pages,
        "hbm_freed_pages": res.hbm_freed_pages,
    }


def _result_from_json(doc: Dict[str, object]) -> SimResult:
    from repro.core.simulator import RequestRecord, TaskStats

    return SimResult(
        sim_us=doc["sim_us"],
        per_task={
            int(tid): TaskStats(**st) for tid, st in doc["per_task"].items()
        },
        faults=doc["faults"],
        migrated_bytes=doc["migrated_bytes"],
        switches=doc["switches"],
        control_us=doc["control_us"],
        requests=[RequestRecord(**r) for r in doc["requests"]],
        hbm_used_pages=doc["hbm_used_pages"],
        hbm_freed_pages=doc["hbm_freed_pages"],
    )


@dataclasses.dataclass
class GPUReport:
    """Per-device slice of a cluster run: how many arrivals placement
    dispatched here and the GPU's own ``SimResult`` (a migrated request
    contributes partial work to every GPU it visited)."""

    name: str
    platform: str
    capacity_bytes: int
    placed: int  # arrivals dispatched here (migrations land on top)
    result: SimResult

    def to_row(self) -> Dict[str, object]:
        return {
            "name": self.name,
            "platform": self.platform,
            "capacity_bytes": self.capacity_bytes,
            "placed": self.placed,
            "finished": len(self.result.finished_requests()),
            "faults": self.result.faults,
            "migrated_bytes": self.result.migrated_bytes,
            "switches": self.result.switches,
        }


@dataclasses.dataclass
class ClusterReport:
    """Fleet-level result of one ``simulate_cluster`` run: the cluster-wide
    serving scoreboard (``stats``, over de-fragmented request records), the
    merged ``SimResult``, per-GPU reports, the migration log, and — on
    NVLink fleets — the peer-prefetch accounting (fetch events, bytes moved
    GPU-to-GPU, and host-fallback pages lost to source-side eviction)."""

    backend: str
    placement: str
    n_gpus: int
    total_capacity_bytes: int
    oversubscription: float  # peak admitted demand / total capacity
    offered_rps: float
    slo: object  # SLOSpec
    stats: RequestStats  # cluster-wide, over merged records
    merged: SimResult
    per_gpu: List[GPUReport]
    migrations: List[MigrationEvent]
    deferred_migrations: int
    # NVLink peer-prefetch accounting (zero/empty on peer-less fleets)
    peer_fetches: List[PeerFetchEvent] = dataclasses.field(default_factory=list)
    peer_fetch_bytes: int = 0
    peer_fallback_pages: int = 0  # lingered pages lost to source eviction
    linger_reclaimed_pages: int = 0
    # of which: reclaimed by the finish hook at task retirement (instead of
    # leaking until the next rebalance tick)
    linger_finish_reaped: int = 0
    # fault-injection accounting (zero/empty on fault-free runs)
    faults_applied: int = 0
    recoveries: List[RecoveryEvent] = dataclasses.field(default_factory=list)
    shed_requests: int = 0  # graceful-degradation sheds
    lost_requests: int = 0  # no alive GPU ever came back for these
    retry_exhausted: int = 0  # continuations whose retry budget ran out
    checkpoints: int = 0
    checkpoint_bytes: int = 0
    # control-plane accounting (zero on runs without a ControlPlane; the
    # fields are unconditional so zero-fault rows compare equal with and
    # without one attached)
    journal_len: int = 0  # decision-journal records appended
    journal_replays: int = 0  # journal replays at coordinator recovery
    coordinator_crashes: int = 0
    deadline_misses: int = 0  # RT requests that missed TTFT/completion SLO
    preemptions: int = 0  # BE tasks preempted by deadline enforcement
    deadline_sheds: int = 0  # BE tasks shed after the escalation ladder
    # transfer-planner accounting (zero in transfer_plan="greedy" mode; the
    # fields stay in schema v2 — from_json defaults them for old artifacts)
    planned_transfers: int = 0  # flights admitted by the TransferPlanner
    planner_replans: int = 0  # committed plans rebooked by later admissions
    planner_deferred: int = 0  # speculative moves deferred by urgency

    def to_row(self) -> Dict[str, object]:
        """Flatten for JSON artifacts (benchmarks)."""
        row: Dict[str, object] = {
            "backend": self.backend,
            "placement": self.placement,
            "n_gpus": self.n_gpus,
            "total_capacity_bytes": self.total_capacity_bytes,
            "oversubscription": self.oversubscription,
            "offered_rps": self.offered_rps,
            "ttft_slo_us": self.slo.ttft_us,
            "tpot_slo_us": self.slo.tpot_us,
            "migrations": len(self.migrations),
            "migrated_requests": len(
                {m.task_id for m in self.migrations}
            ),
            "deferred_migrations": self.deferred_migrations,
            "peer_fetches": len(self.peer_fetches),
            "peer_fetch_bytes": self.peer_fetch_bytes,
            "peer_fallback_pages": self.peer_fallback_pages,
            "linger_finish_reaped": self.linger_finish_reaped,
            "faults_applied": self.faults_applied,
            "recoveries": len(self.recoveries),
            "recoveries_by_kind": {
                k: sum(1 for r in self.recoveries if r.kind == k)
                for k in ("checkpoint", "linger", "cold", "requeue")
            },
            "replayed_iters": sum(r.replayed_iters for r in self.recoveries),
            "shed_requests": self.shed_requests,
            "lost_requests": self.lost_requests,
            "retry_exhausted": self.retry_exhausted,
            "checkpoints": self.checkpoints,
            "checkpoint_bytes": self.checkpoint_bytes,
            "journal_len": self.journal_len,
            "journal_replays": self.journal_replays,
            "coordinator_crashes": self.coordinator_crashes,
            "deadline_misses": self.deadline_misses,
            "preemptions": self.preemptions,
            "deadline_sheds": self.deadline_sheds,
            "planned_transfers": self.planned_transfers,
            "planner_replans": self.planner_replans,
            "planner_deferred": self.planner_deferred,
            "per_gpu": [g.to_row() for g in self.per_gpu],
        }
        row.update(dataclasses.asdict(self.stats))
        return row

    # -- JSON artifact round-trip -------------------------------------------
    def to_json(self) -> Dict[str, object]:
        """Full-fidelity JSON-serializable form (benchmark artifacts).
        Everything :meth:`from_json` needs to reconstruct an equivalent
        report — nested results, request records, and event logs included —
        so artifact writers stop hand-rolling field extraction."""
        return {
            "schema": REPORT_SCHEMA,
            "backend": self.backend,
            "placement": self.placement,
            "n_gpus": self.n_gpus,
            "total_capacity_bytes": self.total_capacity_bytes,
            "oversubscription": self.oversubscription,
            "offered_rps": self.offered_rps,
            "slo": {"ttft_us": self.slo.ttft_us, "tpot_us": self.slo.tpot_us},
            "stats": dataclasses.asdict(self.stats),
            "merged": _result_to_json(self.merged),
            "per_gpu": [
                {
                    "name": g.name,
                    "platform": g.platform,
                    "capacity_bytes": g.capacity_bytes,
                    "placed": g.placed,
                    "result": _result_to_json(g.result),
                }
                for g in self.per_gpu
            ],
            "migrations": [dataclasses.asdict(m) for m in self.migrations],
            "deferred_migrations": self.deferred_migrations,
            "peer_fetches": [
                dataclasses.asdict(f) for f in self.peer_fetches
            ],
            "peer_fetch_bytes": self.peer_fetch_bytes,
            "peer_fallback_pages": self.peer_fallback_pages,
            "linger_reclaimed_pages": self.linger_reclaimed_pages,
            "linger_finish_reaped": self.linger_finish_reaped,
            "faults_applied": self.faults_applied,
            "recoveries": [dataclasses.asdict(r) for r in self.recoveries],
            "shed_requests": self.shed_requests,
            "lost_requests": self.lost_requests,
            "retry_exhausted": self.retry_exhausted,
            "checkpoints": self.checkpoints,
            "checkpoint_bytes": self.checkpoint_bytes,
            "journal_len": self.journal_len,
            "journal_replays": self.journal_replays,
            "coordinator_crashes": self.coordinator_crashes,
            "deadline_misses": self.deadline_misses,
            "preemptions": self.preemptions,
            "deadline_sheds": self.deadline_sheds,
            "planned_transfers": self.planned_transfers,
            "planner_replans": self.planner_replans,
            "planner_deferred": self.planner_deferred,
        }

    @classmethod
    def from_json(cls, doc: Dict[str, object]) -> "ClusterReport":
        from repro.serving.engine import SLOSpec  # lazy: import edge

        schema = doc.get("schema")
        if schema not in _ACCEPTED_SCHEMAS:
            raise ValueError(
                f"unknown cluster-report schema {schema!r} "
                f"(expected one of {_ACCEPTED_SCHEMAS})"
            )
        return cls(
            backend=doc["backend"],
            placement=doc["placement"],
            n_gpus=doc["n_gpus"],
            total_capacity_bytes=doc["total_capacity_bytes"],
            oversubscription=doc["oversubscription"],
            offered_rps=doc["offered_rps"],
            slo=SLOSpec(**doc["slo"]),
            stats=RequestStats(**doc["stats"]),
            merged=_result_from_json(doc["merged"]),
            per_gpu=[
                GPUReport(
                    name=g["name"],
                    platform=g["platform"],
                    capacity_bytes=g["capacity_bytes"],
                    placed=g["placed"],
                    result=_result_from_json(g["result"]),
                )
                for g in doc["per_gpu"]
            ],
            migrations=[MigrationEvent(**m) for m in doc["migrations"]],
            deferred_migrations=doc["deferred_migrations"],
            peer_fetches=[PeerFetchEvent(**f) for f in doc["peer_fetches"]],
            peer_fetch_bytes=doc["peer_fetch_bytes"],
            peer_fallback_pages=doc["peer_fallback_pages"],
            linger_reclaimed_pages=doc["linger_reclaimed_pages"],
            linger_finish_reaped=doc["linger_finish_reaped"],
            faults_applied=doc["faults_applied"],
            recoveries=[
                RecoveryEvent(**r) for r in doc["recoveries"]
            ],
            shed_requests=doc["shed_requests"],
            lost_requests=doc["lost_requests"],
            retry_exhausted=doc["retry_exhausted"],
            checkpoints=doc["checkpoints"],
            checkpoint_bytes=doc["checkpoint_bytes"],
            # v2 fields: absent from v1 artifacts, default 0
            journal_len=doc.get("journal_len", 0),
            journal_replays=doc.get("journal_replays", 0),
            coordinator_crashes=doc.get("coordinator_crashes", 0),
            deadline_misses=doc.get("deadline_misses", 0),
            preemptions=doc.get("preemptions", 0),
            deadline_sheds=doc.get("deadline_sheds", 0),
            planned_transfers=doc.get("planned_transfers", 0),
            planner_replans=doc.get("planner_replans", 0),
            planner_deferred=doc.get("planner_deferred", 0),
        )


def simulate_cluster(
    trace,
    topology: ClusterTopology,
    backend: str = "msched",
    placement: "PlacementPolicy | str" = "msched",
    admission_factory: Optional[Callable[[int], AdmissionController]] = None,
    policy_factory: Optional[Callable[[int], Policy]] = None,
    page_size: int = 1 << 20,
    predictor_kind: str = "template",
    slo=None,
    sim_us: Optional[float] = None,
    drain_factor: float = 8.0,
    rebalance_period_us: Optional[float] = None,
    rebalance_threshold: float = 0.5,
    max_moves_per_tick: int = 1,
    stage_dir: Optional[str] = None,
    pool: str = "run",
    peer_prefetch: str = "auto",
    faults: Optional[FaultInjector] = None,
    recovery: str = "auto",
    checkpoint_period_us: Optional[float] = None,
    audit: bool = False,
    shed_threshold: Optional[float] = 1.25,
    shed_rt_threshold: Optional[float] = None,
    retry_backoff_us: float = 0.0,
    control=None,
    telemetry=None,
    transfer_plan: str = "greedy",
) -> ClusterReport:
    """Replay ``trace`` across the cluster and report fleet-level serving
    quality.

    ``admission_factory`` / ``policy_factory`` build one controller/policy
    *per GPU* (they are stateful); index ``i`` is the GPU's position in the
    topology. ``rebalance_period_us`` enables inter-GPU migration at that
    cadence; ``stage_dir`` routes each checkpointed move through the sharded
    checkpoint format on disk. Other knobs mirror ``serve_trace``.

    ``peer_prefetch`` controls the NVLink peer-to-peer working-set machinery
    (page-location directory, lazy p2p migration, peer-sourced extended
    context switches, cluster-wide OPT eviction): ``"auto"`` enables it
    exactly when the topology has NVLink edges and the backend is
    ``msched``; ``"off"`` forces the plain composition (bulk transfers even
    over NVLink edges). Peer-less topologies and 1-GPU clusters behave
    identically under both settings — the machinery is never constructed.

    ``faults`` injects a :class:`~repro.cluster.faults.FaultInjector`
    schedule (GPU failures, link flaps, task crashes) as first-class
    events; ``recovery`` picks the re-placement policy (``"auto"`` prefers
    landed checkpoints, then linger copies, then cold restart; ``"cold"``
    / ``"linger"`` / ``"checkpoint"`` pin a single source for ablations)
    and ``checkpoint_period_us`` enables the periodic
    :class:`~repro.cluster.faults.CheckpointVault` D2H snapshots that feed
    it. ``shed_threshold`` / ``shed_rt_threshold`` bound graceful
    degradation when failures shrink capacity. An empty or absent
    ``faults`` constructs none of this machinery — fault-free runs are
    bit-for-bit identical to the plain composition. ``audit=True`` runs
    the read-only :class:`~repro.core.invariants.InvariantAuditor` at
    every failure boundary and rebalance tick (raises on violation).
    ``retry_backoff_us`` layers capped exponential delay onto the
    migration retry protocol (0 keeps retries instant).

    ``control`` attaches a :class:`repro.control.ControlPlane` (fresh per
    run): it journals every scheduler decision write-ahead, tracks task
    lifecycle, serves the ``submit``/``cancel``/``status`` API, survives
    ``coordinator_crash``/``coordinator_recover`` fault events (journal
    replay or cold restart, per its ``recovery`` mode), and — when built
    with deadlines — enforces RT SLOs by preempting best-effort work.
    Schedules containing coordinator events *require* it. On a zero-fault
    run with no deadline enforcement the control plane is a pure observer:
    results are bit-for-bit identical to ``control=None``.

    ``telemetry`` attaches one :class:`repro.telemetry.Telemetry` hub to
    the whole fleet: every core, the rebalancer, the prefetch fabric, and
    the fault runtime emit into it, rebalance ticks sample the cluster
    probes (per-GPU occupancy, per-link in-flight bytes and sharers, host
    staging), and the stall ledger is resolved against the merged result
    before returning. ``None`` (the default) emits nothing and takes
    exactly the untraced code paths.

    ``transfer_plan`` selects the bulk-transfer pricing model.
    ``"greedy"`` (the default) prices every movement independently at
    request time with the fluid-at-start share — the historical
    behaviour, preserved bit-for-bit (the planner object is never
    constructed). ``"auto"`` attaches a
    :class:`~repro.cluster.transfer_plan.TransferPlanner` to multi-GPU
    topologies: rebalance windows batch their checkpoint/p2p movements
    into one routed, urgency-ordered schedule, landing estimates are
    re-solved as sharers drain (pending arrivals are retimed in place),
    saturated host links detour over idle NVLink paths, and speculative
    moves whose projected landing exceeds ``defer_stretch`` x their solo
    time are deferred to the next tick.
    """
    # lazy: serving depends on cluster.aggregate at module level; the
    # reverse edge must not exist at import time
    from repro.serving.engine import SLOSpec, build_events, representative_requests

    slo = slo or SLOSpec()
    if (
        control is None
        and faults is not None
        and any(
            ev.kind in ("coordinator_crash", "coordinator_recover")
            for ev in faults.events
        )
    ):
        raise ValueError(
            "coordinator_crash/coordinator_recover fault events require a "
            "control plane: pass control=ControlPlane(...)"
        )
    events = build_events(trace, page_size=page_size)
    footprints = {
        ev.program.task_id: ev.program.footprint_bytes() for ev in events
    }
    reps = representative_requests(trace, page_size=page_size)
    placement = make_placement(placement)
    cores = [
        SimCore(
            [],
            node.platform,
            backend,
            capacity_bytes=node.hbm_bytes,
            policy=policy_factory(i) if policy_factory else RoundRobinPolicy(),
            predictor_kind=predictor_kind,
            admission=admission_factory(i) if admission_factory else None,
            profile_set=reps,
            page_size=page_size,
            prepopulate=False,
            pool=pool,
            dynamic=True,
            name=node.name,
            telemetry=telemetry,
        )
        for i, node in enumerate(topology.gpus)
    ]
    horizon = sim_us or max(1.0, trace.duration_us()) * drain_factor
    # contention state is per-run: a reused topology must not price this
    # run's transfers against a previous run's in-flight migrations
    topology.reset_transfers()
    # NVLink fleets get the peer-prefetch fabric: page-location directory,
    # peer-sourced extended context switches, cluster-wide OPT eviction.
    # Peer-less fleets never construct it — their composition is untouched.
    if peer_prefetch not in ("auto", "off"):
        raise ValueError(
            f"peer_prefetch must be 'auto' or 'off', got {peer_prefetch!r}"
        )
    if transfer_plan not in ("auto", "greedy"):
        raise ValueError(
            f"transfer_plan must be 'auto' or 'greedy', got {transfer_plan!r}"
        )
    fabric = None
    wired_placement = False
    prev_placement_topo = None
    if (
        peer_prefetch != "off"
        and backend == "msched"
        and topology.has_nvlink()
    ):
        fabric = PeerPrefetchFabric(topology, cores)
        fabric.wire()
        if isinstance(placement, MSchedPlacement):
            # fluid-share-aware landing ties for *this* run's topology;
            # restored afterwards so a reused instance never consults a
            # previous run's contention state
            prev_placement_topo = placement.topology
            placement.topology = topology
            wired_placement = True
    rebalancer = (
        Rebalancer(
            topology,
            threshold=rebalance_threshold,
            max_moves=max_moves_per_tick,
            stage_dir=stage_dir,
            prefetch=fabric,
            retry_backoff_us=retry_backoff_us,
        )
        if rebalance_period_us
        else None
    )
    if rebalancer is not None:
        # the retry protocol needs the fleet even before the first tick
        rebalancer.attach(cores)
    placed = [0] * len(cores)

    # fault machinery: constructed only for a non-empty schedule, so
    # fault-free runs (faults=None or FaultInjector.none()) take exactly
    # the plain code path — the structural bit-for-bit guarantee
    fault_rt = None
    vault = None
    if faults is not None and not faults.empty:
        if checkpoint_period_us:
            vault = CheckpointVault(topology, page_size, stage_dir=stage_dir)
        fault_rt = FaultRuntime(
            faults,
            topology,
            cores,
            placement,
            fabric=fabric,
            vault=vault,
            recovery=recovery,
            shed_threshold=shed_threshold,
            shed_rt_threshold=shed_rt_threshold,
        )
    auditor = (
        InvariantAuditor(
            cores,
            topology=topology,
            fabric=fabric,
            vault=vault,
            control=control,
        )
        if audit
        else None
    )
    if telemetry is not None:
        # pure observers: components check `telemetry is not None` at each
        # emission site, so the None path is structurally unchanged
        for component in (fabric, rebalancer, fault_rt, vault):
            if component is not None:
                component.telemetry = telemetry
    if control is not None:
        control.attach(
            cores,
            topology=topology,
            placement=placement,
            fabric=fabric,
            rebalancer=rebalancer,
            vault=vault,
            fault_rt=fault_rt,
            telemetry=telemetry,
        )

    # scheduled transfer planning: "greedy" (the default) never constructs
    # the planner — every movement keeps the historical per-request fluid
    # pricing bit-for-bit. "auto" attaches the window planner to multi-GPU
    # fleets; 1-GPU topologies have no inter-GPU movement to schedule.
    planner = None
    if transfer_plan == "auto" and len(topology) > 1:
        planner = TransferPlanner(topology, telemetry=telemetry)
        topology.planner = planner
        if fabric is not None:
            fabric.planner = planner
        core_by_name = {c.name: c for c in cores}

        def _retime_arrival(plan, old_arrival):
            # a sharer drained (or a cancel freed a leg): the planner moved
            # this flight's landing, so the pending arrival injected at the
            # stale estimate must move with it. Only bulk moves that inject
            # a TaskArrival exactly at plan.arrival_us are retimed —
            # snapshots land on HOST and redispatches offset the arrival,
            # so both fail the match and are safely skipped.
            if plan.kind not in ("checkpoint", "p2p", "restore"):
                return
            if plan.dst == HOST or plan.task_id is None:
                return
            core = core_by_name.get(plan.dst)
            if core is None:
                return
            moved = False
            for ev in core.pending:
                if (
                    ev.program.task_id == plan.task_id
                    and abs(ev.time_us - old_arrival) < 1e-6
                ):
                    ev.time_us = plan.arrival_us
                    moved = True
            if moved:
                core.pending = deque(
                    sorted(core.pending, key=lambda e: e.time_us)
                )
            if fabric is not None and plan.kind == "p2p":
                entry = fabric.directory.get(plan.task_id)
                if (
                    entry is not None
                    and abs(entry.arrival_us - old_arrival) < 1e-6
                ):
                    entry.arrival_us = plan.arrival_us

        topology.replan_hook = _retime_arrival

    # -- the cluster event loop --------------------------------------------
    try:
        ev_i = 0
        next_tick = rebalance_period_us if rebalancer else float("inf")
        next_ck = (
            checkpoint_period_us
            if fault_rt is not None and checkpoint_period_us
            else float("inf")
        )
        while True:
            t_ev = events[ev_i].time_us if ev_i < len(events) else float("inf")
            t_tick = next_tick if next_tick <= horizon else float("inf")
            t_fault = fault_rt.next_time() if fault_rt else float("inf")
            t_ck = next_ck if next_ck <= horizon else float("inf")
            t_ctl = control.next_time() if control is not None else float("inf")
            t_ctl = t_ctl if t_ctl <= horizon else float("inf")
            T = min(t_ev, t_tick, t_fault, t_ck, t_ctl)
            if T == float("inf"):
                break
            for core in cores:
                core.run(T, final=False)
            if t_fault <= T:
                # failures first: a fault and an arrival at the same
                # instant must not dispatch the arrival to the dying GPU
                fault_rt.apply_due(T)
                if auditor is not None:
                    auditor.check(T, "fault")
            elif t_ck <= T:
                # snapshotting is a coordinator decision: skipped while the
                # control plane is down (the cadence keeps advancing)
                if control is None or not control.down:
                    vault.snapshot(cores, T)
                    vault.prune(cores, fault_rt.live_extra())
                next_ck += checkpoint_period_us
            elif t_ctl <= T:
                # scheduled submit/cancel ops and deadline enforcement;
                # next_time() is inf while the coordinator is down and when
                # nothing is scheduled, so runs without ops or deadline
                # monitoring never reach this branch
                control.tick(T)
            elif t_ev <= t_tick:
                ev = events[ev_i]
                ev_i += 1
                if control is not None:
                    control.on_arrival(ev)
                elif fault_rt is not None:
                    fault_rt.dispatch(ev)
                else:
                    gi = placement.place(ev.program, ev.time_us, cores)
                    cores[gi].inject(ev)
                    placed[gi] += 1
            else:
                if control is None or not control.down:
                    # rebalancing (and the directory reap it implies) is
                    # coordinator work — suspended during an outage
                    moves = rebalancer.tick(cores, T)
                    if fabric is not None:
                        # lingering copies of finished tasks are garbage
                        fabric.reap()
                    if telemetry is not None:
                        telemetry.instant(
                            "rebalance_tick", TRACK_CLUSTER, T, moves=len(moves)
                        )
                        _sample_cluster_probes(telemetry, topology, cores, T)
                next_tick += rebalance_period_us
                if auditor is not None:
                    auditor.check(T, "tick")
        while True:
            for core in cores:
                core.run(horizon, final=True)
            # a reject hook firing during the terminal drain may bounce a
            # continuation into a core that already drained — re-drain until
            # quiescent (the retry budget bounds the bounces, so this
            # terminates; without retries pending is empty after one pass
            # and the composition is exactly the single terminal drain)
            leftover = [c for c in cores if c.pending]
            if not leftover:
                break
            # the next pass must actually re-enter the drained cores: push
            # the drain horizon past both the bounced arrivals and the
            # cores' (possibly overrun) clocks
            horizon = max(
                [horizon]
                + [c.pending[0].time_us + 1.0 for c in leftover]
                + [c.t + 1.0 for c in leftover]
            )
    finally:
        if wired_placement:
            placement.topology = prev_placement_topo
        # a reused topology must not carry this run's planner (or retime
        # hook, which closes over this run's cores) into the next run
        topology.planner = None
        topology.replan_hook = None
    if fabric is not None:
        # reclaim every remaining linger copy so end-of-run HBM accounting
        # balances (leak checks read pool.used)
        fabric.reap(final=True)
    lost_records: List = []
    if control is not None:
        # must run before fault_rt.drain_lost(): the control plane accounts
        # journal-known work that is NOT live in the runtime queues (plus
        # any backlog arrivals swallowed by a terminal outage), leaving the
        # live queue items for the runtime drain — no double counting
        lost_records.extend(control.drain_lost())
    if fault_rt is not None:
        if vault is not None:
            vault.prune(cores, fault_rt.live_extra())
        # work the fleet could never re-place is accounted, not dropped
        lost_records.extend(fault_rt.drain_lost())
        for i in range(len(placed)):
            placed[i] += fault_rt.placed[i]
    if control is not None:
        for i in range(len(placed)):
            placed[i] += control.placed[i]
    if auditor is not None:
        auditor.check(horizon, "final")

    results = [core.result() for core in cores]
    records = merge_request_records(
        [r.requests for r in results]
        + ([lost_records] if lost_records else [])
    )
    merged = merge_sim_results(results, records)
    window_us = max(trace.duration_us(), 1.0)
    stats = RequestStats.from_records(
        records, slo.ttft_us, slo.tpot_us, window_us
    )
    total_cap = sum(node.hbm_bytes for node in topology.gpus)
    peak = peak_concurrent_bytes(footprints, records)
    if control is not None:
        # stamp RT deadline outcomes from the merged records (post-hoc
        # bookkeeping only — no simulation effect)
        control.finalize(records)
    report = ClusterReport(
        backend=backend,
        placement=placement.name,
        n_gpus=len(cores),
        total_capacity_bytes=total_cap,
        oversubscription=peak / total_cap if total_cap else 0.0,
        offered_rps=trace.offered_rate_rps(),
        slo=slo,
        stats=stats,
        merged=merged,
        per_gpu=[
            GPUReport(
                name=node.name,
                platform=node.platform.name,
                capacity_bytes=node.hbm_bytes,
                placed=placed[i],
                result=results[i],
            )
            for i, node in enumerate(topology.gpus)
        ],
        migrations=list(rebalancer.events) if rebalancer else [],
        deferred_migrations=topology.deferred,
        peer_fetches=list(fabric.fetches) if fabric else [],
        peer_fetch_bytes=fabric.peer_bytes() if fabric else 0,
        peer_fallback_pages=fabric.fallback_pages if fabric else 0,
        linger_reclaimed_pages=fabric.reclaimed_pages if fabric else 0,
        linger_finish_reaped=fabric.finish_reaped if fabric else 0,
        faults_applied=len(fault_rt.applied) if fault_rt else 0,
        recoveries=list(fault_rt.recoveries) if fault_rt else [],
        shed_requests=len(fault_rt.shed_events) if fault_rt else 0,
        lost_requests=(fault_rt.lost if fault_rt else 0)
        + (control.lost if control else 0),
        retry_exhausted=rebalancer.exhausted if rebalancer else 0,
        checkpoints=vault.taken if vault else 0,
        checkpoint_bytes=vault.bytes if vault else 0,
        journal_len=len(control.journal) if control else 0,
        journal_replays=control.replays if control else 0,
        coordinator_crashes=control.crashes if control else 0,
        deadline_misses=control.deadline_misses if control else 0,
        preemptions=control.preemptions if control else 0,
        deadline_sheds=control.deadline_sheds if control else 0,
        planned_transfers=len(planner.log) if planner else 0,
        planner_replans=topology.replans,
        planner_deferred=planner.urgency_deferred if planner else 0,
    )
    if telemetry is not None:
        telemetry.finalize_cluster(report)
    return report


def _sample_cluster_probes(
    telemetry, topology: ClusterTopology, cores: Sequence[SimCore], now: float
) -> None:
    """Fleet-level time-series probes, sampled at every rebalance tick
    (never strided — ticks are already sparse): per-GPU HBM occupancy and
    queue depths, host staging-budget usage, and per-link in-flight bytes
    and sharer counts."""
    for core in cores:
        telemetry.counter(core.name, "hbm_used_pages", now, core.pool.used)
        telemetry.counter(core.name, "run_queue_depth", now, len(core.tasks))
        telemetry.counter(
            core.name, "wait_queue_depth", now, len(core.waiting)
        )
    telemetry.counter(
        "host", "staged_bytes", now, topology.host_staged_bytes(now)
    )
    # planned runs also expose the scheduler's own per-link queue (flights
    # with a remaining leg on the link); greedy runs have no planner and
    # emit exactly the historical probe set
    depths = (
        topology.planner.link_queue_depths(now)
        if topology.planner is not None
        else None
    )
    for link in topology.links():
        track = f"link:{link.a}<->{link.b}"
        telemetry.counter(
            track, "sharers", now, topology.active_on(link.a, link.b, now)
        )
        telemetry.counter(
            track,
            "inflight_bytes",
            now,
            topology.inflight_bytes(link.a, link.b, now),
        )
        if depths is not None:
            telemetry.counter(
                track,
                "queue_depth",
                now,
                depths.get(frozenset((link.a, link.b)), 0),
            )
