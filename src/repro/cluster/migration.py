"""Inter-GPU task migration: checkpoint the working set, price the transfer
on the link graph, re-admit a continuation on the target GPU.

Migration is iteration-granular: the source core ejects the task between
scheduler steps (``SimCore.eject``), which snapshots the resident working
set; work of a partially-completed iteration is replayed on the target. The
working set travels either peer-to-peer (NVLink edge) or host-staged
(src → host DRAM → dst), with link contention and the host staging budget
enforced by :class:`~repro.cluster.topology.ClusterTopology`. On the target,
the continuation (:class:`ResumedTask`, same task id and address space,
iteration counter offset past the completed prefix) arrives as a normal
``TaskArrival`` at the transfer's landing time, with the checkpointed runs
populated into HBM at admission — the restore half of the move.

When a ``stage_dir`` is given, the working-set manifest actually round-trips
through ``repro.checkpointing.checkpoint`` (the sharded .npy + msgpack
format) — the host-staged path writes real files, and the restored manifest
is what re-admission uses, so checkpoint integrity is on the migration
path, not asserted on the side.

The cheap rebalance move is *stealing*: a queued-but-unadmitted candidate on
the pressured GPU has nothing resident, so rerouting it costs nothing but
the decision. :class:`Rebalancer` always prefers steals and only checkpoints
running tasks when the wait queue is empty.

Known policy interaction: a migrated continuation queues behind the *target*
GPU's admission controller like any arrival, so a controller with a wait
deadline (``MSchedAdmission(max_wait_us=...)``) can reject a
partially-executed request outright — the record ends rejected with its
completed prefix banked on the source. A return-to-source / retry protocol
is an open item (ROADMAP); the shipped benchmarks use deadline-free
admission, where continuations always eventually admit.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.hbm import resident_runs_in
from repro.core.pages import PageRun, run_page_count
from repro.core.simulator import (
    EjectedTask,
    SimCore,
    TaskArrival,
    active_demand_pages,
)
from repro.core.workloads import TaskProgram
from repro.cluster.topology import ClusterTopology


@dataclasses.dataclass
class MigrationEvent:
    """One completed rebalance move, for reporting."""

    time_us: float
    task_id: int
    src: str
    dst: str
    kind: str  # "steal" (queued candidate) | "checkpoint" (running task)
    pages: int
    nbytes: int
    arrival_us: float  # when the task lands on dst
    completed_iters: int = 0


class ResumedTask(TaskProgram):
    """Continuation of a migrated task: same task id and address space, with
    the iteration counter offset past the prefix completed on the source
    GPU. The inner program is *not* released on the source — its space (and
    the page-key identity the pools share) travels with it."""

    def __init__(self, inner: TaskProgram, completed: int):
        # no super().__init__: the continuation adopts the inner program's
        # address space instead of allocating a fresh one
        self.inner = inner
        self.task_id = inner.task_id
        self.space = inner.space
        self.name = f"{getattr(inner, 'name', 'task')}+mig{completed}"
        self.offset = completed
        total = getattr(inner, "total_iterations", None)
        self.total_iterations = (
            None if total is None else max(0, total - completed)
        )

    def iteration(self, it: int):
        return self.inner.iteration(it + self.offset)

    def footprint_bytes(self) -> int:
        return self.inner.footprint_bytes()

    def release(self):
        return self.inner.release()


# --------------------------------------------------------------------------
# Working-set checkpointing (through repro.checkpointing)
# --------------------------------------------------------------------------


def pack_working_set(ej: EjectedTask, page_size: int) -> Dict[str, np.ndarray]:
    """The migration manifest as a flat pytree of host arrays — what the
    host-staged path serializes."""
    starts = np.asarray([s for s, _ in ej.resident_runs], dtype=np.int64)
    stops = np.asarray([e for _, e in ej.resident_runs], dtype=np.int64)
    return {
        "task_id": np.int64(ej.program.task_id),
        "completed": np.int64(ej.completed),
        "page_size": np.int64(page_size),
        "resident_starts": starts,
        "resident_stops": stops,
    }


def unpack_working_set(tree: Dict[str, np.ndarray]) -> List[PageRun]:
    return [
        (int(s), int(e))
        for s, e in zip(tree["resident_starts"], tree["resident_stops"])
    ]


def checkpoint_roundtrip(
    stage_dir: str, seq: int, ej: EjectedTask, page_size: int
) -> List[PageRun]:
    """Stage the working-set manifest through the sharded checkpoint format
    and return the *restored* resident runs (what re-admission warms HBM
    with). Imported lazily: the simulation path stays jax-free unless a
    stage dir is configured."""
    from repro.checkpointing import checkpoint

    tree = pack_working_set(ej, page_size)
    checkpoint.save(stage_dir, seq, tree, keep=4)
    n = len(ej.resident_runs)
    target = {
        "task_id": np.zeros((), np.int64),
        "completed": np.zeros((), np.int64),
        "page_size": np.zeros((), np.int64),
        "resident_starts": np.zeros((n,), np.int64),
        "resident_stops": np.zeros((n,), np.int64),
    }
    restored = checkpoint.restore(stage_dir, seq, target)
    if int(restored["task_id"]) != ej.program.task_id:
        raise RuntimeError(
            f"checkpoint round-trip mismatch: staged task "
            f"{int(restored['task_id'])}, expected {ej.program.task_id}"
        )
    return unpack_working_set(restored)


# --------------------------------------------------------------------------
# Rebalancer
# --------------------------------------------------------------------------


class Rebalancer:
    """Periodic load rebalancing across cores.

    Pressure is memory demand relative to capacity — the same per-cycle
    demand admission and placement price (predicted per-quantum working sets
    plus the queued backlog). Each tick moves at most ``max_moves`` tasks
    from the most- to the least-pressured GPU while the gap exceeds
    ``threshold``; steals (queued candidates) are free, checkpointed moves
    of running tasks pay the link-graph transfer time and host staging.
    """

    def __init__(
        self,
        topology: ClusterTopology,
        threshold: float = 0.5,
        max_moves: int = 1,
        quantum_us: Optional[float] = None,
        stage_dir: Optional[str] = None,
    ):
        assert threshold > 0
        self.topology = topology
        self.threshold = threshold
        self.max_moves = max_moves
        self.quantum_us = quantum_us
        self.stage_dir = stage_dir
        self.events: List[MigrationEvent] = []
        self._seq = 0

    def pressure(self, core: SimCore) -> float:
        st = core.state_view()
        quantum = self.quantum_us or getattr(st.policy, "quantum_us", 5_000.0)
        return (active_demand_pages(st, quantum) + st.waiting_pages) / max(
            1, st.pool.capacity
        )

    def tick(self, cores: Sequence[SimCore], now: float) -> List[MigrationEvent]:
        moves: List[MigrationEvent] = []
        for _ in range(self.max_moves):
            loads = [self.pressure(c) for c in cores]
            si = max(range(len(cores)), key=lambda i: loads[i])
            di = min(range(len(cores)), key=lambda i: loads[i])
            if si == di or loads[si] - loads[di] < self.threshold:
                break
            mv = self._move_one(cores[si], cores[di], now)
            if mv is None:
                break
            moves.append(mv)
        self.events.extend(moves)
        return moves

    def _move_one(
        self, src: SimCore, dst: SimCore, now: float
    ) -> Optional[MigrationEvent]:
        stolen = src.steal_waiting()
        if stolen is not None:
            ev, rec, warm = stolen
            # a stolen candidate may itself be a migrated continuation whose
            # checkpointed working set was still waiting for admission: the
            # warm runs travel with it (staged in host DRAM either way)
            dst.inject(
                TaskArrival(
                    max(now, ev.time_us),
                    ev.program,
                    meta=dict(ev.meta, rerouted_from=src.name),
                ),
                warm_runs=warm,
            )
            return MigrationEvent(
                now, ev.program.task_id, src.name, dst.name, "steal",
                0, 0, max(now, ev.time_us),
            )
        tid = self._pick_victim(src)
        if tid is None:
            return None
        # price the transfer before ejecting: a host-DRAM-budget denial must
        # leave the source untouched (retry next tick)
        span = src.tasks[tid].prog.space.page_span()
        resident = resident_runs_in(src.pool, span)
        nbytes = run_page_count(resident) * src.page_size
        plan = self.topology.plan_transfer(src.name, dst.name, nbytes, now)
        if plan is None:
            return None
        ej = src.eject(tid, resident_runs=resident)
        warm = ej.resident_runs
        if self.stage_dir is not None:
            warm = checkpoint_roundtrip(
                self.stage_dir, self._seq, ej, src.page_size
            )
            self._seq += 1
        if ej.record is not None:
            ej.record.meta["migrated_to"] = dst.name
        cont = ResumedTask(ej.program, ej.completed)
        dst.inject(
            TaskArrival(
                plan.arrival_us, cont, meta={"migrated_from": src.name}
            ),
            warm_runs=warm,
        )
        return MigrationEvent(
            now, tid, src.name, dst.name, "checkpoint",
            run_page_count(ej.resident_runs), nbytes, plan.arrival_us,
            completed_iters=ej.completed,
        )

    def _pick_victim(self, src: SimCore) -> Optional[int]:
        """Most recently admitted running task (least sunk prefix — the
        work-stealing heuristic); deterministic tie-break on task id."""
        best = None
        for tid in src.tasks:
            rec = src.rec_by_tid.get(tid)
            admitted = rec.admitted_us if rec is not None else 0.0
            key = (admitted if admitted is not None else 0.0, tid)
            if best is None or key > best[0]:
                best = (key, tid)
        return None if best is None else best[1]
