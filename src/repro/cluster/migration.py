"""Inter-GPU task migration: checkpoint the working set, price the transfer
on the link graph, re-admit a continuation on the target GPU.

Migration is iteration-granular: the source core ejects the task between
scheduler steps (``SimCore.eject``), which snapshots the resident working
set; work of a partially-completed iteration is replayed on the target. The
working set travels either peer-to-peer (NVLink edge) or host-staged
(src → host DRAM → dst), with link contention and the host staging budget
enforced by :class:`~repro.cluster.topology.ClusterTopology`. On the target,
the continuation (:class:`ResumedTask`, same task id and address space,
iteration counter offset past the completed prefix) arrives as a normal
``TaskArrival`` at the transfer's landing time, with the checkpointed runs
populated into HBM at admission — the restore half of the move.

When a ``stage_dir`` is given, the working-set manifest actually round-trips
through ``repro.checkpointing.checkpoint`` (the sharded .npy + msgpack
format) — the host-staged path writes real files, and the restored manifest
is what re-admission uses, so checkpoint integrity is on the migration
path, not asserted on the side.

The cheap rebalance move is *stealing*: a queued-but-unadmitted candidate on
the pressured GPU has nothing resident, so rerouting it costs nothing but
the decision. :class:`Rebalancer` always prefers steals and only checkpoints
running tasks when the wait queue is empty.

Between NVLink-connected GPUs the bulk copy is skipped entirely (the *lazy*
``p2p`` move): only the working-set manifest ships over the peer edge, the
pages linger on the source — demoted to its eviction-list head, free to
scavenge — and the target's extended context switches prefetch them over
NVLink on demand of the planner (see :mod:`repro.cluster.prefetch`). The
host-staged checkpoint path remains for PCIe-only pairs.

Migration retry protocol: a migrated continuation queues behind the *target*
GPU's admission controller like any arrival, so a controller with a wait
deadline (``MSchedAdmission(max_wait_us=...)``) can reject a
partially-executed request. Instead of dropping the completed prefix, the
rebalancer's rejection handler (installed on every core via
:meth:`Rebalancer.attach`) returns the continuation to the GPU that still
holds its lingering working set, else to the original source, else to the
least-pressured GPU — up to ``max_retries`` bounces before the rejection is
allowed to stand. Fresh (never-executed) arrivals are still shed normally:
load shedding semantics only change for work the cluster already invested
in.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.hbm import resident_runs_in
from repro.core.pages import PageRun, run_page_count
from repro.core.simulator import (
    EjectedTask,
    SimCore,
    TaskArrival,
    active_demand_pages,
)
from repro.core.workloads import TaskProgram
from repro.cluster.topology import ClusterTopology


@dataclasses.dataclass
class MigrationEvent:
    """One completed rebalance move, for reporting.

    ``kind`` is ``"steal"`` (queued candidate re-routed, nothing resident),
    ``"checkpoint"`` (running task's working set bulk-transferred through
    the link graph), ``"p2p"`` (lazy NVLink move: only the manifest ships,
    ``nbytes`` is manifest bytes and ``pages`` the working set left
    lingering on the source as a prefetch source), ``"retry"`` (a
    deadline-rejected continuation returned to a GPU with headroom), or
    ``"exhausted"`` (the retry budget ran out: the continuation's rejection
    stands, its linger copy and staging reservation are released, and the
    request is accounted as failed)."""

    time_us: float
    task_id: int
    src: str
    dst: str
    kind: str  # "steal" | "checkpoint" | "p2p" | "retry" | "exhausted"
    pages: int
    nbytes: int
    arrival_us: float  # when the task lands on dst
    completed_iters: int = 0


# lazy p2p migration ships only the working-set manifest (run intervals +
# header), not the pages; sized after the checkpoint manifest encoding
MANIFEST_BASE_BYTES = 96
MANIFEST_RUN_BYTES = 16


class ResumedTask(TaskProgram):
    """Continuation of a migrated task: same task id and address space, with
    the iteration counter offset past the prefix completed on the source
    GPU. The inner program is *not* released on the source — its space (and
    the page-key identity the pools share) travels with it."""

    def __init__(self, inner: TaskProgram, completed: int):
        # no super().__init__: the continuation adopts the inner program's
        # address space instead of allocating a fresh one
        self.inner = inner
        self.task_id = inner.task_id
        self.space = inner.space
        self.name = f"{getattr(inner, 'name', 'task')}+mig{completed}"
        self.offset = completed
        klass = getattr(inner, "slo_class", None)
        if klass is not None:
            # graceful degradation classifies continuations like originals
            self.slo_class = klass
        total = getattr(inner, "total_iterations", None)
        self.total_iterations = (
            None if total is None else max(0, total - completed)
        )

    def iteration(self, it: int):
        return self.inner.iteration(it + self.offset)

    def footprint_bytes(self) -> int:
        return self.inner.footprint_bytes()

    def release(self):
        return self.inner.release()


# --------------------------------------------------------------------------
# Working-set checkpointing (through repro.checkpointing)
# --------------------------------------------------------------------------


def pack_working_set(ej: EjectedTask, page_size: int) -> Dict[str, np.ndarray]:
    """The migration manifest as a flat pytree of host arrays — what the
    host-staged path serializes."""
    starts = np.asarray([s for s, _ in ej.resident_runs], dtype=np.int64)
    stops = np.asarray([e for _, e in ej.resident_runs], dtype=np.int64)
    return {
        "task_id": np.int64(ej.program.task_id),
        "completed": np.int64(ej.completed),
        "page_size": np.int64(page_size),
        "resident_starts": starts,
        "resident_stops": stops,
    }


def unpack_working_set(tree: Dict[str, np.ndarray]) -> List[PageRun]:
    return [
        (int(s), int(e))
        for s, e in zip(tree["resident_starts"], tree["resident_stops"])
    ]


def checkpoint_roundtrip(
    stage_dir: str, seq: int, ej: EjectedTask, page_size: int
) -> List[PageRun]:
    """Stage the working-set manifest through the sharded checkpoint format
    and return the *restored* resident runs (what re-admission warms HBM
    with). Imported lazily: the simulation path stays jax-free unless a
    stage dir is configured."""
    from repro.checkpointing import checkpoint

    tree = pack_working_set(ej, page_size)
    checkpoint.save(stage_dir, seq, tree, keep=4)
    n = len(ej.resident_runs)
    target = {
        "task_id": np.zeros((), np.int64),
        "completed": np.zeros((), np.int64),
        "page_size": np.zeros((), np.int64),
        "resident_starts": np.zeros((n,), np.int64),
        "resident_stops": np.zeros((n,), np.int64),
    }
    restored = checkpoint.restore(stage_dir, seq, target)
    if int(restored["task_id"]) != ej.program.task_id:
        raise RuntimeError(
            f"checkpoint round-trip mismatch: staged task "
            f"{int(restored['task_id'])}, expected {ej.program.task_id}"
        )
    return unpack_working_set(restored)


# --------------------------------------------------------------------------
# Rebalancer
# --------------------------------------------------------------------------


class Rebalancer:
    """Periodic load rebalancing across cores.

    Pressure is memory demand relative to capacity — the same per-cycle
    demand admission and placement price (predicted per-quantum working sets
    plus the queued backlog). Each tick moves at most ``max_moves`` tasks
    from the most- to the least-pressured GPU while the gap exceeds
    ``threshold``; steals (queued candidates) are free, checkpointed moves
    of running tasks pay the link-graph transfer time and host staging, and
    NVLink pairs with a :class:`~repro.cluster.prefetch.PeerPrefetchFabric`
    (``prefetch``) migrate *lazily* — manifest only, working set lingers on
    the source for peer prefetch.

    :meth:`attach` additionally installs the migration **retry protocol** on
    every core (see module docstring).
    """

    def __init__(
        self,
        topology: ClusterTopology,
        threshold: float = 0.5,
        max_moves: int = 1,
        quantum_us: Optional[float] = None,
        stage_dir: Optional[str] = None,
        prefetch=None,
        max_retries: int = 3,
        retry_backoff_us: float = 0.0,
        retry_backoff_cap_us: float = 400_000.0,
    ):
        assert threshold > 0
        self.topology = topology
        self.threshold = threshold
        self.max_moves = max_moves
        self.quantum_us = quantum_us
        self.stage_dir = stage_dir
        self.prefetch = prefetch  # PeerPrefetchFabric | None
        self.max_retries = max_retries
        # retry bounce N lands at now + min(backoff * 2**N, cap); the 0.0
        # default keeps retries instant (the PR 5 protocol)
        self.retry_backoff_us = retry_backoff_us
        self.retry_backoff_cap_us = retry_backoff_cap_us
        self.exhausted = 0
        self.events: List[MigrationEvent] = []
        # telemetry hub or None; assigned by simulate_cluster when tracing
        self.telemetry = None
        # ControlPlane or None; assigned by ControlPlane.attach. Move and
        # reroute decisions are journaled write-ahead (before the eject or
        # inject they commit) so a coordinator crash can replay them.
        self.control = None
        self._seq = 0
        self._cores: Sequence[SimCore] = ()
        # host-staged checkpoint transfers still parked in host DRAM, by
        # task id — released if the continuation's retry chain exhausts
        self._staged_plans: Dict[int, object] = {}

    def attach(self, cores: Sequence[SimCore]) -> None:
        """Register the fleet and install the per-core rejection handler
        that turns admission-deadline rejections of migrated continuations
        into retries instead of drops."""
        self._cores = list(cores)
        for core in self._cores:
            core.reject_hook = (
                lambda c: lambda ev, rec, warm: self._handle_reject(
                    c, ev, rec, warm
                )
            )(core)

    def _journal(self, kind: str, now: float, task_id: int, **payload) -> None:
        if self.control is not None:
            self.control.record(kind, now, task_id, **payload)

    # -- migration retry protocol -------------------------------------------
    def _handle_reject(self, core, ev, rec, warm) -> bool:
        """Re-route a rejected *continuation* (never a fresh arrival — load
        shedding semantics are unchanged for work the cluster has not yet
        executed) to the GPU holding its lingering working set, else its
        original source, else the least-pressured GPU. Returns True when the
        rejection was absorbed."""
        meta = ev.meta
        # only continuations carry "migrated_from" (steals preserve it); a
        # stolen-but-never-executed fresh arrival must still shed normally
        if "migrated_from" not in meta:
            return False
        tid = ev.program.task_id
        retries = int(meta.get("mig_retries", 0))
        candidates = [c for c in self._cores if c is not core and not c.failed]
        if retries >= self.max_retries or not candidates:
            if self.prefetch is not None:
                self.prefetch.release(tid)  # drop the stranded linger copy
            plan = self._staged_plans.pop(tid, None)
            if plan is not None:
                # the checkpointed working set parked in host DRAM will
                # never be consumed — release the staging reservation and
                # mark the plan canceled so the in-flight probes stop
                # counting it (a same-tick replan must count once)
                self.topology.cancel_staging(plan, core.t)
            self.exhausted += 1
            rec.meta["retry_exhausted"] = True
            self.events.append(
                MigrationEvent(
                    core.t, tid, core.name, core.name, "exhausted", 0, 0,
                    core.t,
                )
            )
            return False
        entry = (
            self.prefetch.directory.get(tid)
            if self.prefetch is not None
            else None
        )
        target = None
        if entry is not None:
            target = next(
                (c for c in candidates if c.name == entry.src), None
            )
        if target is None:
            src_name = meta.get("migrated_from")
            target = next(
                (c for c in candidates if c.name == src_name), None
            )
        if target is None:
            target = min(candidates, key=self.pressure)
        now = core.t
        arrival = now
        if self.retry_backoff_us > 0.0:
            arrival = now + min(
                self.retry_backoff_us * (2.0 ** retries),
                self.retry_backoff_cap_us,
            )
        warm = self._retarget_linger(tid, target.name, warm)
        self._journal(
            "reroute", now, tid, src=core.name, dst=target.name, via="retry"
        )
        target.inject(
            TaskArrival(
                arrival,
                ev.program,
                meta=dict(
                    meta, mig_retries=retries + 1, retried_from=core.name
                ),
            ),
            warm_runs=warm,
        )
        rec.meta["retried_to"] = target.name
        mv = MigrationEvent(
            now, tid, core.name, target.name, "retry", 0, 0, arrival
        )
        self.events.append(mv)
        self._emit_move(mv)
        return True

    def _retarget_linger(self, tid: int, dst_name: str, warm):
        """Point a re-routed continuation's lingering peer copy at its new
        target. The entry only stays in the directory when the new target
        can actually peer-fetch it (a *different* GPU with a direct NVLink
        edge to the source). Otherwise the copy is harvested into the warm
        runs that travel with the task — back to the holder itself (the
        task re-owns its pages at admission; a kept entry would keep
        feeding them to cluster_view as foreign runs), or beyond NVLink
        reach (host-staged with the re-route, the same convention as stolen
        checkpoint warm runs — the simulation must not later re-materialize
        data from a host DRAM that never held it). Returns the (possibly
        augmented) warm runs."""
        if self.prefetch is None:
            return warm
        entry = self.prefetch.directory.get(tid)
        if entry is None:
            return warm
        if (
            entry.src != dst_name
            and self.topology.nvlink_peer(entry.src, dst_name) is not None
        ):
            self.prefetch.directory.retarget(tid, dst_name)
            return warm
        harvested = self.prefetch.harvest(tid)
        if harvested:
            warm = list(warm or []) + harvested
        return warm

    def _emit_move(self, mv: MigrationEvent) -> None:
        """Trace one rebalance move and attribute its transit time. The
        transit splits against the uncontended floor: the solo portion is
        migration-wait, the excess (fluid sharing with concurrent transfers)
        is link-contention. Steals and retries move no bytes — instants
        only, their wait resolves into the queue-wait residual."""
        tel = self.telemetry
        if tel is None:
            return
        transit = max(0.0, mv.arrival_us - mv.time_us)
        if mv.kind in ("checkpoint", "p2p"):
            tel.span(
                "migration_plan",
                mv.src,
                mv.time_us,
                transit,
                task_id=mv.task_id,
                dst=mv.dst,
                kind=mv.kind,
                pages=mv.pages,
                nbytes=mv.nbytes,
            )
            solo = self.topology.solo_transfer_us(mv.src, mv.dst, mv.nbytes)
            tel.stall(mv.task_id, "mig_wait_transit", min(transit, solo))
            if transit > solo:
                tel.stall(mv.task_id, "link_contention", transit - solo)
        tel.instant(
            "migration_land",
            mv.dst,
            mv.arrival_us,
            task_id=mv.task_id,
            kind=mv.kind,
            src=mv.src,
        )

    def pressure(self, core: SimCore) -> float:
        st = core.state_view()
        quantum = self.quantum_us or getattr(st.policy, "quantum_us", 5_000.0)
        return (active_demand_pages(st, quantum) + st.waiting_pages) / max(
            1, st.pool.capacity
        )

    def tick(self, cores: Sequence[SimCore], now: float) -> List[MigrationEvent]:
        if self.topology.planner is not None:
            return self._tick_planned(cores, now)
        moves: List[MigrationEvent] = []
        alive = [c for c in cores if not c.failed]
        if len(alive) < 2:
            return moves
        for _ in range(self.max_moves):
            loads = [self.pressure(c) for c in alive]
            si = max(range(len(alive)), key=lambda i: loads[i])
            di = min(range(len(alive)), key=lambda i: loads[i])
            if si == di or loads[si] - loads[di] < self.threshold:
                break
            mv = self._move_one(alive[si], alive[di], now)
            if mv is None:
                break
            moves.append(mv)
            self._emit_move(mv)
        self.events.extend(moves)
        return moves

    def _tick_planned(
        self, cores: Sequence[SimCore], now: float
    ) -> List[MigrationEvent]:
        """Window collection for the attached
        :class:`~repro.cluster.transfer_plan.TransferPlanner`: select up to
        ``max_moves`` candidates first (steals commit immediately — they
        move no bytes), then submit the bulk movements as *one* planner
        window so they are urgency-ordered, routed, and priced against the
        shared fluid schedule together. A deferred candidate (budget or
        marginal-makespan) simply stays put and is reconsidered at a later
        tick — identical caller semantics to a greedy budget deferral."""
        from repro.cluster.transfer_plan import TransferRequest

        moves: List[MigrationEvent] = []
        alive = [c for c in cores if not c.failed]
        if len(alive) < 2:
            return moves
        candidates: List[tuple] = []
        picked: Dict[str, set] = {}
        # selected-but-uncommitted candidates shift pressure so one window
        # doesn't drain the same pressured pair max_moves times over
        shift: Dict[str, float] = {}
        for _ in range(self.max_moves):
            loads = [
                self.pressure(c) + shift.get(c.name, 0.0) for c in alive
            ]
            si = max(range(len(alive)), key=lambda i: loads[i])
            di = min(range(len(alive)), key=lambda i: loads[i])
            if si == di or loads[si] - loads[di] < self.threshold:
                break
            src, dst = alive[si], alive[di]
            mv = self._try_steal(src, dst, now)
            if mv is not None:
                moves.append(mv)
                self._emit_move(mv)
                continue
            tid = self._pick_victim(src, exclude=picked.get(src.name))
            if tid is None:
                break
            span = src.tasks[tid].prog.space.page_span()
            resident = resident_runs_in(src.pool, span)
            pages = run_page_count(resident)
            nbytes = pages * src.page_size
            lazy = (
                self.prefetch is not None
                and self.topology.nvlink_peer(src.name, dst.name) is not None
            )
            manifest = MANIFEST_BASE_BYTES + MANIFEST_RUN_BYTES * len(resident)
            candidates.append(
                (src, dst, tid, resident, nbytes, lazy, manifest)
            )
            picked.setdefault(src.name, set()).add(tid)
            shift[src.name] = shift.get(src.name, 0.0) - pages / max(
                1, src.pool.capacity
            )
            shift[dst.name] = shift.get(dst.name, 0.0) + pages / max(
                1, dst.pool.capacity
            )
        if candidates:
            reqs = [
                TransferRequest(
                    src.name,
                    dst.name,
                    manifest if lazy else nbytes,
                    "p2p" if lazy else "checkpoint",
                    None,
                    tid,
                )
                for (src, dst, tid, _r, nbytes, lazy, manifest) in candidates
            ]
            plans = self.topology.planner.submit(reqs, now)
            for cand, plan in zip(candidates, plans):
                if plan is None:
                    continue  # deferred — reconsidered at a later tick
                src, dst, tid, resident, nbytes, lazy, manifest = cand
                if lazy:
                    mv = self._commit_lazy(
                        src, dst, tid, resident, manifest, plan, now
                    )
                else:
                    mv = self._commit_checkpoint(
                        src, dst, tid, resident, nbytes, plan, now
                    )
                moves.append(mv)
                self._emit_move(mv)
        self.events.extend(moves)
        return moves

    def _try_steal(
        self, src: SimCore, dst: SimCore, now: float
    ) -> Optional[MigrationEvent]:
        stolen = src.steal_waiting()
        if stolen is None:
            return None
        ev, rec, warm = stolen
        # a stolen candidate may itself be a migrated continuation whose
        # checkpointed working set was still waiting for admission: the
        # warm runs travel with it (staged in host DRAM either way), and
        # a lingering peer copy either follows the retarget (NVLink
        # reachable) or is harvested into the warm runs
        warm = self._retarget_linger(ev.program.task_id, dst.name, warm)
        self._journal(
            "reroute",
            now,
            ev.program.task_id,
            src=src.name,
            dst=dst.name,
            via="steal",
        )
        dst.inject(
            TaskArrival(
                max(now, ev.time_us),
                ev.program,
                meta=dict(ev.meta, rerouted_from=src.name),
            ),
            warm_runs=warm,
        )
        return MigrationEvent(
            now, ev.program.task_id, src.name, dst.name, "steal",
            0, 0, max(now, ev.time_us),
        )

    def _move_one(
        self, src: SimCore, dst: SimCore, now: float
    ) -> Optional[MigrationEvent]:
        mv = self._try_steal(src, dst, now)
        if mv is not None:
            return mv
        tid = self._pick_victim(src)
        if tid is None:
            return None
        # price the transfer before ejecting: a host-DRAM-budget denial must
        # leave the source untouched (retry next tick)
        span = src.tasks[tid].prog.space.page_span()
        resident = resident_runs_in(src.pool, span)
        nbytes = run_page_count(resident) * src.page_size
        if (
            self.prefetch is not None
            and self.topology.nvlink_peer(src.name, dst.name) is not None
        ):
            return self._move_lazy(src, dst, tid, resident, now)
        plan = self.topology.plan_transfer(
            src.name, dst.name, nbytes, now, kind="checkpoint", task_id=tid
        )
        if plan is None:
            return None
        return self._commit_checkpoint(
            src, dst, tid, resident, nbytes, plan, now
        )

    def _commit_checkpoint(
        self,
        src: SimCore,
        dst: SimCore,
        tid: int,
        resident,
        nbytes: int,
        plan,
        now: float,
    ) -> MigrationEvent:
        self._journal(
            "migrate",
            now,
            tid,
            src=src.name,
            dst=dst.name,
            linger=False,
            nbytes=nbytes,
            arrival_us=plan.arrival_us,
        )
        if self.prefetch is not None:
            # a stale linger copy from an earlier visit elsewhere is dead
            # the moment the task's live working set moves through host
            self.prefetch.release(tid)
        ej = src.eject(tid, resident_runs=resident)
        warm = ej.resident_runs
        if self.stage_dir is not None:
            warm = checkpoint_roundtrip(
                self.stage_dir, self._seq, ej, src.page_size
            )
            self._seq += 1
        if ej.record is not None:
            ej.record.meta["migrated_to"] = dst.name
        cont = ResumedTask(ej.program, ej.completed)
        self._staged_plans[tid] = plan
        dst.inject(
            TaskArrival(
                plan.arrival_us, cont, meta={"migrated_from": src.name}
            ),
            warm_runs=warm,
        )
        return MigrationEvent(
            now, tid, src.name, dst.name, "checkpoint",
            run_page_count(ej.resident_runs), nbytes, plan.arrival_us,
            completed_iters=ej.completed,
        )

    def _move_lazy(
        self, src: SimCore, dst: SimCore, tid: int, resident, now: float
    ) -> Optional[MigrationEvent]:
        """Lazy NVLink migration: ship only the working-set manifest over
        the peer edge; the pages linger on the source (eviction-list head —
        free to scavenge) and the target's extended context switches
        prefetch them peer-to-peer as the planner demands them."""
        manifest = MANIFEST_BASE_BYTES + MANIFEST_RUN_BYTES * len(resident)
        plan = self.topology.plan_transfer(
            src.name, dst.name, manifest, now, kind="p2p", task_id=tid
        )
        if plan is None:
            return None
        return self._commit_lazy(src, dst, tid, resident, manifest, plan, now)

    def _commit_lazy(
        self,
        src: SimCore,
        dst: SimCore,
        tid: int,
        resident,
        manifest: int,
        plan,
        now: float,
    ) -> MigrationEvent:
        # journaled with src/dst/arrival: a journal replay rebuilds the
        # wiped directory entry for the still-lingering copy from this
        # record (validated against live pool residency)
        self._journal(
            "migrate",
            now,
            tid,
            src=src.name,
            dst=dst.name,
            linger=True,
            nbytes=manifest,
            arrival_us=plan.arrival_us,
        )
        self.prefetch.release(tid)  # stale copy from an earlier visit
        ej = src.eject(tid, resident_runs=resident, linger=True)
        if ej.record is not None:
            ej.record.meta["migrated_to"] = dst.name
        self.prefetch.directory.record(
            tid, src.name, dst.name, resident, plan.arrival_us
        )
        cont = ResumedTask(ej.program, ej.completed)
        dst.inject(
            TaskArrival(
                plan.arrival_us,
                cont,
                meta={"migrated_from": src.name, "transport": "nvlink-lazy"},
            )
        )
        return MigrationEvent(
            now, tid, src.name, dst.name, "p2p",
            run_page_count(resident), manifest, plan.arrival_us,
            completed_iters=ej.completed,
        )

    def _pick_victim(
        self, src: SimCore, exclude: Optional[set] = None
    ) -> Optional[int]:
        """Most recently admitted running task (least sunk prefix — the
        work-stealing heuristic); deterministic tie-break on task id.
        ``exclude`` skips tasks already selected in the current planner
        window (they are not ejected until their plan is admitted)."""
        best = None
        for tid in src.tasks:
            if exclude and tid in exclude:
                continue
            rec = src.rec_by_tid.get(tid)
            admitted = rec.admitted_us if rec is not None else 0.0
            key = (admitted if admitted is not None else 0.0, tid)
            if best is None or key > best[0]:
                best = (key, tid)
        return None if best is None else best[1]
