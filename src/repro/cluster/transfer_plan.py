"""Cluster-wide transfer planner: scheduled link-graph migrations.

The greedy model in :mod:`repro.cluster.topology` prices every bulk movement
the moment a caller asks, at a fluid share fixed for the transfer's lifetime
(fluid-at-start). Under a migration storm that goes wrong in three ways: the
share is stale the moment a sharer drains (landing estimates overshoot),
every transfer piles onto the shared host links even when an idle NVLink
path exists, and a speculative rebalance is priced exactly like an RT-class
restore. :class:`TransferPlanner` replaces the greedy commit with a
*scheduled* one — the cluster analogue of the paper's single-GPU thesis that
fragmented, eventual page movements should be coalesced into planned
migrations:

* **Segmented fluid schedule.** All admitted flights advance through one
  discrete-event solve where each link's bandwidth is split equally among
  the flights *currently* on it. Shares are re-evaluated at every leg
  completion, so the schedule is piecewise-constant per link and landing
  times are exact for the model (pinned against an independent event-loop
  simulation in tests/cluster/test_transfer_plan.py).
* **Routing.** A host-staged pair whose host legs are saturated is routed
  over an idle two-hop NVLink detour when one exists (both edges healthy and
  carrying no flights); the detour skips host DRAM staging entirely.
* **Urgency-ordered admission with deferral.** Requests in a window are
  admitted RT restores first, then restores/deadline-retries, then peer
  fetches, then speculative rebalances and snapshots. A *speculative* move
  whose projected landing exceeds ``defer_stretch ×`` its uncontended floor
  — the storm's marginal makespan contribution dwarfs the move's urgency —
  is deferred (``None``; callers already retry at the next tick).
* **Rebooking.** Admitting a flight slows the flights it now shares links
  with; canceling one speeds the survivors up. The planner re-solves and
  rewrites the committed plans in place through
  :meth:`~repro.cluster.topology.ClusterTopology.rebook`, which fires the
  topology's ``replan_hook`` so the engine retimes the dependent arrival
  events. Probes (``active_on``/``inflight_bytes``/``host_staged_bytes``)
  keep reading the same ledgers they always did.
* **Peer-fetch pressure feedback.** :meth:`linger_retention_ok` weighs a
  lingering run's NVLink refetch saving against the local misses its
  retention causes, and always yields to the eviction scavenger under zero
  headroom — retention is advisory, so eviction progress never waits on a
  transfer (no-deadlock property test in the conservation suite).

The planner is constructed only by ``simulate_cluster(transfer_plan="auto")``
on multi-GPU fleets; with ``transfer_plan="greedy"`` (the default) it is
never built and every path is bit-for-bit the pre-planner model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.core.simulator import active_demand_pages
from repro.cluster.topology import (
    HOST,
    ClusterTopology,
    Link,
    LingerEntry,
    TransferPlan,
)
from repro.telemetry.hub import TRACK_CLUSTER

# urgency classes, lowest admits first. Deadline-rejected retries and
# RT-class restores outrank speculative rebalances; only SPECULATIVE moves
# are ever deferred by the marginal-makespan test.
URGENCY_RT = 0  # RT-class fault restores / re-dispatches
URGENCY_RESTORE = 1  # best-effort restores, deadline-rejected retries
URGENCY_FETCH = 2  # peer working-set fetches feeding a live switch
URGENCY_SPECULATIVE = 3  # rebalance checkpoints/manifests, vault snapshots

_KIND_URGENCY = {
    "restore": URGENCY_RESTORE,
    "peer_fetch": URGENCY_FETCH,
}


@dataclasses.dataclass
class TransferRequest:
    """One pending bulk movement submitted to the planner. ``urgency`` of
    ``None`` resolves from ``kind`` (restores urgent, everything else
    speculative); ``task_id`` lets the engine retime the dependent arrival
    when a later admission rebooks this flight's landing."""

    src: str
    dst: str
    nbytes: int
    kind: str = "bulk"
    urgency: Optional[int] = None
    task_id: Optional[int] = None

    def effective_urgency(self) -> int:
        if self.urgency is not None:
            return self.urgency
        return _KIND_URGENCY.get(self.kind, URGENCY_SPECULATIVE)


class _Flight:
    """One admitted transfer's progress through the fluid schedule."""

    __slots__ = (
        "fid", "req", "links", "caps", "leg_names", "staged", "detour",
        "start_us", "leg", "rem", "leg_ends", "landed_us", "plan", "solo_us",
    )

    def __init__(
        self,
        fid: int,
        req: TransferRequest,
        links: List[Link],
        caps: List[float],
        staged: bool,
        detour: bool,
        start_us: float,
    ):
        self.fid = fid
        self.req = req
        self.links = links
        # per-leg capacity (bytes/us) frozen at submit: like greedy plans,
        # in-flight transfers keep their rates through later link degrades —
        # only new admissions see the changed factor
        self.caps = caps
        self.leg_names = [f"{l.a}<->{l.b}" for l in links]
        self.staged = staged
        self.detour = detour
        self.start_us = start_us
        self.leg = 0  # index of the leg currently flowing
        self.rem = float(req.nbytes)  # bytes left on the current leg
        self.leg_ends: List[float] = []  # absolute end of each finished leg
        self.landed_us: Optional[float] = None
        self.plan: Optional[TransferPlan] = None
        self.solo_us = sum(
            req.nbytes / c for c in caps if c > 0.0
        ) if req.nbytes > 0 else 0.0


class _St:
    """Mutable DES state for one flight (copied for projections)."""

    __slots__ = ("f", "leg", "rem", "ends")

    def __init__(self, f: _Flight):
        self.f = f
        self.leg = f.leg
        self.rem = f.rem
        self.ends: List[float] = []


Segment = Tuple[float, float, Tuple[Tuple[int, float], ...]]


class TransferPlanner:
    """Scheduled transfer admission over a :class:`ClusterTopology`.

    ``defer_stretch`` bounds how much contention a *speculative* move may
    absorb before it is deferred to a later window; ``saturation_depth`` is
    the host-leg queue depth at which a host-staged pair starts looking for
    an idle NVLink detour."""

    def __init__(
        self,
        topology: ClusterTopology,
        telemetry=None,
        defer_stretch: float = 3.0,
        saturation_depth: int = 2,
    ):
        self.topology = topology
        self.telemetry = telemetry
        self.defer_stretch = defer_stretch
        self.saturation_depth = saturation_depth
        self.reset()

    def reset(self) -> None:
        self._t = 0.0  # committed schedule time (never moves backwards)
        self._fid = 0
        self._flights: List[_Flight] = []  # in flight, fluid schedule order
        self.log: List[_Flight] = []  # every admitted flight, for probes
        # link key -> finalized bandwidth segments (t0, t1, ((fid, rate),..))
        # — the committed piecewise-constant schedule, what the conservation
        # suite integrates
        self.history: Dict[FrozenSet[str], List[Segment]] = {}
        self.windows = 0
        self.urgency_deferred = 0
        self.detours = 0
        self.landed = 0
        self._scavenged: Set[int] = set()

    # -- fluid DES kernel ----------------------------------------------------
    def _run_fluid(
        self,
        states: List[_St],
        t: float,
        until: Optional[float] = None,
        record=None,
    ) -> float:
        """Advance ``states`` through the equal-share fluid model from ``t``
        to ``until`` (or to completion). Each iteration holds shares
        constant until the next leg completion — the piecewise-constant
        segment — then re-evaluates. ``record(key, t0, t1, flows)`` gets
        every non-empty segment per link. Returns the stop time."""
        while True:
            live = [s for s in states if s.leg < len(s.f.links)]
            if not live:
                return t
            occ: Dict[FrozenSet[str], int] = {}
            for s in live:
                k = s.f.links[s.leg].key()
                occ[k] = occ.get(k, 0) + 1
            rates: List[float] = []
            dt = math.inf
            for s in live:
                r = s.f.caps[s.leg] / occ[s.f.links[s.leg].key()]
                rates.append(r)
                if r > 0.0:
                    dt = min(dt, s.rem / r)
            end = t + dt
            partial = until is not None and end > until
            if partial:
                end = until
            if record is not None and end > t:
                flows: Dict[FrozenSet[str], List[Tuple[int, float]]] = {}
                for s, r in zip(live, rates):
                    flows.setdefault(
                        s.f.links[s.leg].key(), []
                    ).append((s.f.fid, r))
                for k, fl in flows.items():
                    record(k, t, end, tuple(fl))
            span = end - t
            if span > 0.0:
                for s, r in zip(live, rates):
                    s.rem -= r * span
            t = end
            if partial:
                return t
            for s, r in zip(live, rates):
                eps = 1e-6 + 1e-9 * s.f.req.nbytes
                # fp guard: a residue whose drain time is below the spacing
                # of ``t`` cannot advance the clock (t + dt == t) — without
                # forcing it to land here the loop would spin forever on a
                # tiny manifest at a large timestamp
                stuck = r > 0.0 and s.rem / r <= 4.0 * math.ulp(max(t, 1.0))
                if r > 0.0 and (s.rem <= eps or stuck):
                    s.ends.append(t)
                    s.leg += 1
                    s.rem = (
                        float(s.f.req.nbytes)
                        if s.leg < len(s.f.links)
                        else 0.0
                    )

    def _record_history(
        self, key: FrozenSet[str], t0: float, t1: float, flows
    ) -> None:
        self.history.setdefault(key, []).append((t0, t1, flows))

    def _advance(self, now: float) -> None:
        """Commit the fluid schedule up to ``now``: finalize segments into
        ``history``, land finished flights, drop them from the active set."""
        if now <= self._t:
            return
        states = [_St(f) for f in self._flights]
        self._run_fluid(states, self._t, until=now, record=self._record_history)
        for st in states:
            f = st.f
            f.leg, f.rem = st.leg, st.rem
            f.leg_ends.extend(st.ends)
            if f.leg >= len(f.links):
                f.landed_us = f.leg_ends[-1]
                self.landed += 1
        self._flights = [f for f in self._flights if f.landed_us is None]
        self._t = now

    def _project(
        self, extra: Optional[_Flight] = None
    ) -> Tuple[Dict[int, List[float]], float]:
        """Landing projection: run the active flights (plus ``extra``) to
        completion on copied state. Returns the full absolute leg-end list
        per flight id and the projected makespan."""
        flights = self._flights + ([extra] if extra is not None else [])
        states = [_St(f) for f in flights]
        t = self._run_fluid(states, self._t)
        out = {st.f.fid: st.f.leg_ends + st.ends for st in states}
        return out, t

    # -- routing -------------------------------------------------------------
    def _queue_depth(self, key: FrozenSet[str]) -> int:
        """Flights with any remaining leg on the link — the per-link queue
        the saturation check and the telemetry probe read."""
        n = 0
        for f in self._flights:
            for i in range(f.leg, len(f.links)):
                if f.links[i].key() == key:
                    n += 1
                    break
        return n

    def link_queue_depths(
        self, now: Optional[float] = None
    ) -> Dict[FrozenSet[str], int]:
        if now is not None:
            self._advance(now)
        out: Dict[FrozenSet[str], int] = {}
        for f in self._flights:
            for key in {f.links[i].key() for i in range(f.leg, len(f.links))}:
                out[key] = out.get(key, 0) + 1
        return out

    def _find_detour(self, src: str, dst: str) -> Optional[List[Link]]:
        """An idle two-hop NVLink path src→x→dst: both edges healthy peers
        carrying no flights. Deterministic: lowest GPU name wins."""
        topo = self.topology
        for name in sorted(g.name for g in topo.gpus):
            if name in (src, dst):
                continue
            l1 = topo.nvlink_peer(src, name)
            l2 = topo.nvlink_peer(name, dst)
            if l1 is None or l2 is None:
                continue
            if self._queue_depth(l1.key()) or self._queue_depth(l2.key()):
                continue
            return [l1, l2]
        return None

    def _route(self, src: str, dst: str) -> Tuple[List[Link], bool, bool]:
        """Pick the leg sequence for a movement: ``(links, staged, detour)``.
        Host-staged pairs check the host-leg queue depth first and take an
        idle NVLink detour (no DRAM staging) when the host path is
        saturated."""
        topo = self.topology
        if dst == HOST:
            return [topo.link(src, HOST)], False, False
        if src == HOST:
            return [topo.link(dst, HOST)], True, False
        direct = topo.nvlink_peer(src, dst)
        if direct is not None:
            return [direct], False, False
        h1 = topo.link(src, HOST)
        h2 = topo.link(dst, HOST)
        depth = max(self._queue_depth(h1.key()), self._queue_depth(h2.key()))
        if depth >= self.saturation_depth:
            det = self._find_detour(src, dst)
            if det is not None:
                return det, False, True
        return [h1, h2], True, False

    # -- admission -----------------------------------------------------------
    def _admit(
        self, req: TransferRequest, now: float, pending_staged: int
    ) -> Optional[_Flight]:
        links, staged, detour = self._route(req.src, req.dst)
        if staged:
            in_use = self.topology.host_staged_bytes(now)
            if (
                in_use + pending_staged + req.nbytes
                > self.topology.host_dram_bytes
            ):
                self.topology.deferred += 1
                return None
        caps = [
            l.gbps * self.topology.link_factor(l.key()) * 1e3 for l in links
        ]
        flight = _Flight(self._fid, req, links, caps, staged, detour, now)
        if (
            req.effective_urgency() >= URGENCY_SPECULATIVE
            and self._flights
            and flight.solo_us > 0.0
        ):
            proj, _ = self._project(extra=flight)
            landing = proj[flight.fid][-1]
            if landing - now > self.defer_stretch * flight.solo_us:
                self.urgency_deferred += 1
                self.topology.deferred += 1
                return None
        self._fid += 1
        if detour:
            self.detours += 1
        self.log.append(flight)
        return flight

    def submit(
        self, requests: Sequence[TransferRequest], now: float
    ) -> List[Optional[TransferPlan]]:
        """Admit one window of pending movements. Requests are considered
        in urgency order (stable within a class), priced against the shared
        fluid schedule, and committed as :class:`TransferPlan`\\ s through
        the topology's ledgers. Results align with ``requests``; ``None``
        means deferred (budget or urgency) — the caller retries later,
        exactly as with a greedy budget deferral."""
        self._advance(now)
        self.windows += 1
        results: List[Optional[TransferPlan]] = [None] * len(requests)
        order = sorted(
            range(len(requests)),
            key=lambda i: (requests[i].effective_urgency(), i),
        )
        admitted: List[Tuple[int, _Flight]] = []
        pending_staged = 0
        for i in order:
            flight = self._admit(requests[i], now, pending_staged)
            if flight is None:
                continue
            if flight.staged:
                pending_staged += requests[i].nbytes
            self._flights.append(flight)
            admitted.append((i, flight))
        proj, makespan = self._project()
        new_fids = {f.fid for _, f in admitted}
        for i, f in admitted:
            ends = proj[f.fid]
            legs = list(zip(f.leg_names, ends))
            plan = TransferPlan(
                f.req.src, f.req.dst, f.req.nbytes, now,
                ends[-1] if ends else now, f.staged, legs,
                kind=f.req.kind, task_id=f.req.task_id,
            )
            f.plan = plan
            self.topology.book(plan)
            results[i] = plan
        self._rebook_changed(proj, skip=new_fids)
        if self.telemetry is not None:
            self.telemetry.span(
                "transfer_plan", TRACK_CLUSTER, now,
                max(0.0, makespan - now),
                requests=len(requests), admitted=len(admitted),
                deferred=self.urgency_deferred,
                replans=self.topology.replans, detours=self.detours,
                in_flight=len(self._flights),
            )
        return results

    def submit_one(
        self, req: TransferRequest, now: float
    ) -> Optional[TransferPlan]:
        return self.submit([req], now)[0]

    def _rebook_changed(
        self, proj: Dict[int, List[float]], skip: Set[int] = frozenset()
    ) -> None:
        for f in self._flights:
            if f.fid in skip or f.plan is None:
                continue
            ends = proj[f.fid]
            legs = list(zip(f.leg_names, ends))
            if any(
                abs(e - old) > 1e-6
                for (_, e), (_, old) in zip(legs, f.plan.legs)
            ):
                self.topology.rebook(f.plan, legs)

    def on_cancel(self, plan: TransferPlan, at_us: float) -> None:
        """A committed flight's payload will never be consumed
        (``cancel_staging``): drop it from the schedule, release its future
        leg bookings, and rebook the survivors at their recovered shares."""
        self._advance(at_us)
        victim = next(
            (f for f in self._flights if f.plan is plan), None
        )
        if victim is None:
            return
        self._flights.remove(victim)
        for leg_name, leg_end in plan.legs:
            if leg_end <= at_us:
                continue
            lst = self.topology._active.get(frozenset(leg_name.split("<->")))
            if lst is not None:
                try:
                    lst.remove(leg_end)
                except ValueError:
                    pass
        proj, _ = self._project()
        self._rebook_changed(proj)

    # -- peer-fetch pressure feedback ----------------------------------------
    def linger_retention_ok(
        self, entry: LingerEntry, src_core, now: float
    ) -> bool:
        """Should the eviction scavenger keep protecting this lingering
        working set? ``False`` the moment the holder has zero free headroom
        (eviction must always make progress — protection is advisory, so no
        transfer ever waits on a page whose eviction waits on the transfer),
        and whenever the NVLink refetch saving the copy buys its target no
        longer covers the local misses its retention causes."""
        pool = src_core.pool
        if pool.capacity - pool.used <= 0:
            self._scavenged.add(entry.task_id)
            return False
        linger_pages = entry.pages()
        if linger_pages <= 0:
            return False
        nv = self.topology.nvlink_peer(entry.src, entry.dst)
        if nv is None:
            # target can no longer peer-fetch: retention saves nothing
            self._scavenged.add(entry.task_id)
            return False
        st = src_core.state_view()
        quantum = getattr(st.policy, "quantum_us", 5_000.0)
        demand = active_demand_pages(st, quantum) + st.waiting_pages
        overflow = demand - (pool.capacity - linger_pages)
        if overflow <= 0:
            return True  # retention costs the holder nothing
        page = src_core.page_size
        topo = self.topology
        dst_host = topo.link(entry.dst, HOST)
        src_host = topo.link(entry.src, HOST)
        host_fetch = dst_host.gbps * topo.link_factor(dst_host.key()) * 1e3
        host_miss = src_host.gbps * topo.link_factor(src_host.key()) * 1e3
        nv_rate = nv.gbps * topo.link_factor(nv.key()) * 1e3
        if host_fetch <= 0.0 or host_miss <= 0.0:
            return True
        saving_us = linger_pages * page * max(
            0.0, 1.0 / host_fetch - 1.0 / nv_rate
        )
        miss_us = min(linger_pages, overflow) * page / host_miss
        if saving_us < miss_us:
            self._scavenged.add(entry.task_id)
            return False
        return True

    @property
    def pressure_scavenged(self) -> int:
        """Distinct linger copies the pressure feedback released to the
        eviction scavenger."""
        return len(self._scavenged)
