"""Placement policies: which GPU gets an arriving task.

A policy sees the arriving program and the live per-GPU cores (through
``SimCore.state_view()`` — the same read-only view admission controllers
get) and returns the index of the chosen GPU. Baselines:

  * ``RoundRobinPlacement`` — arrival order, no load awareness;
  * ``LeastLoadedPlacement`` — fewest resident-plus-queued tasks (the classic
    task-count balancer; blind to memory and to device capacity).

``MSchedPlacement`` is the MSched-aware bin-packer: it prices each GPU's
*per-schedule-cycle HBM demand* from exactly the state the memory manager
already maintains — every admitted task's predicted per-quantum working set
(the planner's ``consume_cut``) plus the whole-footprint bound for queued
candidates — and best-fits the arrival's footprint against the remaining
residency headroom. When several GPUs fit equally it prefers the one whose
interconnect lands the working set fastest (``plan_population_runs`` on the
candidate footprint — meaningful on heterogeneous clusters where swap
bandwidths differ); when nothing fits it picks the least *relatively*
overloaded device, which degrades gracefully into capacity-proportional
balancing.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

from repro.core.migration import plan_population_runs
from repro.core.simulator import SimState, active_demand_pages
from repro.core.workloads import TaskProgram, footprint_pages
from repro.cluster.topology import HOST, ClusterTopology


class PlacementPolicy:
    """Base class for arrival-dispatch policies.

    ``simulate_cluster`` calls :meth:`place` once per trace arrival, the
    moment the request arrives; the chosen core receives the program as a
    normal ``TaskArrival`` (its own admission controller still decides
    *when* the task actually starts). Policies may be stateful — one
    instance drives one cluster run.
    """

    name = "base"

    def place(
        self, prog: TaskProgram, arrival_us: float, cores: Sequence
    ) -> int:
        """Index of the GPU that receives ``prog``. ``cores`` expose
        ``state_view() -> SimState`` and ``name``."""
        raise NotImplementedError


class RoundRobinPlacement(PlacementPolicy):
    """Arrival order, no load awareness — the parity baseline: every GPU
    gets every N-th request regardless of footprint or device capacity."""

    name = "roundrobin"

    def __init__(self) -> None:
        self._next = 0

    def place(self, prog, arrival_us, cores):
        i = self._next % len(cores)
        self._next += 1
        return i


class LeastLoadedPlacement(PlacementPolicy):
    """Fewest tasks on device (active + queued); ties go to the lowest
    index. Capacity- and memory-blind by design — the baseline the paper-
    style bin-packer is measured against."""

    name = "leastloaded"

    def place(self, prog, arrival_us, cores):
        loads = []
        for i, core in enumerate(cores):
            st: SimState = core.state_view()
            loads.append((len(st.active) + st.waiting, i))
        return min(loads)[1]


class MSchedPlacement(PlacementPolicy):
    """Best-fit by predicted working set against per-GPU residency headroom.

    ``headroom`` mirrors the admission controller's: the fraction of HBM the
    packed working sets may claim. ``quantum_us`` defaults to each GPU's own
    scheduler quantum.

    ``topology`` (optional; the engine wires it for NVLink-bearing fleets)
    makes the landing-time tie-break *fluid-share aware*: a GPU whose host
    link is currently carrying in-flight migrations or peer prefetches
    would land the arrival's working set at a contended share of its PCIe
    bandwidth, so its landing estimate is scaled by the live sharer count
    (``ClusterTopology.active_on``). Peer-less fleets never set it, keeping
    their placement decisions identical to the plain bin-packer.
    """

    name = "msched"

    def __init__(
        self,
        headroom: float = 0.9,
        quantum_us: Optional[float] = None,
        topology: Optional[ClusterTopology] = None,
    ):
        assert headroom > 0
        self.headroom = headroom
        self.quantum_us = quantum_us
        self.topology = topology

    def _demand(self, st: SimState) -> int:
        quantum = self.quantum_us or getattr(st.policy, "quantum_us", 5_000.0)
        return active_demand_pages(st, quantum) + st.waiting_pages

    def place(self, prog, arrival_us, cores):
        fits: List[tuple] = []
        overloaded: List[tuple] = []
        for i, core in enumerate(cores):
            st: SimState = core.state_view()
            cand = footprint_pages(prog, st.page_size)
            budget = self.headroom * st.pool.capacity
            free = budget - self._demand(st)
            if cand <= free:
                # tightest feasible fit: filling the snuggest GPU first
                # preserves the large contiguous headrooms for the large
                # arrivals that have nowhere else to go (classic best-fit);
                # ties go to the fastest-landing interconnect, at the fluid
                # share its host link would actually grant right now
                land_us = plan_population_runs(
                    st.platform, [(0, cand)], 0, True, st.page_size
                ).total_us
                if self.topology is not None:
                    land_us *= 1 + self.topology.active_on(
                        core.name, HOST, arrival_us
                    )
                fits.append((free - cand, land_us, i))
            else:
                # relative overload: a 2x-capacity device absorbs twice the
                # spill before it is as pressured as its smaller sibling
                overloaded.append(((self._demand(st) + cand) / st.pool.capacity, i))
        if fits:
            return min(fits)[2]
        return min(overloaded)[1]


PLACEMENTS = {
    RoundRobinPlacement.name: RoundRobinPlacement,
    LeastLoadedPlacement.name: LeastLoadedPlacement,
    MSchedPlacement.name: MSchedPlacement,
}


def make_placement(name_or_policy) -> PlacementPolicy:
    """Resolve a policy: an instance passes through (callers may pre-build
    one with custom knobs), a name from :data:`PLACEMENTS` is constructed
    with defaults. ``simulate_cluster`` accepts either form."""
    if isinstance(name_or_policy, PlacementPolicy):
        return name_or_policy
    return PLACEMENTS[name_or_policy]()
