"""NVLink peer-to-peer working-set prefetch: the cluster side of the
extended context switch.

When a task migrates between NVLink-connected GPUs, the bulk working-set
copy is unnecessary: only the *manifest* ships (``Rebalancer``'s lazy path),
the pages stay resident on the source — demoted to its eviction-list head,
so they cost the source nothing — and the target's memory manager pulls the
predicted working set over NVLink during its own extended context switches.
That turns the paper's core move (one proactive migration instead of
fragmented faults) into a cluster-level primitive: the prefetch is sourced
from whichever tier is fastest (peer HBM ≫ host DRAM), priced by the link
graph's fluid-share bandwidth, and contends with ordinary migrations on the
same edges.

:class:`PeerPrefetchFabric` is the wiring layer the cluster engine installs
when (and only when) the topology has NVLink edges:

  * a per-core ``peer_source`` hook on each MSched coordinator that
    partitions a switch's population set into **peer / host / fresh** source
    tiers (:func:`repro.core.planner.partition_source_tiers`) and returns a
    :class:`~repro.core.migration.TieredMigration` pricing the peer tier at
    the NVLink fluid-share rate (``ClusterTopology.plan_transfer`` — the same
    contention bookkeeping migrations use);
  * a per-core ``cluster_view`` hook feeding the coordinator's madvise walk
    the *fleet-level* next-use estimate of lingering foreign runs, so each
    GPU's eviction list realizes Belady-OPT over the cluster-wide timeline —
    the eviction head holds the page the *cluster* needs last, and
    evicted-but-peer-resident runs become prefetch sources instead of host
    round-trips;
  * :meth:`reap` — reclaims lingering copies once the fleet no longer needs
    them (task finished/rejected elsewhere, or end of run).

The directory is a hint, never the truth: every fetch re-checks the source
pool's live residency, and lingered sub-runs the source evicted under its
own pressure fall back to the host tier (counted in ``fallback_pages``).
Fetched runs *move* (single-owner accounting): they are dropped from the
source pool and consumed from the directory.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.migration import (
    PeerGroup,
    TieredMigration,
    plan_population_runs,
)
from repro.core.pages import PageRun, merge_runs, run_page_count, subtract_runs
from repro.core.planner import partition_source_tiers
from repro.core.simulator import SimCore
from repro.cluster.topology import ClusterTopology, LingerEntry, PageDirectory


@dataclasses.dataclass
class PeerFetchEvent:
    """One committed peer-HBM fetch, for reporting: ``pages`` moved from
    ``src`` to ``dst`` over NVLink, landing at ``arrival_us``;
    ``fallback_pages`` of the same switch's lingered set had already been
    evicted by the source and took the host tier instead."""

    time_us: float
    task_id: int
    src: str
    dst: str
    pages: int
    nbytes: int
    arrival_us: float
    fallback_pages: int


class PeerPrefetchFabric:
    """Owns the page-location directory and wires the per-core cluster hooks.

    Built by ``simulate_cluster`` for NVLink-bearing topologies with the
    ``msched`` backend; a peer-less fleet never constructs one, which is the
    structural guarantee that plain compositions stay bit-for-bit with the
    single-GPU engine.
    """

    def __init__(self, topology: ClusterTopology, cores: Sequence[SimCore]):
        self.topology = topology
        self.cores: Dict[str, SimCore] = {c.name: c for c in cores}
        self.directory = PageDirectory()
        self.fetches: List[PeerFetchEvent] = []
        self.fallback_pages = 0  # lingered runs lost to source-side eviction
        self.fresh_pages = 0  # populated pages never held by any peer
        self.reclaimed_pages = 0
        # linger copies reclaimed by the finish hook — i.e. at the instant
        # their task retired, instead of waiting for the next rebalance tick
        self.finish_reaped = 0
        # telemetry hub or None; assigned by simulate_cluster when tracing
        self.telemetry = None
        # TransferPlanner or None; assigned by simulate_cluster in
        # transfer_plan="auto" mode. When present, the cluster view applies
        # the planner's pressure feedback: a lingering copy whose refetch
        # saving no longer covers the local misses its retention causes is
        # left unprotected for the eviction scavenger.
        self.planner = None

    def wire(self) -> None:
        """Install ``peer_source`` + ``cluster_view`` on every MSched
        coordinator (um/suv have no coordinator; ideal keeps its idealized
        bound and ignores real interconnects by design), and the per-core
        finish hook that reaps a retired task's directory hint immediately
        — a task that finishes mid-flight (its lazy-migration manifest still
        in transit) must not leave its linger copy pinned on the source
        until the next rebalance tick."""
        for core in self.cores.values():
            core.finish_hook = self._on_finish
            if core.backend.name != "msched":
                continue
            coord = core.backend.coordinator
            coord.peer_source = self._make_peer_source(core)
            coord.cluster_view = self._make_cluster_view(core)

    def _on_finish(self, task_id: int, now: float) -> None:
        if self.directory.get(task_id) is None:
            return
        freed = self.release(task_id)
        if freed > 0:
            self.finish_reaped += freed

    # -- peer-sourced population ---------------------------------------------
    def _make_peer_source(self, core: SimCore):
        def plan(
            task_id: int,
            populated_runs: Sequence[PageRun],
            evicted_pages: int,
            now: float,
        ) -> Optional[TieredMigration]:
            return self._plan_fetch(
                core, task_id, populated_runs, evicted_pages, now
            )

        return plan

    def _plan_fetch(
        self,
        core: SimCore,
        task_id: int,
        populated_runs: Sequence[PageRun],
        evicted_pages: int,
        now: float,
    ) -> Optional[TieredMigration]:
        entry = self.directory.get(task_id)
        if entry is None:
            return None
        if entry.src == core.name:
            # the task ping-ponged back onto the GPU that still held its
            # old working set: admission re-owned those pages, the hint is
            # stale — drop it
            self.directory.forget(task_id)
            return None
        src_core = self.cores.get(entry.src)
        link = self.topology.nvlink_peer(entry.src, core.name)
        if src_core is None or link is None:
            # re-routed beyond NVLink reach: everything comes from host
            return None
        peer, lost, fresh = partition_source_tiers(
            populated_runs, entry.runs, src_core.pool.missing_runs
        )
        self.fallback_pages += run_page_count(lost)
        self.fresh_pages += run_page_count(fresh)
        if lost:
            # source-evicted sub-runs are gone for good: drop them from the
            # hint so later switches neither re-count the fallback nor keep
            # madvising stale runs through the cluster view
            self.directory.consume(task_id, lost)
            self._reclaim_if_exhausted(task_id, src_core)
        if not peer:
            return None
        nbytes = run_page_count(peer) * core.page_size
        plan = self.topology.plan_transfer(
            entry.src, core.name, nbytes, now,
            kind="peer_fetch", task_id=task_id,
        )
        if plan is None:  # direct edges never stage, but stay defensive
            return None
        rate = nbytes / max(plan.arrival_us - now, 1e-9)
        # the copy moves: drop it at the source (reclaiming linger space)
        # and shrink the directory hint to what still lingers
        src_core.pool.drop_runs(peer)
        self.directory.consume(task_id, peer)
        self._reclaim_if_exhausted(task_id, src_core)
        host_runs = subtract_runs(list(populated_runs), merge_runs(peer))
        host_mig = plan_population_runs(
            core.platform,
            host_runs,
            evicted_pages,
            core.backend.coordinator.pipelined,
            core.page_size,
        )
        fetch = PeerFetchEvent(
            now, task_id, entry.src, core.name,
            run_page_count(peer), nbytes, plan.arrival_us,
            run_page_count(lost),
        )
        self.fetches.append(fetch)
        if self.telemetry is not None:
            # transit is NOT ledger-attributed here: the fetch overlaps the
            # switch, and any wait the task actually experiences surfaces as
            # the backend's ready-view delay (migration-wait, in-slice)
            self.telemetry.span(
                "peer_fetch",
                core.name,
                now,
                plan.arrival_us - now,
                task_id=task_id,
                src=entry.src,
                pages=fetch.pages,
                nbytes=nbytes,
                fallback_pages=fetch.fallback_pages,
            )
        return TieredMigration(
            host_mig, [PeerGroup(entry.src, peer, rate)], core.page_size
        )

    # -- fleet-level next-use (cluster-wide OPT) ------------------------------
    def _make_cluster_view(self, core: SimCore):
        def view(now: float) -> List[Tuple[float, List[PageRun]]]:
            out: List[Tuple[float, List[PageRun]]] = []
            for entry in self.directory.on_gpu(core.name):
                if self.planner is not None and not (
                    self.planner.linger_retention_ok(entry, core, now)
                ):
                    # pressure feedback: the refetch saving no longer pays
                    # for the holder's misses (or there is zero headroom) —
                    # leave the copy unprotected; the scavenger may take it
                    # and later fetches fall back to the host tier
                    continue
                est = self._next_use_estimate(entry, now)
                if est is not None:
                    out.append((est, entry.runs))
            return out

        return view

    def _next_use_estimate(
        self, entry: LingerEntry, now: float
    ) -> Optional[float]:
        """When the fleet will next touch a lingering working set: imminent
        if the continuation is running on its target GPU, one quantum per
        queue position if it is waiting behind admission, the manifest
        landing time if still in flight — and never (``None`` → stay
        unprotected, reaped soon) once it finished or was shed."""
        dst = self.cores.get(entry.dst)
        if dst is None or dst.failed:
            # a failed target's victims are resolved by the fault runtime at
            # the failure boundary; anything still pointing at it is garbage
            return None
        rec = dst.rec_by_tid.get(entry.task_id)
        if rec is not None and (rec.finished_us is not None or rec.rejected):
            return None
        if entry.task_id in dst.tasks:
            return max(now, dst.t)
        for pos, (ev, _rec, _pages) in enumerate(dst.waiting):
            if ev.program.task_id == entry.task_id:
                return max(now, dst.t) + (pos + 1) * dst.quantum
        return max(entry.arrival_us, now)

    def _reclaim_if_exhausted(self, task_id: int, src_core: SimCore) -> None:
        """A fully-consumed hint must also release the source's linger
        bookkeeping (the ``lingering`` flag and the registered task span)
        — otherwise every completed lazy migration leaks one stale entry
        on its source core for the rest of the run."""
        if self.directory.get(task_id) is None:
            self.reclaimed_pages += src_core.reclaim_linger(task_id)

    def harvest(self, task_id: int) -> Optional[List[PageRun]]:
        """Withdraw a task's lingering working set so it can travel with the
        task as warm runs (a steal or retry re-routed it to a GPU with *no*
        NVLink edge to the linger source — the copy must move through host
        staging with the task, like any stolen checkpoint, rather than be
        silently re-materialized from a host DRAM that never held it).
        Returns the still-resident runs (dropped from the source pool and
        forgotten from the directory), or ``None`` if nothing lingers."""
        entry = self.directory.forget(task_id)
        if entry is None:
            return None
        src = self.cores.get(entry.src)
        if src is None:
            return None
        gone = merge_runs(src.pool.missing_runs(entry.runs))
        live = subtract_runs(entry.runs, gone)
        src.pool.drop_runs(live)
        src.reclaim_linger(task_id)  # clears the flag; nothing left to free
        return live or None

    def drop_gpu(self, name: str) -> int:
        """A GPU failed: every linger hint *on* it is void (the peer-HBM
        copy vanished with the device — later fetches for those tasks fall
        back to host DRAM, where the backing copy lives). Entries pointing
        *at* the failed GPU (``dst``) are left alone: they are recovery
        sources for its victims, resolved by the fault runtime. Returns the
        number of entries dropped."""
        dropped = 0
        for entry in self.directory.entries():
            if entry.src == name:
                self.directory.forget(entry.task_id)
                dropped += 1
        return dropped

    # -- lifecycle -----------------------------------------------------------
    def release(self, task_id: int) -> int:
        """Reclaim a task's lingering copy outright (re-migration, terminal
        rejection). Returns pages reclaimed."""
        entry = self.directory.forget(task_id)
        if entry is None:
            return 0
        src = self.cores.get(entry.src)
        freed = src.reclaim_linger(task_id) if src is not None else 0
        self.reclaimed_pages += freed
        return freed

    def reap(self, final: bool = False) -> int:
        """Reclaim lingering copies the fleet no longer needs (their task
        finished or was shed on its target GPU); ``final`` reclaims
        everything so end-of-run HBM accounting balances. Called by the
        engine at rebalance ticks and after the terminal drain."""
        freed = 0
        for entry in self.directory.entries():
            if final or self._next_use_estimate(entry, 0.0) is None:
                freed += self.release(entry.task_id)
        return freed

    def peer_bytes(self) -> int:
        return sum(f.nbytes for f in self.fetches)
