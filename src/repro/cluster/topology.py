"""Cluster topology: GPU nodes, the PCIe/NVLink link graph, and the shared
host DRAM staging budget.

The topology answers one question for the cluster scheduler: *what does it
cost to move bytes between two GPUs right now?* Every GPU has a host link
(its PCIe connection, bandwidth taken from the device's ``Platform``); pairs
of GPUs may additionally have a peer-to-peer NVLink edge. A transfer follows
the direct edge when one exists, otherwise it stages through host DRAM
(src → host, then host → dst), charging the staged bytes against the shared
``host_dram_bytes`` budget for the duration of the transfer.

Link **contention** is modeled fluid-at-start: when a transfer enters a link
it shares that link's bandwidth equally with every transfer still active on
it, and the share is fixed for the transfer's lifetime (no re-evaluation as
sharers come and go). That keeps planning deterministic and O(active
transfers) while still penalizing migration storms that pile onto one PCIe
root port — the first-order effect the paper's pipelined-migration analysis
(§6.3) cares about. The assumptions are documented in EXPERIMENTS.md
("Cluster topology model").

When a :class:`~repro.cluster.transfer_plan.TransferPlanner` is attached
(``simulate_cluster(transfer_plan="auto")``), :meth:`plan_transfer` /
:meth:`plan_restore` delegate to it instead: the planner prices every move
against a piecewise-constant fluid schedule (shares re-evaluated as sharers
drain), may route around a saturated host link over an idle NVLink detour,
and *rebooks* in-flight plans (:meth:`rebook`) when later admissions change
their landing times — firing ``replan_hook`` so the engine can retime the
dependent arrival events. With no planner attached every code path below is
byte-identical to the pre-planner fluid-at-start model.
"""
from __future__ import annotations

import dataclasses
from typing import (
    Callable,
    Dict,
    FrozenSet,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
)

from repro.core.hardware import Platform
from repro.core.pages import PageRun, merge_runs, run_page_count, subtract_runs


@dataclasses.dataclass(frozen=True)
class GPUNode:
    """One device in the cluster. ``capacity_bytes`` overrides the platform's
    HBM size (benchmarks shrink capacity to hit a target oversubscription
    without shrinking the workload)."""

    name: str
    platform: Platform
    capacity_bytes: Optional[int] = None

    @property
    def hbm_bytes(self) -> int:
        return self.capacity_bytes or self.platform.hbm_bytes


@dataclasses.dataclass
class Link:
    """Undirected edge of the link graph. ``kind`` is ``"pcie"`` for
    GPU↔host edges and ``"nvlink"`` for GPU↔GPU peer edges."""

    a: str
    b: str
    gbps: float
    kind: str = "pcie"

    def key(self) -> FrozenSet[str]:
        return frozenset((self.a, self.b))


@dataclasses.dataclass
class TransferPlan:
    """One planned inter-GPU transfer: leg completion times on the chosen
    path, with the share each leg got of its link.

    ``kind``/``task_id`` classify the payload for telemetry and replan
    routing (``"bulk"`` when the caller did not say). ``canceled_us`` is
    stamped by :meth:`ClusterTopology.cancel_staging`: from that instant
    the plan's remaining legs no longer count as in-flight — without it a
    canceled transfer and its same-timestamp retry would both be counted
    by the :meth:`ClusterTopology.inflight_bytes` probe."""

    src: str
    dst: str
    nbytes: int
    start_us: float
    arrival_us: float
    staged: bool  # True when routed through host DRAM
    legs: List[Tuple[str, float]]  # (link key as "a<->b", leg end time)
    kind: str = "bulk"
    task_id: Optional[int] = None
    canceled_us: Optional[float] = None


HOST = "host"


class ClusterTopology:
    """GPU fleet + link graph + host DRAM budget.

    ``nvlinks`` lists peer edges as ``(gpu_a, gpu_b, gbps)``. Host links are
    created automatically for every GPU at the platform's PCIe bandwidth
    (``min(d2h, h2d)`` — the symmetric planning rate)."""

    def __init__(
        self,
        gpus: Sequence[GPUNode],
        host_dram_bytes: int = 512 << 30,
        nvlinks: Sequence[Tuple[str, str, float]] = (),
    ):
        if len({g.name for g in gpus}) != len(gpus):
            raise ValueError("GPU names must be unique")
        self.gpus = list(gpus)
        self.by_name = {g.name: g for g in self.gpus}
        self.host_dram_bytes = host_dram_bytes
        self._links: Dict[FrozenSet[str], Link] = {}
        for g in self.gpus:
            bw = min(g.platform.d2h_gbps, g.platform.h2d_gbps)
            self._add(Link(g.name, HOST, bw, "pcie"))
        for a, b, gbps in nvlinks:
            if a not in self.by_name or b not in self.by_name:
                raise ValueError(f"nvlink endpoint not in cluster: {a}<->{b}")
            self._add(Link(a, b, gbps, "nvlink"))
        # active-transfer bookkeeping: link key -> [end_us, ...] and the host
        # staging intervals (start_us, end_us, bytes)
        self._active: Dict[FrozenSet[str], List[float]] = {}
        self._staged: List[Tuple[float, float, int]] = []
        self.transfers: List[TransferPlan] = []
        self.deferred = 0  # transfers denied by the host DRAM budget
        # fault injection: link key -> bandwidth factor (absent = healthy);
        # 0.0 takes an NVLink edge down entirely (traffic re-routes through
        # host staging)
        self._degraded: Dict[FrozenSet[str], float] = {}
        # scheduled-transfer mode: when a TransferPlanner is attached the
        # plan_* entry points delegate to it; replan_hook fires whenever a
        # rebook moves a committed plan's arrival (the engine retimes the
        # dependent TaskArrival). Both stay None in greedy mode.
        self.planner = None  # repro.cluster.transfer_plan.TransferPlanner
        self.replan_hook: Optional[Callable[[TransferPlan, float], None]] = None
        self.replans = 0

    def _add(self, link: Link) -> None:
        self._links[link.key()] = link

    # -- queries -------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.gpus)

    def link(self, a: str, b: str) -> Optional[Link]:
        return self._links.get(frozenset((a, b)))

    def links(self) -> List[Link]:
        return list(self._links.values())

    def link_factor(self, key: FrozenSet[str]) -> float:
        """Current bandwidth factor of a link (1.0 = healthy, 0.0 = down)."""
        return self._degraded.get(key, 1.0)

    def has_nvlink(self) -> bool:
        """True when any peer (GPU↔GPU) edge exists. The cluster engine only
        builds the peer-prefetch machinery for NVLink-bearing fleets, which
        is what keeps peer-less topologies bit-for-bit with the plain
        composition."""
        return any(l.kind == "nvlink" for l in self._links.values())

    def nvlink_peer(self, a: str, b: str) -> Optional[Link]:
        """The direct peer edge between two GPUs, or ``None`` (host-staged).
        A downed edge (``degrade(..., 0.0)``) does not count as a peer."""
        link = self.link(a, b)
        if link is None or link.kind != "nvlink":
            return None
        return link if self.link_factor(link.key()) > 0.0 else None

    def active_on(self, a: str, b: str, at_us: float) -> int:
        """Transfers still in flight on the ``a<->b`` link at ``at_us`` —
        a read-only contention probe (no booking) for fluid-share-aware
        placement and planning."""
        ends = self._active.get(frozenset((a, b)), ())
        return sum(1 for e in ends if e > at_us)

    def inflight_bytes(self, a: str, b: str, at_us: float) -> int:
        """Bytes of planned transfers whose ``a<->b`` leg is still in flight
        at ``at_us`` — a read-only probe (telemetry link-utilization
        counters). A leg covers ``[previous leg's end, its own end)``;
        fluid-at-start pricing means the payload occupies the whole leg.
        A canceled plan (retry chain exhausted, unreachable working set)
        stops counting at its ``canceled_us``: a transfer canceled and
        replanned at the same timestamp must count once, not twice."""
        name = f"{a}<->{b}"
        alt = f"{b}<->{a}"
        total = 0
        for plan in self.transfers:
            if plan.canceled_us is not None and at_us >= plan.canceled_us:
                continue
            start = plan.start_us
            for leg_name, leg_end in plan.legs:
                if leg_name in (name, alt) and start <= at_us < leg_end:
                    total += plan.nbytes
                start = leg_end
        return total

    def solo_transfer_us(self, src: str, dst: str, nbytes: int) -> float:
        """What moving ``nbytes`` would take on an *uncontended* path at
        current degradation factors — no booking, no staging check. The
        telemetry layer splits a real (shared-rate) transit time against
        this floor: the solo portion is migration-wait, the excess is
        link-contention."""
        if nbytes <= 0:
            return 0.0
        us = 0.0
        for link in self.path(src, dst):
            factor = self.link_factor(link.key())
            if factor <= 0.0:
                return float("inf")
            us += nbytes / (link.gbps * factor * 1e3)
        return us

    def path(self, src: str, dst: str) -> List[Link]:
        """Direct peer edge when present (and not downed), else host-staged
        two-hop path."""
        direct = self.link(src, dst)
        if direct is not None and self.link_factor(direct.key()) > 0.0:
            return [direct]
        return [self._links[frozenset((src, HOST))],
                self._links[frozenset((dst, HOST))]]

    def host_staged_bytes(self, now: float) -> int:
        """Bytes currently parked in host DRAM by in-flight staged
        transfers. Drained stagings are pruned in place (time only moves
        forward within a run), so the scan stays O(in-flight)."""
        self._staged = [se for se in self._staged if se[1] > now]
        return sum(b for s, e, b in self._staged if s <= now)

    def reset_transfers(self) -> None:
        """Clear all transfer bookkeeping (active links, stagings, history,
        deferral count). ``simulate_cluster`` calls this at the start of a
        run: contention state is per-run, so one topology can be reused
        across a policy sweep."""
        self._active.clear()
        self._staged.clear()
        self.transfers.clear()
        self.deferred = 0
        self._degraded.clear()
        self.replans = 0
        if self.planner is not None:
            self.planner.reset()

    # -- fault injection -----------------------------------------------------
    def degrade(self, a: str, b: str, factor: float) -> None:
        """Scale the ``a<->b`` link's bandwidth by ``factor`` (a flap or
        partial lane failure). ``factor == 0`` takes the edge *down* —
        NVLink edges only: peer traffic re-routes through host staging, but
        a GPU's host link must always exist (a GPU with no PCIe path is a
        failed GPU, which is a ``gpu_fail`` event, not a link event).
        In-flight transfers keep their planned times (fluid-at-start); only
        new plans see the factor."""
        key = frozenset((a, b))
        link = self._links.get(key)
        if link is None:
            raise ValueError(f"no link {a}<->{b}")
        if not 0.0 <= factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1], got {factor}")
        if factor == 0.0 and link.kind != "nvlink":
            raise ValueError("only NVLink edges can go fully down")
        if factor == 1.0:
            self._degraded.pop(key, None)
        else:
            self._degraded[key] = factor

    def restore(self, a: str, b: str) -> None:
        """Undo :meth:`degrade` on the ``a<->b`` link."""
        self._degraded.pop(frozenset((a, b)), None)

    def cancel_staging(
        self, plan: TransferPlan, at_us: Optional[float] = None
    ) -> int:
        """Drop a staged transfer's host-DRAM reservation before it drains
        (a retry chain was exhausted, or a failure made the parked working
        set unreachable — the bytes will never be consumed). Returns bytes
        released (0 when the staging already drained).

        ``at_us`` marks the plan canceled at that instant so the in-flight
        probes stop counting its remaining legs — a transfer canceled and
        replanned at the same timestamp otherwise shows up twice in
        :meth:`inflight_bytes`. Fluid-at-start *pricing* deliberately keeps
        the dead booking (conservative, and byte-identical to the
        pre-planner model); an attached planner instead drops the flight
        and rebooks the survivors at their recovered shares."""
        if not plan.staged:
            return 0
        token = (plan.start_us, plan.arrival_us, plan.nbytes)
        try:
            self._staged.remove(token)
        except ValueError:
            return 0
        if at_us is not None:
            plan.canceled_us = at_us
            if self.planner is not None:
                self.planner.on_cancel(plan, at_us)
        return plan.nbytes

    def _sharers(self, key: FrozenSet[str], at_us: float) -> int:
        """This transfer plus every transfer still active on the link."""
        ends = self._active.setdefault(key, [])
        ends[:] = [e for e in ends if e > at_us]
        return 1 + len(ends)

    # -- planner bookkeeping --------------------------------------------------
    def book(self, plan: TransferPlan) -> None:
        """Commit an externally-priced plan (the attached planner's exact
        piecewise-constant schedule) into the same bookkeeping greedy plans
        use, so ``active_on`` / ``inflight_bytes`` / ``host_staged_bytes``
        and the staging-cancel protocol keep working unchanged."""
        for leg_name, leg_end in plan.legs:
            key = frozenset(leg_name.split("<->"))
            self._active.setdefault(key, []).append(leg_end)
        if plan.staged:
            self._staged.append((plan.start_us, plan.arrival_us, plan.nbytes))
        self.transfers.append(plan)

    def rebook(self, plan: TransferPlan, new_legs: List[Tuple[str, float]]) -> None:
        """Replace a committed plan's leg schedule in place (the planner
        re-solved the fluid schedule after a later admission or a cancel
        changed this flight's shares). Updates the active-end and staging
        ledgers to the new times, counts a replan, and fires
        ``replan_hook(plan, old_arrival_us)`` so the engine can retime the
        arrival event that depends on this landing."""
        old_arrival = plan.arrival_us
        for leg_name, leg_end in plan.legs:
            lst = self._active.get(frozenset(leg_name.split("<->")))
            if lst is not None:
                try:
                    lst.remove(leg_end)
                except ValueError:
                    pass  # already pruned by a _sharers sweep
        for leg_name, leg_end in new_legs:
            key = frozenset(leg_name.split("<->"))
            self._active.setdefault(key, []).append(leg_end)
        new_arrival = new_legs[-1][1] if new_legs else old_arrival
        if plan.staged:
            token = (plan.start_us, old_arrival, plan.nbytes)
            try:
                i = self._staged.index(token)
                self._staged[i] = (plan.start_us, new_arrival, plan.nbytes)
            except ValueError:
                pass  # staging already drained or canceled
        plan.legs = list(new_legs)
        plan.arrival_us = new_arrival
        if new_arrival != old_arrival:
            self.replans += 1
            if self.replan_hook is not None:
                self.replan_hook(plan, old_arrival)

    # -- planning ------------------------------------------------------------
    def plan_transfer(
        self,
        src: str,
        dst: str,
        nbytes: int,
        now: float,
        *,
        kind: str = "bulk",
        urgency: Optional[int] = None,
        task_id: Optional[int] = None,
    ) -> Optional[TransferPlan]:
        """Price moving ``nbytes`` from ``src`` to ``dst`` starting at
        ``now`` and commit the plan to the contention bookkeeping. Returns
        ``None`` (and counts a deferral) when the transfer would need host
        staging beyond the DRAM budget — the caller retries at a later
        rebalance tick, when earlier stagings have drained.

        ``kind``/``urgency``/``task_id`` classify the movement for the
        attached :class:`~repro.cluster.transfer_plan.TransferPlanner`
        (scheduled mode); the greedy model stamps them on the plan and
        otherwise ignores them, so greedy pricing is unchanged."""
        if src == dst:
            raise ValueError("transfer to self")
        if self.planner is not None:
            from repro.cluster.transfer_plan import TransferRequest

            return self.planner.submit_one(
                TransferRequest(src, dst, nbytes, kind, urgency, task_id), now
            )
        path = self.path(src, dst)
        staged = len(path) > 1
        if staged:
            in_use = self.host_staged_bytes(now)
            if in_use + nbytes > self.host_dram_bytes:
                self.deferred += 1
                return None
        t = now
        legs: List[Tuple[str, float]] = []
        for link in path:
            key = link.key()
            share = self._sharers(key, t)
            rate = link.gbps * self.link_factor(key) * 1e3 / share  # bytes/us
            t += nbytes / rate
            self._active[key].append(t)
            legs.append((f"{link.a}<->{link.b}", t))
        if staged:
            self._staged.append((now, t, nbytes))
        plan = TransferPlan(
            src, dst, nbytes, now, t, staged, legs, kind=kind, task_id=task_id
        )
        self.transfers.append(plan)
        return plan

    def plan_restore(
        self,
        dst: str,
        nbytes: int,
        now: float,
        *,
        urgency: Optional[int] = None,
        task_id: Optional[int] = None,
    ) -> Optional[TransferPlan]:
        """Price re-landing ``nbytes`` that already sit in host DRAM (a
        checkpoint restore, or a re-dispatched continuation's warm working
        set) onto ``dst``: one host-link leg, with the bytes charged against
        the staging budget until they land. A saturated budget defers the
        restore (``None`` + a deferral count) — the caller backs off and
        retries, or falls back to another recovery source. An empty payload
        (a checkpoint of a task with nothing resident) lands instantly and
        never touches the link or the staging ledger."""
        if nbytes <= 0:
            return TransferPlan(
                HOST, dst, 0, now, now, False, [], kind="restore",
                task_id=task_id,
            )
        if self.planner is not None:
            from repro.cluster.transfer_plan import TransferRequest

            return self.planner.submit_one(
                TransferRequest(HOST, dst, nbytes, "restore", urgency, task_id),
                now,
            )
        in_use = self.host_staged_bytes(now)
        if in_use + nbytes > self.host_dram_bytes:
            self.deferred += 1
            return None
        link = self._links[frozenset((dst, HOST))]
        key = link.key()
        share = self._sharers(key, now)
        rate = link.gbps * self.link_factor(key) * 1e3 / share
        t = now + nbytes / rate
        self._active[key].append(t)
        plan = TransferPlan(
            HOST, dst, nbytes, now, t, True, [(f"{link.a}<->{link.b}", t)],
            kind="restore", task_id=task_id,
        )
        self._staged.append((now, t, nbytes))
        self.transfers.append(plan)
        return plan


# --------------------------------------------------------------------------
# Page-location directory
# --------------------------------------------------------------------------


@dataclasses.dataclass
class LingerEntry:
    """One migrated task whose working set still (partially) lives in a peer
    GPU's HBM. ``runs`` is the directory's *hint* of what lingers on ``src``;
    the source pool's live residency is always re-checked at fetch time (the
    source may have evicted runs under its own pressure — those sub-runs
    fall back to host DRAM)."""

    task_id: int
    src: str  # GPU whose HBM holds the lingering runs
    dst: str  # GPU the task migrated to (where it will next run)
    runs: List[PageRun]  # merged (sorted, disjoint)
    arrival_us: float  # when the migration manifest lands on dst

    def pages(self) -> int:
        return run_page_count(self.runs)


class PageDirectory:
    """Cluster-wide map of *where a migrated task's resident runs live*.

    The directory is the piece of shared state that turns per-GPU memory
    managers into a cluster co-design: the migration planner consults it to
    source a working set from a peer's HBM over NVLink instead of host DRAM,
    and each GPU's coordinator consults it (via the engine's fleet view) to
    keep lingering runs that a *peer* needs soon out of the local eviction
    head — Belady-OPT over the cluster-wide next-use timeline.

    One entry per task (a task's working set lingers on at most one GPU —
    re-migration reclaims the old copy first). Entries are hints: residency
    truth stays in the owning pool."""

    def __init__(self) -> None:
        self._entries: Dict[int, LingerEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def record(
        self,
        task_id: int,
        src: str,
        dst: str,
        runs: Sequence[PageRun],
        arrival_us: float,
    ) -> LingerEntry:
        entry = LingerEntry(task_id, src, dst, merge_runs(runs), arrival_us)
        self._entries[task_id] = entry
        return entry

    def get(self, task_id: int) -> Optional[LingerEntry]:
        return self._entries.get(task_id)

    def forget(self, task_id: int) -> Optional[LingerEntry]:
        return self._entries.pop(task_id, None)

    def on_gpu(self, src: str) -> Iterator[LingerEntry]:
        """Entries whose lingering runs live on ``src`` (the source GPU's
        coordinator asks this to protect fleet-needed runs)."""
        return (e for e in self._entries.values() if e.src == src)

    def entries(self) -> List[LingerEntry]:
        return list(self._entries.values())

    def retarget(self, task_id: int, new_dst: str) -> None:
        """A queued continuation was stolen/re-routed: its lingering runs
        stay put, but the GPU that will fetch them changed."""
        e = self._entries.get(task_id)
        if e is not None:
            e.dst = new_dst

    def consume(self, task_id: int, fetched: Sequence[PageRun]) -> None:
        """Drop fetched sub-runs from the hint (the peer copy moved to the
        fetching GPU); an emptied entry is forgotten."""
        e = self._entries.get(task_id)
        if e is None:
            return
        e.runs = subtract_runs(e.runs, merge_runs(fetched))
        if not e.runs:
            self._entries.pop(task_id, None)


def homogeneous(
    n: int,
    platform: Platform,
    capacity_bytes: Optional[int] = None,
    host_dram_bytes: int = 512 << 30,
    nvlink_gbps: Optional[float] = None,
    prefix: str = "gpu",
) -> ClusterTopology:
    """N identical GPUs. ``nvlink_gbps`` adds an all-to-all peer mesh."""
    gpus = [GPUNode(f"{prefix}{i}", platform, capacity_bytes) for i in range(n)]
    links: List[Tuple[str, str, float]] = []
    if nvlink_gbps:
        links = [
            (gpus[i].name, gpus[j].name, nvlink_gbps)
            for i in range(n)
            for j in range(i + 1, n)
        ]
    return ClusterTopology(gpus, host_dram_bytes, links)


def mixed(
    nodes: Sequence[Tuple[Platform, Optional[int]]],
    host_dram_bytes: int = 512 << 30,
    nvlinks: Sequence[Tuple[str, str, float]] = (),
    prefix: str = "gpu",
) -> ClusterTopology:
    """Heterogeneous cluster from (platform, capacity_override) pairs."""
    gpus = [
        GPUNode(f"{prefix}{i}", plat, cap) for i, (plat, cap) in enumerate(nodes)
    ]
    return ClusterTopology(gpus, host_dram_bytes, nvlinks)
