"""Fault injection and recovery: GPU loss, link degradation, task crashes —
and the machinery that keeps the fleet serving through them.

The paper's thesis is that memory movement is predictable enough to schedule
*proactively*; a production fleet must also survive the unpredictable. This
module supplies both halves:

  * :class:`FaultInjector` — a seeded, trace-schedulable source of
    :class:`FaultEvent`\\ s (``gpu_fail``/``gpu_recover``, per-edge
    ``link_degrade``/``link_restore`` flaps, ``task_crash`` ECC-style fatal
    faults) that ``simulate_cluster`` consumes as first-class events in its
    conservative DES loop. Schedules are either explicit (tests pin exact
    timelines) or sampled (:meth:`FaultInjector.random` — exponential
    fail/repair cycles, deterministic per seed).
  * :class:`CheckpointVault` — periodic working-set snapshots to host DRAM,
    priced as real D2H transfers on the link graph (checkpointing *contends*
    with migrations and prefetches for the PCIe root port — the overhead the
    goodput benchmark charges against recovery quality).
  * :class:`FaultRuntime` — the recovery policy. A failing GPU surrenders
    everything (``SimCore.fail``); queued candidates are re-dispatched to
    surviving devices (their host-DRAM warm sets re-priced through
    ``plan_restore``), and each running victim is re-placed from its best
    durable source:

      1. a *landed* checkpoint with progress (``completed > 0``) — restores
         the iteration prefix, pays one H2D restore leg;
      2. a surviving linger copy (harvested through the existing
         ``PageDirectory`` path) — loses this visit's iterations but lands
         instantly on the GPU that still holds the working set;
      3. a progress-free checkpoint (warm pages only);
      4. cold restart — nothing durable survived; pages fault back in from
         the host backing store.

    A restore denied by the saturated host staging budget backs off with
    capped exponential delay (layered on the PR 5 retry protocol) before
    degrading to a colder source. When capacity shrinks, graceful
    degradation sheds best-effort queued work *before* touching RT SLO
    classes.

The UM backing-store model is what makes recovery semantics crisp: host DRAM
holds every page's backing copy, so a GPU failure loses only the HBM *cache*
and execution state. Durable progress therefore lives in exactly two places
— checkpointed iteration counts, and the iteration offset already baked into
a migrated continuation — and recovery is always "re-place the program
somewhere, warm or cold".
"""
from __future__ import annotations

import dataclasses
import heapq
import random
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.hbm import resident_runs_in
from repro.core.pages import PageRun, run_page_count
from repro.core.simulator import (
    EjectedTask,
    RequestRecord,
    SimCore,
    TaskArrival,
    active_demand_pages,
)
from repro.cluster.migration import ResumedTask, checkpoint_roundtrip
from repro.cluster.topology import HOST, ClusterTopology
from repro.cluster.transfer_plan import URGENCY_RESTORE, URGENCY_RT
from repro.control.deadline import slo_class_of

FAULT_KINDS = (
    "gpu_fail",
    "gpu_recover",
    "link_degrade",
    "link_restore",
    "task_crash",
    "coordinator_crash",
    "coordinator_recover",
)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``gpu`` names the device for GPU events;
    ``link`` is the ``(a, b)`` endpoint pair for link events (``factor``
    scales its bandwidth, 0.0 = NVLink edge down); ``task_id`` optionally
    pins which task a ``task_crash`` kills (``None`` = seeded pick among
    the tasks running at crash time). ``coordinator_crash``/
    ``coordinator_recover`` tear down and restart the control plane —
    schedules containing them require ``simulate_cluster(control=...)``."""

    time_us: float
    kind: str
    gpu: Optional[str] = None
    link: Optional[Tuple[str, str]] = None
    factor: float = 1.0
    task_id: Optional[int] = None

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind in ("gpu_fail", "gpu_recover") and not self.gpu:
            raise ValueError(f"{self.kind} needs a gpu name")
        if self.kind in ("link_degrade", "link_restore") and not self.link:
            raise ValueError(f"{self.kind} needs link endpoints")
        if self.kind == "link_degrade" and not 0.0 <= self.factor <= 1.0:
            raise ValueError(f"degrade factor must be in [0, 1]")


class FaultInjector:
    """A fault schedule: an ordered stream of :class:`FaultEvent`.

    Built either from an explicit event list (tests pin timelines) or
    sampled via :meth:`random` (exponential MTBF/MTTR cycles, deterministic
    per seed). ``FaultInjector.none()`` is the explicit empty schedule —
    pinned bit-for-bit identical to running without an injector, because
    the engine constructs no fault machinery for it."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        evs = list(events)
        for ev in evs:
            if not isinstance(ev, FaultEvent):
                raise TypeError(f"expected FaultEvent, got {type(ev)!r}")
        # stable sort: simultaneous events keep schedule order
        self.events: List[FaultEvent] = sorted(evs, key=lambda e: e.time_us)

    @classmethod
    def none(cls) -> "FaultInjector":
        return cls(())

    @property
    def empty(self) -> bool:
        return not self.events

    @classmethod
    def random(
        cls,
        topology: ClusterTopology,
        duration_us: float,
        seed: int = 0,
        gpu_mtbf_us: Optional[float] = None,
        gpu_mttr_us: float = 400_000.0,
        link_mtbf_us: Optional[float] = None,
        link_mttr_us: float = 150_000.0,
        link_factor: float = 0.25,
        crash_mtbf_us: Optional[float] = None,
        coord_mtbf_us: Optional[float] = None,
        coord_mttr_us: float = 300_000.0,
    ) -> "FaultInjector":
        """Sample a schedule over ``[0, duration_us)``: per-GPU exponential
        fail→repair cycles (``gpu_mtbf_us``/``gpu_mttr_us``), per-link
        degrade→restore flaps (NVLink edges may use any ``link_factor``
        including 0; host PCIe links are clamped to ≥ 0.05 — a GPU with no
        host path is a failed GPU, not a slow link), a fleet-wide Poisson
        crash process (``crash_mtbf_us``), and coordinator outage cycles
        (``coord_mtbf_us``/``coord_mttr_us``). ``None`` disables a fault
        class. Deterministic for a given seed."""
        rnd = random.Random(seed)
        events: List[FaultEvent] = []
        if gpu_mtbf_us:
            for g in sorted(n.name for n in topology.gpus):
                t = rnd.expovariate(1.0 / gpu_mtbf_us)
                while t < duration_us:
                    repair = rnd.expovariate(1.0 / gpu_mttr_us)
                    events.append(FaultEvent(t, "gpu_fail", gpu=g))
                    events.append(FaultEvent(t + repair, "gpu_recover", gpu=g))
                    t += repair + rnd.expovariate(1.0 / gpu_mtbf_us)
        if link_mtbf_us:
            for link in sorted(
                topology.links(), key=lambda l: (l.a, l.b)
            ):
                ends = (link.a, link.b)
                factor = (
                    link_factor
                    if link.kind == "nvlink"
                    else max(link_factor, 0.05)
                )
                t = rnd.expovariate(1.0 / link_mtbf_us)
                while t < duration_us:
                    repair = rnd.expovariate(1.0 / link_mttr_us)
                    events.append(
                        FaultEvent(
                            t, "link_degrade", link=ends, factor=factor
                        )
                    )
                    events.append(
                        FaultEvent(t + repair, "link_restore", link=ends)
                    )
                    t += repair + rnd.expovariate(1.0 / link_mtbf_us)
        if crash_mtbf_us:
            t = rnd.expovariate(1.0 / crash_mtbf_us)
            while t < duration_us:
                events.append(FaultEvent(t, "task_crash"))
                t += rnd.expovariate(1.0 / crash_mtbf_us)
        if coord_mtbf_us:
            t = rnd.expovariate(1.0 / coord_mtbf_us)
            while t < duration_us:
                repair = rnd.expovariate(1.0 / coord_mttr_us)
                events.append(FaultEvent(t, "coordinator_crash"))
                events.append(FaultEvent(t + repair, "coordinator_recover"))
                t += repair + rnd.expovariate(1.0 / coord_mtbf_us)
        return cls(events)


# --------------------------------------------------------------------------
# Checkpoint vault
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Checkpoint:
    """One durable working-set snapshot in host DRAM. ``ready_us`` is when
    the D2H copy lands (a checkpoint is restorable only once *landed* — a
    failure mid-copy loses it). ``program`` pins the snapshot to the exact
    continuation it was taken from: ``completed`` is relative to that
    program's iteration base, so a checkpoint must never restore against a
    different visit's continuation."""

    task_id: int
    taken_us: float
    ready_us: float
    completed: int
    runs: List[PageRun]
    nbytes: int
    program: object


class CheckpointVault:
    """Periodic per-task working-set snapshots, priced on the link graph.

    ``snapshot`` walks every running task on every alive core and copies
    its resident working set D2H over the core's host link — sharing
    (and contending for) the same fluid-share bandwidth migrations use.
    Checkpoint *residency* in host DRAM is durable storage (not charged to
    the transient staging budget); the *restore* leg is priced by
    ``ClusterTopology.plan_restore`` at recovery time. Snapshots of a task
    that made no progress since its last checkpoint are skipped (no new
    information, no D2H traffic). With a ``stage_dir`` each manifest
    round-trips through the sharded on-disk checkpoint format."""

    def __init__(
        self,
        topology: ClusterTopology,
        page_size: int,
        stage_dir: Optional[str] = None,
        keep: int = 2,
    ):
        assert keep >= 1
        self.topology = topology
        self.page_size = page_size
        self.stage_dir = stage_dir
        self.keep = keep
        self._by_task: Dict[int, List[Checkpoint]] = {}
        self._seq = 0
        self.taken = 0
        self.bytes = 0
        self.skipped = 0  # no-progress snapshots avoided
        self.deferred = 0  # D2H legs denied by link-graph planning
        # telemetry hub or None; assigned by simulate_cluster when tracing
        self.telemetry = None
        # ControlPlane or None; assigned by ControlPlane.attach
        self.control = None

    def snapshot(self, cores: Sequence[SimCore], now: float) -> int:
        """Checkpoint every running task on every alive core; returns the
        number of snapshots taken."""
        n = 0
        for core in cores:
            if core.failed:
                continue
            for tid in sorted(core.tasks):
                rt = core.tasks[tid]
                cks = self._by_task.get(tid)
                if (
                    cks
                    and cks[-1].program is rt.prog
                    and cks[-1].completed == rt.stats.completions
                ):
                    self.skipped += 1
                    continue
                span = rt.prog.space.page_span()
                runs = resident_runs_in(core.pool, span)
                nbytes = run_page_count(runs) * self.page_size
                if nbytes:
                    # snapshots are speculative traffic: an attached planner
                    # may defer them behind urgent restores under a storm
                    plan = self.topology.plan_transfer(
                        core.name, HOST, nbytes, now,
                        kind="snapshot", task_id=rt.prog.task_id,
                    )
                    if plan is None:
                        self.deferred += 1
                        continue
                    ready = plan.arrival_us
                else:
                    ready = now
                if self.control is not None:
                    self.control.record(
                        "checkpoint",
                        now,
                        tid,
                        gpu=core.name,
                        nbytes=nbytes,
                        completed=rt.stats.completions,
                    )
                if self.stage_dir is not None:
                    runs = checkpoint_roundtrip(
                        self.stage_dir,
                        self._seq,
                        EjectedTask(rt.prog, rt.stats.completions, runs, None),
                        self.page_size,
                    )
                    self._seq += 1
                lst = self._by_task.setdefault(tid, [])
                lst.append(
                    Checkpoint(
                        tid, now, ready, rt.stats.completions,
                        list(runs), nbytes, rt.prog,
                    )
                )
                del lst[:-self.keep]
                self.taken += 1
                self.bytes += nbytes
                n += 1
                if self.telemetry is not None:
                    self.telemetry.span(
                        "checkpoint",
                        core.name,
                        now,
                        ready - now,
                        task_id=tid,
                        nbytes=nbytes,
                        completed=rt.stats.completions,
                    )
        return n

    def get(
        self, task_id: int, now: float, program: object
    ) -> Optional[Checkpoint]:
        """Best restorable checkpoint: landed (``ready_us <= now``), taken
        from exactly this continuation (stale cross-visit snapshots would
        restore a ``completed`` count against the wrong iteration base),
        most progress wins, newest breaks ties."""
        best = None
        for ck in self._by_task.get(task_id, ()):
            if ck.ready_us > now or ck.program is not program:
                continue
            if (
                best is None
                or ck.completed > best.completed
                or (ck.completed == best.completed and ck.taken_us > best.taken_us)
            ):
                best = ck
        return best

    def drop(self, task_id: int) -> None:
        self._by_task.pop(task_id, None)

    def prune(
        self, cores: Sequence[SimCore], extra_live: Sequence[int] = ()
    ) -> int:
        """Drop checkpoints of tasks no longer live anywhere (finished,
        shed, or lost) — the no-orphaned-artifacts half of the vault's
        contract. ``extra_live`` protects victims the fault runtime still
        holds (stranded, held, or backing off)."""
        live: Set[int] = set(extra_live)
        for core in cores:
            live.update(core.tasks)
            live.update(ev.program.task_id for ev, _r, _p in core.waiting)
            live.update(ev.program.task_id for ev in core.pending)
            live.update(core.lingering)
        dead = [tid for tid in self._by_task if tid not in live]
        for tid in dead:
            del self._by_task[tid]
        return len(dead)


# --------------------------------------------------------------------------
# Fault runtime (recovery policy)
# --------------------------------------------------------------------------


@dataclasses.dataclass
class RecoveryEvent:
    """One recovery decision, for reporting. ``kind`` is ``"checkpoint"``
    (restored a landed snapshot: ``completed`` iterations preserved),
    ``"linger"`` (re-placed on the GPU still holding the working set —
    instant, but this visit's iterations replay), ``"cold"`` (nothing
    durable survived), or ``"requeue"`` (restore denied by the staging
    budget; backing off). ``replayed_iters`` is the progress lost."""

    time_us: float
    task_id: int
    kind: str  # "checkpoint" | "linger" | "cold" | "requeue"
    src: str  # the failed/crashed origin
    dst: str
    completed: int  # iterations the recovery source preserves
    replayed_iters: int
    arrival_us: float


class FaultRuntime:
    """Consumes a :class:`FaultInjector` schedule inside the cluster loop
    and drives recovery. Owns the retry heap (capped exponential backoff on
    budget-denied restores), the held/stranded sets (work with *no* alive
    GPU to run on), and graceful degradation (shedding best-effort queued
    candidates before RT classes when fleet capacity shrinks past
    ``shed_threshold``; RT classes are only shed past ``shed_rt_threshold``,
    default never)."""

    def __init__(
        self,
        injector: FaultInjector,
        topology: ClusterTopology,
        cores: Sequence[SimCore],
        placement,
        fabric=None,
        vault: Optional[CheckpointVault] = None,
        recovery: str = "auto",
        shed_threshold: Optional[float] = 1.25,
        shed_rt_threshold: Optional[float] = None,
        backoff_us: float = 25_000.0,
        backoff_cap_us: float = 400_000.0,
        max_recovery_retries: int = 8,
        seed: int = 0,
    ):
        if recovery not in ("auto", "checkpoint", "linger", "cold"):
            raise ValueError(f"unknown recovery mode {recovery!r}")
        if (
            shed_threshold is not None
            and shed_rt_threshold is not None
            and shed_rt_threshold < shed_threshold
        ):
            raise ValueError(
                "shed_rt_threshold must be >= shed_threshold (RT work "
                "sheds only after best-effort)"
            )
        self.events = list(injector.events)
        self.topology = topology
        self.cores = list(cores)
        self._by_name = {c.name: c for c in self.cores}
        self.placement = placement
        self.fabric = fabric
        self.vault = vault
        self.recovery = recovery
        self.shed_threshold = shed_threshold
        self.shed_rt_threshold = shed_rt_threshold
        self.backoff_us = backoff_us
        self.backoff_cap_us = backoff_cap_us
        self.max_recovery_retries = max_recovery_retries
        self.rnd = random.Random(seed)

        self._ei = 0
        # (due_us, seq, (prog, completed, rec, origin, attempt))
        self._retryq: List[Tuple[float, int, tuple]] = []
        self._seq = 0
        # arrivals with no alive GPU: (TaskArrival, warm_runs, record|None)
        self._held: List[tuple] = []
        # running victims with no alive GPU: (prog, completed, rec, origin)
        self._stranded: List[tuple] = []

        # telemetry hub or None; assigned by simulate_cluster when tracing
        self.telemetry = None
        # ControlPlane or None; assigned by ControlPlane.attach. When set,
        # every queue decision is journaled write-ahead, and the runtime's
        # coordinator-side work (placement, flush, shedding, retries) is
        # gated while the coordinator is down.
        self.control = None
        self.applied: List[FaultEvent] = []
        self.recoveries: List[RecoveryEvent] = []
        self.shed_events: List[Tuple[float, int, str, str]] = []
        self.crashes = 0
        self.lost = 0  # set by drain_lost()
        self.placed = [0] * len(self.cores)

    # -- control-plane coupling ----------------------------------------------
    def _ctl_down(self) -> bool:
        return self.control is not None and self.control.down

    def _journal(self, kind: str, now: float, task_id: int, **payload) -> None:
        if self.control is not None:
            self.control.record(kind, now, task_id, **payload)

    # -- event-stream interface (the engine's DES loop) ----------------------
    def next_time(self) -> float:
        t = (
            self.events[self._ei].time_us
            if self._ei < len(self.events)
            else float("inf")
        )
        if self._retryq and not self._ctl_down():
            t = min(t, self._retryq[0][0])
        return t

    def drain_due_retries(self, now: float) -> None:
        """Pop and re-attempt every backoff-denied restore due by ``now``.
        Also called at ``coordinator_recover``: rebuilt retry entries may
        carry due times from before the outage."""
        while (
            self._retryq
            and self._retryq[0][0] <= now
            and not self._ctl_down()
        ):
            _due, _seq, victim = heapq.heappop(self._retryq)
            prog, completed, rec, origin, attempt = victim
            self._journal("release", now, prog.task_id, of="requeue")
            self._recover(prog, completed, rec, origin, now, attempt)

    def apply_due(self, now: float) -> None:
        """Process every retry and fault event due at or before ``now``."""
        self.drain_due_retries(now)
        while (
            self._ei < len(self.events)
            and self.events[self._ei].time_us <= now
        ):
            ev = self.events[self._ei]
            self._ei += 1
            self._apply(ev, now)
            self.applied.append(ev)

    def dispatch(self, ev: TaskArrival) -> Optional[int]:
        """Place a trace arrival on an alive GPU (the placement policy sees
        only the alive subset). Returns the fleet index, or ``None`` when
        no GPU is alive — the arrival is held and flushed at the next
        ``gpu_recover`` (or accounted lost at drain)."""
        alive = [(i, c) for i, c in enumerate(self.cores) if not c.failed]
        if not alive:
            self._journal(
                "hold", ev.time_us, ev.program.task_id, ev=ev, rec=None
            )
            self._held.append((ev, None, None))
            return None
        idx = self.placement.place(
            ev.program, ev.time_us, [c for _i, c in alive]
        )
        i, core = alive[idx]
        self._journal("place", ev.time_us, ev.program.task_id, gpu=core.name)
        core.inject(ev)
        self.placed[i] += 1
        return i

    # -- fault application ----------------------------------------------------
    def _apply(self, ev: FaultEvent, now: float) -> None:
        tel = self.telemetry
        if ev.kind == "gpu_fail":
            if tel is not None:
                tel.instant("gpu_fail", ev.gpu, now)
            self._gpu_fail(ev.gpu, now)
        elif ev.kind == "gpu_recover":
            core = self._require_core(ev.gpu)
            if tel is not None and core.failed:
                tel.instant("gpu_recover", ev.gpu, now)
            core.recover(now)
            self._flush(now)
        elif ev.kind == "link_degrade":
            self.topology.degrade(ev.link[0], ev.link[1], ev.factor)
            if tel is not None:
                tel.counter(
                    f"link:{ev.link[0]}<->{ev.link[1]}",
                    "bandwidth_factor",
                    now,
                    ev.factor,
                )
        elif ev.kind == "link_restore":
            self.topology.restore(ev.link[0], ev.link[1])
            if tel is not None:
                tel.counter(
                    f"link:{ev.link[0]}<->{ev.link[1]}",
                    "bandwidth_factor",
                    now,
                    1.0,
                )
        elif ev.kind == "task_crash":
            self._crash(ev, now)
        elif ev.kind == "coordinator_crash":
            # validated at engine construction: these events require a
            # ControlPlane, so self.control is never None here
            self.control.crash(now)
        elif ev.kind == "coordinator_recover":
            self.control.recover(now)

    def _require_core(self, name: str) -> SimCore:
        core = self._by_name.get(name)
        if core is None:
            raise ValueError(f"fault event names unknown GPU {name!r}")
        return core

    def _gpu_fail(self, name: str, now: float) -> None:
        core = self._require_core(name)
        if core.failed:
            return  # double-fail in a sampled schedule: already down
        if self.fabric is not None:
            # linger copies *on* the device evaporate with its HBM
            self.fabric.drop_gpu(name)
        report = core.fail(now)
        if self.control is not None:
            # the failure tears every resident task down — journal before
            # any re-placement decision references them
            for victim in report.running:
                self._journal("fail", now, victim.program.task_id, gpu=name)
            for ev, _rec, _warm in report.waiting:
                self._journal("fail", now, ev.program.task_id, gpu=name)
            for ev, _warm in report.pending:
                self._journal("fail", now, ev.program.task_id, gpu=name)
        # queued/pending candidates survive (their state is host-side):
        # re-dispatch each, re-pricing any host-DRAM warm set
        for ev, rec, warm in report.waiting:
            self._redispatch(ev, rec, warm, now, name)
        for ev, warm in report.pending:
            self._redispatch(ev, None, warm, now, name)
        # running victims lost their execution state: recover from the best
        # durable source
        for victim in report.running:
            self._recover(
                victim.program, victim.completed, victim.record, name, now
            )
        self._shed_pressure(now)

    def _crash(self, ev: FaultEvent, now: float) -> None:
        tid = ev.task_id
        core = None
        if tid is not None:
            core = next(
                (
                    c
                    for c in self.cores
                    if not c.failed and tid in c.tasks
                ),
                None,
            )
        else:
            running = [
                (c.name, t, c)
                for c in self.cores
                if not c.failed
                for t in sorted(c.tasks)
            ]
            if running:
                _n, tid, core = running[self.rnd.randrange(len(running))]
        if core is None:
            return  # nothing to kill (pinned task not running anywhere)
        self._journal("fail", now, tid, gpu=core.name, crash=True)
        ej = core.eject(tid)
        if ej.record is not None:
            ej.record.meta["crashed_us"] = now
        self.crashes += 1
        self._recover(ej.program, ej.completed, ej.record, core.name, now)

    # -- recovery ------------------------------------------------------------
    def _log_recovery(self, rev: RecoveryEvent) -> None:
        self.recoveries.append(rev)
        tel = self.telemetry
        if tel is None:
            return
        tel.instant(
            "recovery",
            rev.dst or rev.src,
            rev.time_us,
            task_id=rev.task_id,
            kind=rev.kind,
            src=rev.src,
            replayed_iters=rev.replayed_iters,
        )
        # the gap between the recovery decision and the continuation's
        # re-arrival (restore transit, or backoff on a denied restore) is
        # recovery-induced: the task runs nowhere during it
        tel.stall(rev.task_id, "recovery", rev.arrival_us - rev.time_us)

    def _recover(
        self,
        prog,
        completed: int,
        rec: Optional[RequestRecord],
        origin: str,
        now: float,
        attempt: int = 0,
    ) -> None:
        tid = prog.task_id
        alive = [c for c in self.cores if not c.failed]
        if not alive or self._ctl_down():
            # no placement without an alive GPU — or without a coordinator
            # to decide one. The node agent journals the parked victim: in
            # journal mode the record is what replay re-parks after a
            # coordinator crash wipes this queue.
            self._journal(
                "strand",
                now,
                tid,
                prog=prog,
                completed=completed,
                rec=rec,
                origin=origin,
            )
            self._stranded.append((prog, completed, rec, origin))
            return
        ck = None
        if self.vault is not None and self.recovery in ("auto", "checkpoint"):
            ck = self.vault.get(tid, now, prog)
        linger_src = None
        if self.fabric is not None and self.recovery in ("auto", "linger"):
            entry = self.fabric.directory.get(tid)
            if entry is not None:
                src = self._by_name.get(entry.src)
                if src is not None and not src.failed:
                    linger_src = src
        # preference: progress-bearing landed checkpoint > linger copy >
        # progress-free checkpoint > cold
        if ck is not None and (ck.completed > 0 or linger_src is None):
            target = self._pick(prog, now)
            # RT-class restores outrank everything the planner schedules
            urgency = (
                URGENCY_RT
                if slo_class_of(getattr(rec, "meta", None), prog) == "rt"
                else URGENCY_RESTORE
            )
            plan = self.topology.plan_restore(
                target.name, ck.nbytes, now, urgency=urgency, task_id=tid
            )
            if plan is not None:
                self._journal(
                    "recovery",
                    now,
                    tid,
                    tier="checkpoint",
                    src=origin,
                    dst=target.name,
                    completed=ck.completed,
                    arrival_us=plan.arrival_us,
                )
                if self.fabric is not None:
                    # any surviving linger copy predates the checkpoint's
                    # host-side state — dead once we restore from host
                    self.fabric.release(tid)
                cont = (
                    ResumedTask(prog, ck.completed)
                    if ck.completed > 0
                    else prog
                )
                target.inject(
                    TaskArrival(
                        plan.arrival_us,
                        cont,
                        meta={
                            "migrated_from": origin,
                            "recovered_from": origin,
                            "recovery": "checkpoint",
                        },
                    ),
                    warm_runs=ck.runs,
                )
                self._log_recovery(
                    RecoveryEvent(
                        now, tid, "checkpoint", origin, target.name,
                        ck.completed, completed - ck.completed,
                        plan.arrival_us,
                    )
                )
                return
            if linger_src is None and attempt < self.max_recovery_retries:
                # staging saturated and no warmer fallback: back off
                # (capped exponential) and retry the restore
                due = now + min(
                    self.backoff_us * (2.0 ** attempt), self.backoff_cap_us
                )
                self._journal(
                    "requeue",
                    now,
                    tid,
                    prog=prog,
                    completed=completed,
                    rec=rec,
                    origin=origin,
                    attempt=attempt + 1,
                    due_us=due,
                )
                heapq.heappush(
                    self._retryq,
                    (due, self._seq, (prog, completed, rec, origin, attempt + 1)),
                )
                self._seq += 1
                self._log_recovery(
                    RecoveryEvent(
                        now, tid, "requeue", origin, "", 0, 0, due
                    )
                )
                return
            # else fall through to linger/cold
        if linger_src is not None:
            # the linger copy is exactly the continuation's iteration-0
            # working set: re-place prog on its holder, warm and instant
            # (the harvest path drops the pages from the pool and clears
            # the linger bookkeeping; admission re-owns them)
            warm = self.fabric.harvest(tid)
            if warm is not None:
                self._journal(
                    "recovery",
                    now,
                    tid,
                    tier="linger",
                    src=origin,
                    dst=linger_src.name,
                    completed=0,
                    arrival_us=now,
                )
                linger_src.inject(
                    TaskArrival(
                        now,
                        prog,
                        meta={
                            "migrated_from": origin,
                            "recovered_from": origin,
                            "recovery": "linger",
                        },
                    ),
                    warm_runs=warm,
                )
                self._log_recovery(
                    RecoveryEvent(
                        now, tid, "linger", origin, linger_src.name,
                        0, completed, now,
                    )
                )
                return
        # cold restart: the backing store serves everything on demand
        if self.fabric is not None:
            self.fabric.release(tid)
        target = self._pick(prog, now)
        self._journal(
            "recovery",
            now,
            tid,
            tier="cold",
            src=origin,
            dst=target.name,
            completed=0,
            arrival_us=now,
        )
        target.inject(
            TaskArrival(
                now,
                prog,
                meta={
                    "migrated_from": origin,
                    "recovered_from": origin,
                    "recovery": "cold",
                },
            )
        )
        self._log_recovery(
            RecoveryEvent(
                now, tid, "cold", origin, target.name, 0, completed, now
            )
        )

    def _pick(self, prog, now: float) -> SimCore:
        alive = [c for c in self.cores if not c.failed]
        idx = self.placement.place(prog, now, alive)
        return alive[idx]

    def _redispatch(
        self,
        ev: TaskArrival,
        rec: Optional[RequestRecord],
        warm: Optional[List[PageRun]],
        now: float,
        origin: str,
    ) -> None:
        """Re-place a queued/pending candidate surrendered by a failing
        core. Its warm working set (if any) sits in host DRAM and survives;
        landing it on the new target is a real H2D restore, priced (and
        budget-gated) by ``plan_restore`` — a denied restore drops the warm
        copy (the pages fault back in on demand instead).

        The recovery mode governs which surviving copies re-land warm:
        ``"cold"`` is a true cold-restart baseline (peer linger copies are
        reclaimed and the surrendered warm runs dropped — every page faults
        back in from the backing store); ``"checkpoint"`` restores from
        host-side sources only (warm runs survive, peer copies are
        released); ``"auto"``/``"linger"`` retarget or harvest the linger
        copy like the rebalancer would."""
        alive = [c for c in self.cores if not c.failed]
        if not alive or self._ctl_down():
            self._journal(
                "hold", now, ev.program.task_id, ev=ev, warm=warm, rec=rec
            )
            self._held.append((ev, warm, rec))
            return
        idx = self.placement.place(ev.program, now, alive)
        target = alive[idx]
        tid = ev.program.task_id
        self._journal("place", now, tid, gpu=target.name, origin=origin)
        if self.recovery == "cold":
            if self.fabric is not None:
                self.fabric.release(tid)
            warm = None
        elif self.recovery == "checkpoint":
            if self.fabric is not None:
                self.fabric.release(tid)
        else:
            warm = self._retarget_linger(tid, target.name, warm)
        arrival = max(now, ev.time_us)
        if warm:
            nbytes = run_page_count(warm) * target.page_size
            urgency = (
                URGENCY_RT
                if slo_class_of(ev.meta, ev.program) == "rt"
                else URGENCY_RESTORE
            )
            plan = self.topology.plan_restore(
                target.name, nbytes, now,
                urgency=urgency, task_id=ev.program.task_id,
            )
            if plan is None:
                warm = None
            else:
                arrival = max(arrival, plan.arrival_us)
        target.inject(
            TaskArrival(
                arrival, ev.program, meta=dict(ev.meta, redispatched_from=origin)
            ),
            warm_runs=warm,
        )

    def _retarget_linger(self, tid: int, dst_name: str, warm):
        """Mirror of the rebalancer's linger retargeting for the recovery
        path: keep the directory entry only when the new target can still
        peer-fetch it; otherwise harvest the copy into the warm runs that
        travel with the task."""
        if self.fabric is None:
            return warm
        entry = self.fabric.directory.get(tid)
        if entry is None:
            return warm
        src = self._by_name.get(entry.src)
        if src is None or src.failed:
            self.fabric.directory.forget(tid)
            return warm
        if (
            entry.src != dst_name
            and self.topology.nvlink_peer(entry.src, dst_name) is not None
        ):
            self.fabric.directory.retarget(tid, dst_name)
            return warm
        harvested = self.fabric.harvest(tid)
        if harvested:
            warm = list(warm or []) + harvested
        return warm

    def _flush(self, now: float) -> None:
        """A device came back: held arrivals and stranded victims get
        another shot at placement. Flushing is coordinator work — while the
        coordinator is down the queues keep accumulating, and the control
        plane flushes them itself at ``coordinator_recover``."""
        if self._ctl_down():
            return
        held, self._held = self._held, []
        for ev, warm, rec in held:
            self._journal("release", now, ev.program.task_id, of="hold")
            self._redispatch(ev, rec, warm, now, "held")
        stranded, self._stranded = self._stranded, []
        for prog, completed, rec, origin in stranded:
            self._journal("release", now, prog.task_id, of="strand")
            self._recover(prog, completed, rec, origin, now)

    # -- coordinator-crash queue semantics ------------------------------------
    def wipe_queues(self) -> None:
        """Coordinator crash under journal recovery: the in-memory queues
        die with the coordinator, but their unreleased journal records are
        the durable copy — replay re-parks every item at recovery."""
        self._held.clear()
        self._stranded.clear()
        self._retryq.clear()

    def drop_queues(self, now: float, reason: str) -> List[RequestRecord]:
        """Coordinator crash under cold recovery: parked work is lost
        outright (the restarted coordinator has no record of victims not
        resident on any core). Marks/synthesizes rejected records exactly
        like end-of-run drain and returns the synthesized ones."""
        if self.control is not None:
            for ev, _warm, _rec in self._held:
                self._journal(
                    "release", now, ev.program.task_id, of="hold", why=reason
                )
            for prog, _c, _rec, _o in self._stranded:
                self._journal(
                    "release", now, prog.task_id, of="strand", why=reason
                )
            for _due, _seq, (prog, _c, _rec, _o, _a) in self._retryq:
                self._journal(
                    "release", now, prog.task_id, of="requeue", why=reason
                )
        return self._drop_parked(reason)

    # -- graceful degradation -------------------------------------------------
    def _klass(self, ev: TaskArrival) -> str:
        return slo_class_of(ev.meta, ev.program)

    def _core_demand(self, core: SimCore) -> Tuple[int, int]:
        st = core.state_view()
        return (
            active_demand_pages(st, core.quantum) + st.waiting_pages,
            st.pool.capacity,
        )

    def fleet_pressure(self) -> float:
        demand = 0
        cap = 0
        for core in self.cores:
            if core.failed:
                continue
            d, c = self._core_demand(core)
            demand += d
            cap += c
        return demand / max(1, cap)

    def _shed_pressure(self, now: float) -> None:
        if self._ctl_down():
            return  # shedding is a coordinator decision
        self._shed_class(now, frozenset(("be",)), self.shed_threshold)
        self._shed_class(now, None, self.shed_rt_threshold)

    def _shed_class(
        self, now: float, classes: Optional[frozenset], threshold: Optional[float]
    ) -> None:
        if threshold is None:
            return
        while self.fleet_pressure() > threshold:
            by_pressure = sorted(
                (c for c in self.cores if not c.failed),
                key=lambda c: -(
                    self._core_demand(c)[0] / max(1, c.pool.capacity)
                ),
            )
            shed = None
            for core in by_pressure:
                out = core.shed_one_waiting(
                    lambda ev: classes is None or self._klass(ev) in classes
                )
                if out is not None:
                    shed = (core, out)
                    break
            if shed is None:
                return
            core, (ev, _rec) = shed
            tid = ev.program.task_id
            if self.fabric is not None:
                self.fabric.release(tid)
            self.shed_events.append((now, tid, self._klass(ev), core.name))

    # -- end-of-run accounting -------------------------------------------------
    def live_extra(self) -> Set[int]:
        """Task ids the runtime still holds outside any core (protects
        their checkpoints from pruning)."""
        tids: Set[int] = set()
        tids.update(ev.program.task_id for ev, _w, _r in self._held)
        tids.update(p.task_id for p, _c, _r, _o in self._stranded)
        tids.update(v[0].task_id for _d, _s, v in self._retryq)
        return tids

    def drain_lost(self) -> List[RequestRecord]:
        """End of run: anything still held/stranded (the fleet never came
        back) is accounted as rejected — never silently dropped. Returns
        records synthesized for work that has no fragment anywhere."""
        return self._drop_parked(
            "no_alive_gpu", retry_reason="restore_backoff_unresolved"
        )

    def _drop_parked(
        self, reason: str, retry_reason: Optional[str] = None
    ) -> List[RequestRecord]:
        self.lost += (
            len(self._held) + len(self._stranded) + len(self._retryq)
        )
        synthesized: List[RequestRecord] = []
        for ev, _warm, rec in self._held:
            if rec is not None:
                rec.rejected = True
                rec.meta["lost"] = reason
            else:
                synthesized.append(
                    RequestRecord(
                        ev.program.task_id,
                        ev.time_us,
                        rejected=True,
                        meta=dict(ev.meta, lost=reason),
                    )
                )
        for prog, completed, rec, origin in self._stranded:
            if rec is not None:
                rec.rejected = True
                rec.meta["lost"] = reason
            else:
                synthesized.append(
                    RequestRecord(
                        prog.task_id,
                        0.0,
                        rejected=True,
                        iterations_done=completed,
                        meta={"lost": reason, "origin": origin},
                    )
                )
        # a retry heap drained past the horizon behaves like stranded work
        rr = retry_reason or reason
        for _due, _seq, (prog, completed, rec, _origin, _a) in self._retryq:
            if rec is not None:
                rec.rejected = True
                rec.meta["lost"] = rr
            else:
                synthesized.append(
                    RequestRecord(
                        prog.task_id,
                        0.0,
                        rejected=True,
                        iterations_done=completed,
                        meta={"lost": rr},
                    )
                )
        self._held.clear()
        self._stranded.clear()
        self._retryq.clear()
        return synthesized
