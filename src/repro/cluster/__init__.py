"""Multi-GPU cluster scheduling on top of the MSched simulator.

The cluster subsystem composes N per-GPU simulation cores
(:class:`repro.core.simulator.SimCore`) under one event loop:

  * :mod:`~repro.cluster.topology` — GPU fleet, PCIe/NVLink link graph with
    bandwidth contention, shared host DRAM staging budget;
  * :mod:`~repro.cluster.placement` — which GPU gets an arriving task
    (round-robin / least-loaded baselines vs the MSched-aware bin-packer
    that best-fits predicted working sets against residency headroom);
  * :mod:`~repro.cluster.migration` — inter-GPU task migration: checkpoint
    the working set through ``repro.checkpointing``, pay the link-graph
    transfer, resume on the target; lazy manifest-only moves over NVLink,
    and the admission-rejection retry protocol;
  * :mod:`~repro.cluster.prefetch` — NVLink peer-to-peer working-set
    prefetch: the page-location directory's wiring into each GPU's extended
    context switch, and cluster-wide OPT eviction;
  * :mod:`~repro.cluster.aggregate` — merge per-GPU results/records into
    cluster-wide goodput/TTFT/TPOT;
  * :mod:`~repro.cluster.faults` — fault injection (GPU/link/task failures)
    and the recovery runtime: checkpoint-based re-placement, linger-copy
    harvesting, capped-backoff requeues, and graceful degradation;
  * :mod:`~repro.cluster.engine` — the ``simulate_cluster()`` entrypoint.
"""
from repro.cluster.aggregate import (  # noqa: F401
    RequestStats,
    merge_request_records,
    merge_sim_results,
    peak_concurrent_bytes,
)
from repro.cluster.engine import (  # noqa: F401
    ClusterReport,
    GPUReport,
    simulate_cluster,
)
from repro.cluster.faults import (  # noqa: F401
    Checkpoint,
    CheckpointVault,
    FaultEvent,
    FaultInjector,
    FaultRuntime,
    RecoveryEvent,
)
from repro.cluster.migration import (  # noqa: F401
    MigrationEvent,
    Rebalancer,
    ResumedTask,
)
from repro.cluster.placement import (  # noqa: F401
    PLACEMENTS,
    LeastLoadedPlacement,
    MSchedPlacement,
    PlacementPolicy,
    RoundRobinPlacement,
    make_placement,
)
from repro.cluster.prefetch import (  # noqa: F401
    PeerFetchEvent,
    PeerPrefetchFabric,
)
from repro.cluster.topology import (  # noqa: F401
    ClusterTopology,
    GPUNode,
    LingerEntry,
    Link,
    PageDirectory,
    TransferPlan,
    homogeneous,
    mixed,
)
