"""Fault-tolerant training loop: checkpoint/restart, straggler mitigation,
failure injection, and elastic re-mesh.

Designed for thousands-of-nodes operation:
  * periodic async checkpoints (serialization overlapped with compute),
  * restart-from-latest on failure (``TrainSupervisor.run`` survives injected
    faults and resumes bit-exact thanks to the stateless data pipeline),
  * straggler mitigation: per-step deadline watchdog — steps exceeding
    ``straggler_factor`` x the rolling median are logged and counted so the
    orchestrator can re-slice (on CPU we record; on real fleets this signal
    feeds the MSched scheduler's timeline, which deprioritizes the slow pod),
  * elastic re-mesh: checkpoints are mesh-agnostic, so ``run`` can be invoked
    again with a different mesh and continue.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpointing.checkpoint import AsyncCheckpointer, latest_step, restore
from repro.configs.base import ModelConfig, ShapeSpec
from repro.data.pipeline import TokenPipeline, pipeline_for
from repro.launch.steps import make_train_state, make_train_step


@dataclasses.dataclass
class TrainReport:
    steps_run: int
    final_step: int
    losses: List[float]
    restarts: int
    straggler_steps: int
    checkpoints: int


class FailureInjector:
    """Deterministic fault injection for tests: raises at given steps."""

    def __init__(self, fail_at: Optional[List[int]] = None):
        self.fail_at = set(fail_at or [])
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class TrainSupervisor:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeSpec,
        ckpt_dir: str,
        mesh=None,
        shardings=None,
        ckpt_every: int = 10,
        straggler_factor: float = 3.0,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.shape = shape
        self.ckpt_dir = ckpt_dir
        self.mesh = mesh
        self.shardings = shardings
        self.ckpt_every = ckpt_every
        self.straggler_factor = straggler_factor
        self.pipeline: TokenPipeline = pipeline_for(cfg, shape, seed)
        self.seed = seed

    def _init_state(self):
        state = make_train_state(self.cfg, jax.random.PRNGKey(self.seed))
        if self.shardings is not None:
            state = jax.device_put(state, self.shardings)
        return state

    def _jit_step(self):
        step_fn = make_train_step(self.cfg)
        if self.shardings is not None:
            return jax.jit(step_fn, donate_argnums=(0,))
        return jax.jit(step_fn, donate_argnums=(0,))

    def run(
        self,
        total_steps: int,
        injector: Optional[FailureInjector] = None,
        max_restarts: int = 3,
    ) -> TrainReport:
        restarts = 0
        report = TrainReport(0, 0, [], 0, 0, 0)
        while True:
            try:
                self._run_once(total_steps, injector, report)
                return report
            except RuntimeError as e:
                if "injected node failure" not in str(e) or restarts >= max_restarts:
                    raise
                restarts += 1
                report.restarts = restarts

    def _run_once(self, total_steps, injector, report: TrainReport) -> None:
        ckpt = AsyncCheckpointer(self.ckpt_dir)
        step_fn = self._jit_step()
        start = latest_step(self.ckpt_dir)
        if start is not None:
            target = jax.eval_shape(self._init_state)
            if self.shardings is not None:
                target = jax.tree.map(
                    lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                    target,
                    self.shardings,
                )
            state = restore(self.ckpt_dir, start, target)
            step = start
        else:
            state = self._init_state()
            step = 0

        durations: List[float] = []
        while step < total_steps:
            if injector is not None:
                injector.maybe_fail(step)
            batch = {
                k: jax.numpy.asarray(v) for k, v in self.pipeline.batch(step).items()
            }
            t0 = time.perf_counter()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            durations.append(dt)
            if len(durations) >= 5:
                med = statistics.median(durations[-20:])
                if dt > self.straggler_factor * med:
                    report.straggler_steps += 1
            report.losses.append(loss)
            report.steps_run += 1
            step += 1
            report.final_step = step
            if step % self.ckpt_every == 0 or step == total_steps:
                ckpt.save_async(step, state)
                report.checkpoints += 1
        ckpt.wait()
