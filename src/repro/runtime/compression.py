"""Int8 gradient compression with error feedback for the DP axis.

At 1000+ nodes the gradient all-reduce over DCN (the ``pod`` axis) is the
scaling bottleneck; quantizing to int8 cuts that traffic 4x (bf16) with an
error-feedback accumulator preserving convergence (1-bit-Adam-style residual
carrying). Applied as a gradient transform around the optimizer update —
composes with any optimizer and with GSPMD (the quantized tree reduces with
the same shardings).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g.astype(jnp.float32))) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(grads: Any, error: Any) -> Tuple[Any, Any, dict]:
    """Returns (decompressed grads as the optimizer sees them, new error
    feedback state, stats). The quantize->dequantize round trip models the
    wire format; on a real fleet the int8 tree is what crosses DCN."""

    def per_leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    out = [per_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in out])
    new_e = treedef.unflatten([o[1] for o in out])
    orig_bytes = sum(g.size * g.dtype.itemsize for g in flat_g)
    wire_bytes = sum(g.size * 1 + 4 for g in flat_g)
    return new_g, new_e, {"compression_ratio": orig_bytes / max(wire_bytes, 1)}
