"""Batched serving loop with MSched-style multi-model scheduling.

Hosts several models on one device budget: requests queue per model, the
scheduler round-robins (or priority-schedules) across models, and the MSched
coordinator proactively migrates the next model's weights into the device
pool before its batch runs — serving-side integration of the paper's
extended context switch (the live analogue of benchmarks fig13).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.runtime import LiveModelTask, LiveRuntime


@dataclasses.dataclass
class Request:
    model: int
    arrival_s: float
    tokens: int = 1


@dataclasses.dataclass
class ServeStats:
    served: Dict[int, int]
    latencies_s: Dict[int, List[float]]
    migrated_in_bytes: int
    demand_faults: int

    def p99(self, model: int) -> float:
        xs = sorted(self.latencies_s.get(model, []))
        if not xs:
            return 0.0
        return xs[min(len(xs) - 1, int(0.99 * len(xs)))]


class MultiModelServer:
    def __init__(
        self,
        archs: List[str],
        hbm_budget_bytes: Optional[int] = None,
        steps_per_slice: int = 4,
    ):
        tasks = [LiveModelTask(i, a, seed=i) for i, a in enumerate(archs)]
        total = sum(t.footprint_bytes() for t in tasks)
        budget = hbm_budget_bytes or int(total / 1.5)  # 150% oversubscription
        self.runtime = LiveRuntime(tasks, budget, steps_per_slice=steps_per_slice)
        self.queues: Dict[int, Deque[Request]] = {
            t.task_id: deque() for t in tasks
        }

    def submit(self, req: Request) -> None:
        self.queues[req.model].append(req)

    def serve(self, wall_budget_s: float = 5.0) -> ServeStats:
        stats = ServeStats(
            {m: 0 for m in self.queues}, {m: [] for m in self.queues}, 0, 0
        )
        t_end = time.perf_counter() + wall_budget_s
        rt = self.runtime
        while time.perf_counter() < t_end and any(self.queues.values()):
            # pick the model with the oldest pending request (FIFO fairness)
            pending = {m: q for m, q in self.queues.items() if q}
            if not pending:
                break
            model = min(pending, key=lambda m: pending[m][0].arrival_s)
            # run one slice for that model via the MSched runtime
            before = rt.stats.steps[model]
            rt.policy._rr = [model] + [m for m in rt.tasks if m != model]
            rt.run(total_slices=1)
            served_steps = rt.stats.steps[model] - before
            now = time.perf_counter()
            for _ in range(min(served_steps, len(self.queues[model]))):
                req = self.queues[model].popleft()
                stats.served[model] += 1
                stats.latencies_s[model].append(now - req.arrival_s)
        stats.migrated_in_bytes = rt.stats.migrated_in_bytes
        stats.demand_faults = rt.stats.demand_faults
        return stats
