"""HLO-text cost extraction with while-loop trip-count correction.

``compiled.cost_analysis()`` counts a while-loop body ONCE (verified on this
jax/XLA build: an 8-step scan of matmuls reports 1/8 of the true FLOPs), so
layer-scanned models would be undercounted by ~num_layers x. This module
parses the post-optimization HLO text (already the per-device partitioned
module), builds the computation call graph (while bodies, fusions, calls),
multiplies by trip counts, and returns:

  * dot FLOPs (2 x result_elems x contraction) — the dominant compute term
  * collective bytes by kind, with per-op transfer models:
      all-gather:          result - operand     (ring, (k-1)/k x result)
      reduce-scatter:      operand - result
      all-reduce:          2 x operand          (ring: ~2(k-1)/k x operand)
      all-to-all:          operand              ((k-1)/k x operand)
      collective-permute:  operand
  * per-kind op counts

Trip counts come from the while op's backend_config known_trip_count when
present, else the max s32 constant in the loop condition computation.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s*->\s*.+\s*\{\s*$")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_info(type_str: str) -> Tuple[int, int]:
    """(elements, bytes) for the first shape in a type string (tuples: sum)."""
    total_e = total_b = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.groups()
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_e += n
        total_b += n * _DTYPE_BYTES[dt]
    return total_e, total_b


def _first_shape(type_str: str) -> Optional[Tuple[str, List[int]]]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclasses.dataclass
class OpInfo:
    kind: str
    result_type: str
    body: str  # full op text after '='


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, OpInfo]
    lines: List[str]


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and ("->" in line):
            cur = Computation(m.group(1), {}, [])
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        cur.lines.append(line)
        dm = _DEF_RE.match(line)
        if dm:
            name, rest = dm.groups()
            # op kind = token right before '('
            km = re.search(r"\}?\s*([\w\-]+)\(", rest)
            kind = km.group(1) if km else ""
            # result type = prefix before the op kind
            rtype = rest[: km.start()] if km else rest
            cur.ops[name] = OpInfo(kind, rtype, rest)
    return comps


def _operand_refs(body: str) -> List[str]:
    inner = body[body.index("(") + 1 :]
    depth = 1
    out, cur = [], []
    for ch in inner:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        cur.append(ch)
    args = "".join(cur)
    return re.findall(r"%([\w.\-]+)", args)


def _trip_count(body_cfg: str, cond_comp: Optional[Computation]) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', body_cfg)
    if m:
        return int(m.group(1))
    if cond_comp is not None:
        consts = [
            int(c)
            for c in re.findall(r"s32\[\]\s*constant\((\d+)\)", "\n".join(cond_comp.lines))
        ]
        if consts:
            return max(consts)
    return 1


@dataclasses.dataclass
class HloCosts:
    dot_flops: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    while_loops: int

    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze_hlo(hlo: str) -> HloCosts:
    comps = parse_computations(hlo)

    entry = None
    for name, c in comps.items():
        if any("parameter(0)" in l and "metadata" in l for l in c.lines):
            pass
    # entry is the computation containing the top-level while/outfeed; XLA
    # prints ENTRY with the module name — detect by 'ENTRY' keyword:
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        # fall back: the computation that is never referenced by others
        referenced = set()
        for c in comps.values():
            for line in c.lines:
                for r in re.findall(r"(?:calls|body|condition|to_apply)=%([\w.\-]+)", line):
                    referenced.add(r)
        cands = [n for n in comps if n not in referenced]
        entry = cands[-1] if cands else next(iter(comps))

    costs = HloCosts(0.0, {k: 0.0 for k in COLLECTIVES}, {k: 0.0 for k in COLLECTIVES}, 0)

    def comp_cost(name: str, mult: float, seen: Tuple[str, ...]):
        if name not in comps or name in seen:
            return
        c = comps[name]
        symtab = {n: op.result_type for n, op in c.ops.items()}
        for op_name, op in c.ops.items():
            kind = op.kind
            if kind == "dot":
                sh = _first_shape(op.result_type)
                if sh is None:
                    continue
                _, rdims = sh
                relems = 1
                for d in rdims:
                    relems *= d
                refs = _operand_refs(op.body)
                contract = 1
                cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.body)
                if refs and cm and refs[0] in symtab:
                    lsh = _first_shape(symtab[refs[0]])
                    if lsh:
                        for idx in cm.group(1).split(","):
                            if idx and int(idx) < len(lsh[1]):
                                contract *= lsh[1][int(idx)]
                costs.dot_flops += mult * 2.0 * relems * contract
            elif kind in COLLECTIVES:
                refs = _operand_refs(op.body)
                op_bytes = 0
                for r in refs:
                    if r in symtab:
                        op_bytes += _shape_info(symtab[r])[1]
                _, res_bytes = _shape_info(op.result_type)
                if kind == "all-gather":
                    moved = max(res_bytes - op_bytes, 0)
                elif kind == "reduce-scatter":
                    moved = max(op_bytes - res_bytes, 0)
                elif kind == "all-reduce":
                    moved = 2 * op_bytes
                else:  # all-to-all, collective-permute
                    moved = op_bytes
                costs.collective_bytes[kind] += mult * moved
                costs.collective_counts[kind] += mult
            elif kind == "while":
                bm = re.search(r"body=%([\w.\-]+)", op.body)
                cm2 = re.search(r"condition=%([\w.\-]+)", op.body)
                trips = _trip_count(op.body, comps.get(cm2.group(1)) if cm2 else None)
                costs.while_loops += 1
                if bm:
                    comp_cost(bm.group(1), mult * trips, seen + (name,))
            elif kind in ("fusion", "call", "conditional", "custom-call", "map", "reduce", "reduce-window", "sort", "scatter", "select-and-scatter"):
                for r in re.findall(r"(?:calls|to_apply|branch_computations=\{)[=%]*%?([\w.\-]+)", op.body):
                    comp_cost(r, mult, seen + (name,))
                cm3 = re.findall(r"calls=%([\w.\-]+)", op.body)
                for r in cm3:
                    pass  # already handled above

    comp_cost(entry, 1.0, ())
    return costs
