"""Input specs per (arch × shape): ShapeDtypeStruct stand-ins for the dry-run
and concrete random batches for smoke tests — same shapes, one source of truth.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import common

VISION_FRACTION = 8  # qwen2-vl: 1/8 of the sequence is vision patches


def batch_spec(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract input specs for ``train_step``/``prefill_step``/``serve_step``."""
    b, s = shape.global_batch, shape.seq_len
    dt = common.dtype_of(cfg)
    i32 = jnp.int32

    if shape.kind == "decode":
        spec = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
        if cfg.family == "vlm":
            pass  # decode positions derive from the cache index
        return spec

    if cfg.family == "audio":
        spec = {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dt),
            "frame_mask": jax.ShapeDtypeStruct((b, s), jnp.bool_),
        }
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        return spec

    if cfg.family == "vlm":
        s_vis = s // VISION_FRACTION
        s_text = s - s_vis
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, s_text), i32),
            "vision_embeds": jax.ShapeDtypeStruct((b, s_vis, cfg.d_model), dt),
            "positions3": jax.ShapeDtypeStruct((3, b, s), i32),
        }
        if shape.kind == "train":
            spec["labels"] = jax.ShapeDtypeStruct((b, s_text), i32)
        return spec

    spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    if shape.kind == "train":
        spec["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return spec


def make_concrete_batch(cfg: ModelConfig, shape: ShapeSpec, seed: int = 0):
    """Materialize a random batch matching ``batch_spec`` (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for name, sds in batch_spec(cfg, shape).items():
        if name in ("tokens", "labels"):
            out[name] = jnp.asarray(
                rng.integers(1, cfg.vocab_size, size=sds.shape, dtype=np.int32)
            )
        elif name == "positions3":
            _, b, s = sds.shape
            pos = np.broadcast_to(np.arange(s, dtype=np.int32), (3, b, s))
            out[name] = jnp.asarray(pos)
        elif name == "frame_mask":
            out[name] = jnp.asarray(rng.random(sds.shape) < 0.3)
        else:  # float embeddings
            out[name] = jnp.asarray(
                rng.standard_normal(sds.shape), dtype=sds.dtype
            )
    return out
