"""Multi-model serving launcher: MSched-scheduled colocation.

    PYTHONPATH=src python -m repro.launch.serve \
        --archs qwen3-1.7b,llama3.2-3b,mamba2-1.3b --oversub 1.5 --requests 24

Hosts several (reduced) models under one device-memory budget; the MSched
coordinator proactively migrates each model's working set on its slice.
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--archs", default="qwen3-1.7b,llama3.2-3b,mamba2-1.3b"
    )
    ap.add_argument("--oversub", type=float, default=1.5)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--wall-budget-s", type=float, default=20.0)
    args = ap.parse_args()

    from repro.core.runtime import LiveModelTask
    from repro.runtime.serve_loop import MultiModelServer, Request

    archs = args.archs.split(",")
    probe = [LiveModelTask(i, a) for i, a in enumerate(archs)]
    total = sum(t.footprint_bytes() for t in probe)
    budget = int(total / args.oversub)
    print(
        f"{len(archs)} models, aggregate {total/2**20:.1f} MiB, "
        f"budget {budget/2**20:.1f} MiB ({100*args.oversub:.0f}% oversubscription)"
    )
    server = MultiModelServer(archs, hbm_budget_bytes=budget)
    t0 = time.perf_counter()
    for i in range(args.requests):
        server.submit(Request(model=i % len(archs), arrival_s=time.perf_counter()))
    stats = server.serve(wall_budget_s=args.wall_budget_s)
    for m in range(len(archs)):
        print(
            f"model {m} ({archs[m]}): served={stats.served[m]} "
            f"p99={1e3*stats.p99(m):.0f}ms"
        )
    print(
        f"migrated_in={stats.migrated_in_bytes/2**20:.1f}MiB "
        f"faults={stats.demand_faults} wall={time.perf_counter()-t0:.1f}s"
    )


if __name__ == "__main__":
    main()
