"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 50 --ckpt-dir /tmp/ckpt [--devices 8 --mesh 2x4]

With ``--devices N`` the launcher forks a host-device mesh (CPU testing);
on a real fleet, jax.distributed handles process groups and the same code
runs per host. Checkpoints are mesh-agnostic (elastic re-mesh: restart with
a different --mesh and training continues from the latest step).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--devices", type=int, default=0, help="fake host devices")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 => (data=2, model=4)")
    args = ap.parse_args()

    if args.devices and "XLA_FLAGS" not in os.environ:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}"
        )

    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import make_train_state
    from repro.runtime.train_loop import TrainSupervisor
    from repro.sharding.act import use_activation_mesh
    from repro.sharding.specs import opt_state_shardings, param_shardings

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("train", args.seq_len, args.global_batch, "train")

    mesh = shardings = None
    ctx = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = make_mesh(dims, axes)
        state_shape = jax.eval_shape(
            lambda: make_train_state(cfg, jax.random.PRNGKey(0))
        )
        pspecs = param_shardings(cfg, state_shape["params"], mesh)
        ospecs = opt_state_shardings(cfg, state_shape["opt"], pspecs, mesh)
        shardings = {
            "params": pspecs,
            "opt": ospecs,
            "step": NamedSharding(mesh, P()),
        }
        ctx = use_activation_mesh(mesh)

    sup = TrainSupervisor(
        cfg, shape, args.ckpt_dir, mesh=mesh, shardings=shardings,
        ckpt_every=args.ckpt_every,
    )
    if ctx is not None:
        with ctx:
            report = sup.run(args.steps)
    else:
        report = sup.run(args.steps)
    print(
        f"final_step={report.final_step} loss {report.losses[0]:.3f} -> "
        f"{report.losses[-1]:.3f} checkpoints={report.checkpoints}"
    )


if __name__ == "__main__":
    main()
