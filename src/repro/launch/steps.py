"""Step builders: train_step / prefill_step / serve_step per architecture."""
from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import ModelFns, build_model
from repro.optim import get_optimizer, get_schedule


def make_train_state(cfg: ModelConfig, rng):
    fns = build_model(cfg)
    params = fns.init(rng)
    opt = get_optimizer(cfg.optimizer)
    return {"params": params, "opt": opt.init(params), "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(cfg: ModelConfig):
    """ShapeDtypeStruct train state (dry-run: no allocation)."""
    rng = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda: make_train_state(cfg, rng))


def make_train_step(
    cfg: ModelConfig,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    microbatches: int = 0,
):
    """``microbatches`` > 1 enables gradient accumulation: the batch splits
    along dim 0 and a scan accumulates grads, dividing peak activation
    memory (the remat carry stack) by the microbatch count at the cost of
    smaller per-matmul shapes. Overridable via REPRO_MICROBATCH for the
    dry-run perf sweeps."""
    fns = build_model(cfg)
    opt = get_optimizer(cfg.optimizer)
    sched = get_schedule(cfg.schedule, peak_lr, warmup, total_steps)
    n_micro = microbatches or int(os.environ.get("REPRO_MICROBATCH", "0") or 0)

    def _split(batch, i):
        def per(v):
            if v.ndim >= 1 and v.shape[0] % n_micro == 0:
                mb = v.shape[0] // n_micro
                return jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=0)
            if v.ndim >= 2 and v.shape[1] % n_micro == 0:  # positions3 (3,B,S)
                mb = v.shape[1] // n_micro
                return jax.lax.dynamic_slice_in_dim(v, i * mb, mb, axis=1)
            return v
        return {k: per(v) for k, v in batch.items()}

    def train_step(state: Dict[str, Any], batch: Dict[str, Any]):
        def loss_fn(params, b):
            return fns.loss(params, b)

        if n_micro > 1:
            def micro_step(acc, i):
                (loss, aux), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    state["params"], _split(batch, i)
                )
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(a.dtype) / n_micro, acc[0], g
                ), (acc[1][0] + loss / n_micro, acc[1][1])
                return acc, None
            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"]
            )
            aux0 = {"ce": jnp.float32(0.0), "aux": jnp.float32(0.0)}
            (grads, (loss, aux)), _ = jax.lax.scan(
                micro_step, (zero, (jnp.float32(0.0), aux0)),
                jnp.arange(n_micro),
            )
        else:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state["params"], batch
            )
        lr = sched(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], state["params"], lr)
        gnorm = jnp.sqrt(
            sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree.leaves(grads)
            )
        )
        metrics = {
            "loss": loss.astype(jnp.float32),
            "ce": aux["ce"].astype(jnp.float32),
            "grad_norm": gnorm,
            "lr": lr,
        }
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    fns = build_model(cfg)

    def prefill_step(params, batch):
        return fns.prefill(params, batch)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """Decode: one new token against a seq_len-sized cache (per assignment)."""
    fns = build_model(cfg)

    def serve_step(params, cache, batch):
        return fns.decode_step(params, cache, batch)

    return serve_step


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    fns = build_model(cfg)
    return jax.eval_shape(lambda: fns.init_cache(batch, max_seq))
