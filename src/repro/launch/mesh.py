"""Production mesh construction.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the ``pod`` axis maps to
the DCN dimension and composes with ``data`` for batch/gradient reduction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; omit it elsewhere (Auto is
    the default there anyway)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic re-mesh, tests on few host devices)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_type_kwargs(len(axes)))


def dp_axes(mesh) -> tuple:
    """Axes that carry the global batch (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)
