import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, record memory/cost/collective artifacts for §Roofline.

MUST be run as a module: ``PYTHONPATH=src python -m repro.launch.dryrun
--arch llama3.2-3b [--multi-pod] [--shapes train_4k,...] --out dryrun.jsonl``

The XLA_FLAGS line above executes before any other import (jax locks the
device count at first init). Smoke tests and benches never import this
module, so they see the real single CPU device.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, ASSIGNED, get_config  # noqa: E402
from repro.configs.base import SHAPES, SHAPE_ORDER, cell_applicable  # noqa: E402
from repro.launch import steps as steps_lib  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import batch_spec  # noqa: E402
from repro.roofline.hlo_costs import analyze_hlo  # noqa: E402
from repro.sharding.act import use_activation_mesh  # noqa: E402
from repro.sharding import specs as sh  # noqa: E402


def _abstractify(shape_tree, shardings):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        shape_tree,
        shardings,
    )


def build_cell(arch: str, shape_name: str, mesh):
    """Returns (fn, args_abstract, donate) for the cell."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    bspec = batch_spec(cfg, shape)
    bshard = sh.batch_shardings(cfg, bspec, mesh)
    batch_abs = {
        k: jax.ShapeDtypeStruct(v.shape, v.dtype, sharding=bshard[k])
        for k, v in bspec.items()
    }

    pshape = jax.eval_shape(
        lambda: steps_lib.make_train_state(cfg, jax.random.PRNGKey(0))
    )
    pspecs = sh.param_shardings(cfg, pshape["params"], mesh)

    if shape.kind == "train":
        ospecs = sh.opt_state_shardings(cfg, pshape["opt"], pspecs, mesh)
        sspecs = {
            "params": pspecs,
            "opt": ospecs,
            "step": NamedSharding(mesh, P()),
        }
        state_abs = _abstractify(pshape, sspecs)
        fn = steps_lib.make_train_step(cfg)
        return fn, (state_abs, batch_abs), (0,)

    params_abs = _abstractify(pshape["params"], pspecs)
    if shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(cfg)
        return fn, (params_abs, batch_abs), ()

    # decode: serve_step over a seq_len-sized cache
    cache_shape = steps_lib.abstract_cache(cfg, shape.global_batch, shape.seq_len)
    cspecs = sh.cache_shardings(cfg, cache_shape, mesh, shape.global_batch)
    cache_abs = _abstractify(cache_shape, cspecs)
    fn = steps_lib.make_serve_step(cfg)
    return fn, (params_abs, cache_abs, batch_abs), (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "multi_pod": multi_pod,
    }
    cfg = get_config(arch)
    ok, reason = cell_applicable(cfg, SHAPES[shape_name])
    if not ok:
        rec["status"] = "skipped"
        rec["skip_reason"] = reason
        return rec
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        t0 = time.time()
        with use_activation_mesh(mesh):
            fn, args, donate = build_cell(arch, shape_name, mesh)
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax returns [dict]
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        hc = analyze_hlo(hlo)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            n_devices=mesh.size,
            mem={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            cost_analysis={
                "flops_raw": ca.get("flops", 0.0),
                "bytes_raw": ca.get("bytes accessed", 0.0),
            },
            hlo_costs={
                "dot_flops": hc.dot_flops,
                "collective_bytes": hc.collective_bytes,
                "collective_counts": hc.collective_counts,
                "while_loops": hc.while_loops,
            },
            model={
                "param_count": cfg.param_count(),
                "active_param_count": cfg.active_param_count(),
            },
        )
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--shapes", default=None, help="comma-separated shape names")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None, help="append JSONL here")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a in ASSIGNED:
            print(a)
        return

    archs = [args.arch] if args.arch else list(ASSIGNED)
    shapes = args.shapes.split(",") if args.shapes else list(SHAPE_ORDER)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for mp in meshes:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mp)
                line = json.dumps(rec)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
                brief = {
                    k: rec.get(k)
                    for k in ("arch", "shape", "mesh", "status", "compile_s")
                }
                if rec.get("status") == "ok":
                    brief["temp_GiB"] = round(rec["mem"]["temp_bytes"] / 2**30, 2)
                    brief["arg_GiB"] = round(rec["mem"]["argument_bytes"] / 2**30, 2)
                if rec.get("status") == "error":
                    brief["error"] = rec["error"][:200]
                print(json.dumps(brief), flush=True)


if __name__ == "__main__":
    main()
