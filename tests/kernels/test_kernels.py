"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; the kernels themselves target TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.streammm.kernel import stream_matmul, stream_matmul_int8
from repro.kernels.streammm.ref import stream_matmul_int8_ref, stream_matmul_ref


def _close(a, b, rtol=5e-2, atol=5e-2):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), rtol=rtol, atol=atol
    )


# -- streammm ---------------------------------------------------------------

MM_SHAPES = [(64, 64, 64), (128, 256, 192), (256, 128, 128), (64, 512, 64)]


@pytest.mark.parametrize("m,k,n", MM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_stream_matmul(m, k, n, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(dtype)
    w = jax.random.normal(k2, (k, n), jnp.float32).astype(dtype)
    out = stream_matmul(
        x, w, block_m=64, block_n=64, block_k=64, out_dtype=dtype, interpret=True
    )
    _close(out, stream_matmul_ref(x, w, out_dtype=dtype))


@pytest.mark.parametrize("m,k,n", [(64, 128, 64), (128, 256, 128)])
def test_stream_matmul_int8(m, k, n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    bk = 64
    x = jax.random.normal(k1, (m, k), jnp.float32).astype(jnp.bfloat16)
    wq = jax.random.randint(k2, (k, n), -127, 127, jnp.int8)
    scales = jax.random.uniform(k1, (k // bk, n), jnp.float32, 0.005, 0.02)
    out = stream_matmul_int8(
        x, wq, scales, block_m=64, block_n=64, block_k=bk, interpret=True
    )
    _close(out, stream_matmul_int8_ref(x, wq, scales, bk))


# -- flash attention ----------------------------------------------------------

FA_CASES = [
    # (B, Sq, Skv, H, Hkv, D, causal, window)
    (1, 128, 128, 4, 4, 32, True, 0),
    (2, 256, 256, 8, 2, 64, True, 0),
    (2, 128, 128, 4, 1, 32, True, 64),  # MQA + sliding window
    (1, 128, 128, 4, 4, 32, False, 0),  # bidirectional (hubert)
]


@pytest.mark.parametrize("b,sq,skv,h,hkv,d,causal,window", FA_CASES)
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_flash_attention(b, sq, skv, h, hkv, d, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, sq, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32).astype(dtype)
    out = flash_attention(
        q, k, v, causal=causal, window=window, block_q=64, block_kv=64,
        interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    _close(out, ref)


# -- paged attention ----------------------------------------------------------

PA_CASES = [
    # (B, H, Hkv, D, page_tokens, max_pages)
    (2, 4, 2, 32, 16, 4),
    (3, 8, 1, 64, 32, 3),
    (1, 4, 4, 32, 16, 8),
]


@pytest.mark.parametrize("b,h,hkv,d,pt,mp", PA_CASES)
def test_paged_attention(b, h, hkv, d, pt, mp):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    n_pool = b * mp + 2
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(jnp.bfloat16)
    pool_k = jax.random.normal(ks[1], (n_pool, pt, hkv, d), jnp.float32).astype(
        jnp.bfloat16
    )
    pool_v = jax.random.normal(ks[2], (n_pool, pt, hkv, d), jnp.float32).astype(
        jnp.bfloat16
    )
    # each sequence gets mp distinct pages; lengths somewhere mid-page
    table = jnp.arange(b * mp, dtype=jnp.int32).reshape(b, mp)
    lengths = jnp.asarray(
        [1 + (i * 7) % (pt * mp - 1) for i in range(b)], jnp.int32
    )
    out = paged_attention(q, pool_k, pool_v, table, lengths, interpret=True)
    ref = paged_attention_ref(q, pool_k, pool_v, table, lengths)
    _close(out, ref)


def test_paged_attention_growing_length():
    """Decode realism: growing length touches exactly one more page at the
    boundary — the T2-predictable working-set growth (paper §5.1)."""
    b, h, hkv, d, pt, mp = 1, 4, 2, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = jax.random.normal(ks[0], (b, h, d), jnp.float32).astype(jnp.bfloat16)
    pool_k = jax.random.normal(ks[1], (mp, pt, hkv, d), jnp.float32).astype(jnp.bfloat16)
    pool_v = jax.random.normal(ks[2], (mp, pt, hkv, d), jnp.float32).astype(jnp.bfloat16)
    table = jnp.arange(mp, dtype=jnp.int32)[None]
    for ln in (1, pt, pt + 1, 2 * pt, mp * pt):
        out = paged_attention(
            q, pool_k, pool_v, table, jnp.asarray([ln], jnp.int32), interpret=True
        )
        ref = paged_attention_ref(
            q, pool_k, pool_v, table, jnp.asarray([ln], jnp.int32)
        )
        _close(out, ref)
