"""Make `pytest tests/` work without PYTHONPATH=src (harmless with it)."""
import os
import sys

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)
