"""Prefill + decode must agree with the full-sequence forward pass.

This exercises the KV cache, the hybrid ring-buffer/window cache, the RG-LRU
recurrent state carry-over, and the SSM conv/state decode continuation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model

DECODE_ARCHS = [a for a in sorted(ARCHS) if ARCHS[a].has_decode]
B = 2
S = 68  # prefill 64 (multiple of reduced ssm chunk 32), decode 4 more


def _batches(cfg, rng):
    tokens = jax.random.randint(rng, (B, S), 1, cfg.vocab_size, jnp.int32)
    if cfg.family == "vlm":
        s_vis = 8
        ve = jax.random.normal(rng, (B, s_vis, cfg.d_model), jnp.bfloat16)
        pos = jnp.broadcast_to(jnp.arange(S + s_vis, dtype=jnp.int32), (3, B, S + s_vis))
        full = {
            "tokens": tokens,
            "vision_embeds": ve,
            "positions3": pos,
        }
        prefill = {
            "tokens": tokens[:, : S - 4],
            "vision_embeds": ve,
            "positions3": pos[:, :, : S + s_vis - 4],
        }
        return full, prefill, tokens
    full = {"tokens": tokens}
    prefill = {"tokens": tokens[:, : S - 4]}
    return full, prefill, tokens


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    fns = build_model(cfg)
    rng = jax.random.PRNGKey(7)
    params = fns.init(rng)
    full, prefill_batch, tokens = _batches(cfg, rng)

    ref_logits = jax.jit(fns.forward)(params, full).astype(jnp.float32)
    logits, cache = jax.jit(lambda p, b: fns.prefill(p, b, max_seq=S + 8))(params, prefill_batch)

    # prefill's last-position logits match the forward pass at that position
    ref_at = ref_logits[:, S - 5 + (8 if cfg.family == "vlm" else 0), :]
    np.testing.assert_allclose(
        np.asarray(logits[:, 0, :]), np.asarray(ref_at), rtol=0.08, atol=0.08
    )

    # four decode steps reproduce the tail of the forward pass
    decode = jax.jit(fns.decode_step)
    for i in range(4):
        tok = tokens[:, S - 4 + i][:, None]
        logits, cache = decode(params, cache, {"tokens": tok})
        ref_i = ref_logits[:, S - 4 + i + (8 if cfg.family == "vlm" else 0), :]
        np.testing.assert_allclose(
            np.asarray(logits[:, 0, :]).astype(np.float32),
            np.asarray(ref_i),
            rtol=0.08,
            atol=0.08,
            err_msg=f"{arch}: decode step {i} diverged",
        )
